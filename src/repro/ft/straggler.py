"""Straggler mitigation for coordinated checkpoints.

Two mechanisms (DESIGN.md §4):

1. **CP-dedicated threads** (core/async_engine.py) keep slow I/O off the
   step path entirely — a slow disk delays the *next* checkpoint, not the
   training step.
2. **Quorum commit**: an L2 checkpoint is restorable when, for every rank,
   either its own payload or its partner's replica exists. The commit
   validator below implements that rule, so a straggler (or dead) writer
   does not block the commit — its partner's copy covers it.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List, Optional

from repro.core import manifest as mf
from repro.redundancy.groups import Topology


@dataclass
class QuorumReport:
    restorable: bool
    present: List[int]
    covered_by_partner: List[int]
    lost: List[int]


def validate_quorum(ckpt_dir_path: str, topo: Topology) -> QuorumReport:
    """Is this (possibly incomplete) checkpoint restorable for all ranks?"""
    present, covered, lost = [], [], []
    for r in range(topo.world):
        own = os.path.join(ckpt_dir_path, f"rank{r}.chk5")
        if os.path.exists(own):
            present.append(r)
            continue
        holder = topo.partner_of(r)
        rep = os.path.join(ckpt_dir_path, f"rank{holder}.partner{r}.chk5")
        if os.path.exists(rep):
            covered.append(r)
        else:
            lost.append(r)
    return QuorumReport(not lost, present, covered, lost)


def commit_if_quorum(root: str, ckpt_id: int, topo: Topology,
                     extra_meta: Optional[dict] = None) -> bool:
    """Commit a .tmp checkpoint when the quorum rule holds (straggler-safe
    commit path used by the training loop's watchdog)."""
    d = mf.ckpt_dir(root, ckpt_id, tmp=True)
    if not os.path.isdir(d):
        return False
    rep = validate_quorum(d, topo)
    if not rep.restorable:
        return False
    mf.write_manifest(root, ckpt_id, dict(
        extra_meta or {}, kind="FULL", level=2, world=topo.world,
        quorum={"present": rep.present, "partner": rep.covered_by_partner}))
    mf.commit(root, ckpt_id)
    return True
