"""Mesh-change restart: a checkpoint written under one mesh restores under
different mesh shapes, bit-exact after gather, with the restored leaves
placed per the new mesh's shardings. Runs in a subprocess with 16 forced
host devices (device count locks at jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.core.context import CHK_DIFF, CheckpointConfig, CheckpointContext
    from repro.core.protect import flatten_named
    from repro.core.resharding import gather_tree, reshard_tree
    from repro.dist.sharding import param_shardings
    from repro.models.zoo import build_model

    ckpt_dir = sys.argv[1]
    cfg = get_arch("tinyllama-1.1b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    # store under a 4x4 mesh, params sharded per the TP/DP rules
    mesh_a = jax.make_mesh((4, 4), ("data", "model"))
    params_a = reshard_tree(params, param_shardings(mesh_a, m.param_struct()))
    ctx = CheckpointContext(CheckpointConfig(
        dir=ckpt_dir, backend="fti", dedicated_thread=False, block_bytes=256))
    ctx.store(params_a, id=1, level=1)                       # FULL base
    embed2 = params_a["embed"].at[0, 0].set(-3.0)            # stays sharded
    params_a2 = dict(params_a, embed=embed2)
    ctx.store(params_a2, id=2, level=1, kind=CHK_DIFF)       # DIFF link
    ctx.shutdown()
    want = gather_tree(params_a2)                            # global view

    # restart on two other mesh shapes: the restart template carries the
    # new mesh's shardings; load must land every leaf on them, bit-exact
    for shape in ((2, 8), (16, 1)):
        mesh_b = jax.make_mesh(shape, ("data", "model"))
        sh_b = param_shardings(mesh_b, m.param_struct())
        template = reshard_tree(jax.tree.map(jnp.zeros_like, params), sh_b)
        ctx2 = CheckpointContext(CheckpointConfig(
            dir=ckpt_dir, backend="fti", dedicated_thread=False,
            block_bytes=256))
        got = ctx2.load(template)
        assert ctx2.restarted, shape
        ctx2.shutdown()
        got_named = flatten_named(got)[0]
        sh_named = flatten_named(sh_b)[0]
        for path, arr in flatten_named(want)[0].items():
            np.testing.assert_array_equal(
                np.asarray(got_named[path]), arr, err_msg=f"{shape} {path}")
            assert got_named[path].sharding == sh_named[path], (shape, path)
    assert float(want["embed"][0, 0]) == -3.0      # the DIFF link replayed
    print("MESH-RESTART-OK")
""")


def test_store_one_mesh_restore_on_two_others(tmp_path):
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path / "ck")],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "MESH-RESTART-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
