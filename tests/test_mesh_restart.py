"""Mesh-change restart: a checkpoint written under one mesh restores under
different mesh shapes, bit-exact, with the restored leaves placed per the
new mesh's shardings.  Stores are shard-local (no full-tree gather): each
leaf's owned shards land as ``shard-<k>`` datasets in sibling
``rank<r>.shard<j>.chk5`` files, and restore assembles exactly the regions
each target device needs via the ElasticLoader path — on all three
backends.  Runs in subprocesses with 16 forced host devices (device count
locks at jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import glob
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    sys.path.insert(0, "src")
    from repro.configs import get_arch
    from repro.core.context import CHK_DIFF, CheckpointConfig, CheckpointContext
    from repro.core.protect import flatten_named
    from repro.core.resharding import ElasticLoader, gather_tree, reshard_tree
    from repro.dist.sharding import param_shardings
    from repro.models.zoo import build_model

    ckpt_dir = sys.argv[1]
    backend = sys.argv[2]
    diff_link = backend == "fti"        # only fti has checkpoint kinds
    cfg = get_arch("tinyllama-1.1b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    # store under a 4x4 mesh, params sharded per the TP/DP rules
    mesh_a = jax.make_mesh((4, 4), ("data", "model"))
    params_a = reshard_tree(params, param_shardings(mesh_a, m.param_struct()))
    ctx = CheckpointContext(CheckpointConfig(
        dir=ckpt_dir, backend=backend, dedicated_thread=False,
        block_bytes=256))
    ctx.store(params_a, id=1, level=1)                       # FULL base
    embed2 = params_a["embed"].at[0, 0].set(-3.0)            # stays sharded
    params_a2 = dict(params_a, embed=embed2)
    ctx.store(params_a2, id=2, level=1,
              kind=CHK_DIFF if diff_link else "FULL")
    ctx.shutdown()
    want = gather_tree(params_a2)                            # global view

    # the store was shard-local: shard files sit next to the container,
    # and ElasticLoader assembles any region of a leaf straight from them
    ck1 = os.path.join(ckpt_dir, "node-local", "ckpts", "ckpt-1")
    shard_files = sorted(glob.glob(os.path.join(ck1, "rank0.shard*.chk5")))
    assert shard_files, os.listdir(ck1)
    loader = ElasticLoader(shard_files)
    assert "embed" in loader.names(), loader.names()
    g = loader.global_shape("embed")
    region = loader.read_region(
        "embed", (slice(1, g[0] // 2), slice(0, g[1])))
    base_embed = np.asarray(gather_tree({"e": params_a})["e"]["embed"])
    np.testing.assert_array_equal(region, base_embed[1:g[0] // 2])
    loader.close()

    # restart on two other mesh shapes: the restart template carries the
    # new mesh's shardings; load must land every leaf on them, bit-exact
    for shape in ((2, 8), (16, 1)):
        mesh_b = jax.make_mesh(shape, ("data", "model"))
        sh_b = param_shardings(mesh_b, m.param_struct())
        template = reshard_tree(jax.tree.map(jnp.zeros_like, params), sh_b)
        ctx2 = CheckpointContext(CheckpointConfig(
            dir=ckpt_dir, backend=backend, dedicated_thread=False,
            block_bytes=256))
        got = ctx2.load(template)
        assert ctx2.restarted, shape
        ctx2.shutdown()
        got_named = flatten_named(got)[0]
        sh_named = flatten_named(sh_b)[0]
        for path, arr in flatten_named(want)[0].items():
            np.testing.assert_array_equal(
                np.asarray(got_named[path]), arr, err_msg=f"{shape} {path}")
            assert got_named[path].sharding == sh_named[path], (shape, path)
    if diff_link:
        assert float(want["embed"][0, 0]) == -3.0   # the DIFF link replayed
    print("MESH-RESTART-OK")
""")


def test_store_one_mesh_restore_on_two_others(tmp_path):
    r = subprocess.run([sys.executable, "-c", SCRIPT,
                        str(tmp_path / "ck"), "fti"],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "MESH-RESTART-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_mesh_restore_from_shard_files_scr_veloc(tmp_path):
    """The same store → mesh-change restore cycle through the other two
    backends (file-mode SCR writes the identical sharded layout; VeloC
    exercises the shared pipeline pack)."""
    for backend in ("scr", "veloc"):
        r = subprocess.run([sys.executable, "-c", SCRIPT,
                            str(tmp_path / f"ck-{backend}"), backend],
                           capture_output=True, text=True, timeout=540,
                           cwd=".")
        assert "MESH-RESTART-OK" in r.stdout, \
            backend + ": " + r.stdout[-2000:] + r.stderr[-3000:]
