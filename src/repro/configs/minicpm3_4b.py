"""minicpm3-4b — dense with Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA: q_lora 768, kv_lora 256,
qk nope/rope 64/32, v_head 64 (MiniCPM3 HF config values).
"""
from repro.configs.base import ArchConfig, MLAConfig, register

ARCH = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab_size=73_448,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B; hf",
))
