"""Deterministic synthetic data pipeline with a checkpointable cursor.

The cursor (`DataState`) is part of `TrainState`, so OpenCHK checkpoints
capture the exact position in the stream — after restart, training consumes
the *same* batches it would have seen without the fault (exactly-once data
semantics; property-tested in tests/test_data.py).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class DataState(NamedTuple):
    seed: jnp.ndarray            # scalar uint32
    position: jnp.ndarray        # scalar int32 — batches consumed


def init_data_state(seed: int = 0) -> DataState:
    return DataState(jnp.uint32(seed), jnp.zeros((), jnp.int32))


def data_state_struct() -> DataState:
    return DataState(
        jax.ShapeDtypeStruct((), jnp.uint32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def next_batch(
    state: DataState,
    cfg: ArchConfig,
    global_batch: int,
    seq_len: int,
) -> Tuple[Dict[str, jnp.ndarray], DataState]:
    """Pure function (jit-safe): cursor → (batch, cursor+1)."""
    key = jax.random.fold_in(jax.random.PRNGKey(state.seed), state.position)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.encdec:
        k1, k2 = jax.random.split(key)
        out["frames"] = jax.random.normal(
            k1, (global_batch, seq_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
        toks = jax.random.randint(k2, (global_batch, seq_len + 1), 0,
                                  cfg.vocab_size, jnp.int32)
        out["tokens"], out["labels"] = toks[:, :-1], toks[:, 1:]
    elif cfg.frontend == "vision_stub":
        p = cfg.n_frontend_tokens
        k1, k2 = jax.random.split(key)
        out["patch_embeds"] = jax.random.normal(
            k1, (global_batch, p, cfg.d_model), jnp.dtype(cfg.compute_dtype)) * 0.02
        toks = jax.random.randint(k2, (global_batch, seq_len - p + 1), 0,
                                  cfg.vocab_size, jnp.int32)
        out["tokens"] = toks[:, :-1]
        # labels cover the full (patch+text) sequence; patch positions ignored
        pad = jnp.full((global_batch, p), -1, jnp.int32)
        out["labels"] = jnp.concatenate([pad, toks[:, 1:]], axis=1)
    else:
        toks = jax.random.randint(key, (global_batch, seq_len + 1), 0,
                                  cfg.vocab_size, jnp.int32)
        out["tokens"], out["labels"] = toks[:, :-1], toks[:, 1:]
    return out, DataState(state.seed, state.position + 1)


class SyntheticDataset:
    """Host-side iterator wrapper (examples / benchmarks)."""

    def __init__(self, cfg: ArchConfig, global_batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg, self.gb, self.sl = cfg, global_batch, seq_len
        self.state = init_data_state(seed)
        self._fn = jax.jit(
            lambda st: next_batch(st, cfg, global_batch, seq_len))

    def __iter__(self):
        return self

    def __next__(self):
        batch, self.state = self._fn(self.state)
        return batch

    # checkpointable cursor ------------------------------------------------ #
    def get_state(self) -> DataState:
        return self.state

    def set_state(self, st: DataState) -> None:
        self.state = st
