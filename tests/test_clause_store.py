"""Clause-driven stores end to end: per-subtree kind/compress/precision
clauses through store → crash → restart on every backend, the Pack-side
int8 compression tier (roundtrip-verified), the CHK5 format tier's clause
attrs, and mixed-kind (DIFF + FULL) checkpoints."""

import io
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import (
    CHK_DIFF,
    CHK_FULL,
    CheckpointConfig,
    CheckpointContext,
    Protect,
)
from repro.core.formats import CHK5Reader, CHK5CorruptionError, CHK5Writer
from repro.core.tiers import decode_leaf, pack_named, unpack_named
from repro.tools.chkls import main as chkls_main


def _int8_exact(n, seed=0, scale=0.25):
    """Values exactly representable under per-block int8 max-abs
    quantization: integers in [-127, 127] times a power-of-two scale,
    with ±127·scale present in every 1024-block so the recovered scale is
    exact."""
    rng = np.random.default_rng(seed)
    v = rng.integers(-126, 127, size=n).astype(np.float32)
    v[::1024] = 127.0
    return v * np.float32(scale)


def _mixed_state(n=4096):
    return {
        "params": {"w": jnp.asarray(_int8_exact(n)),
                   "b": jnp.asarray(_int8_exact(64, seed=1))},
        "opt": {"m": jnp.arange(512.0), "v": jnp.ones(512) * 0.5},
        "step": jnp.int32(3),
    }


def _protects():
    return (Protect("params/**", kind=CHK_DIFF, compress="int8"),
            Protect("opt/**", kind=CHK_FULL),
            Protect("step"))


def _ckpt_file(root_dir, ckpt_id):
    p = os.path.join(root_dir, "node-local", "ckpts", f"ckpt-{ckpt_id}",
                     "rank0.chk5")
    assert os.path.exists(p), p
    return p


@pytest.mark.parametrize("backend", ["fti", "scr", "veloc"])
def test_clause_store_crash_restart_bit_exact(tmp_path, backend):
    """The acceptance scenario: one store with DIFF+int8 params and FULL
    opt round-trips bit-exact through store → crash → restart on all three
    backends, and chkls --json shows the int8 codec attr on params
    datasets only."""
    d = str(tmp_path / backend)
    state = _mixed_state()
    ctx = CheckpointContext(CheckpointConfig(
        dir=d, backend=backend, dedicated_thread=False))
    ctx.protect(*_protects())
    rep = ctx.store(state, id=1, level=1)
    assert rep is not None
    ctx.shutdown()                                  # "crash" boundary

    ctx2 = CheckpointContext(CheckpointConfig(
        dir=d, backend=backend, dedicated_thread=False))
    ctx2.protect(*_protects())
    tmpl = {"params": {"w": jnp.zeros(4096), "b": jnp.zeros(64)},
            "opt": {"m": jnp.zeros(512), "v": jnp.zeros(512)},
            "step": jnp.int32(0)}
    got = ctx2.load(tmpl)
    assert ctx2.restarted
    for k in ("w", "b"):
        np.testing.assert_array_equal(np.asarray(got["params"][k]),
                                      np.asarray(state["params"][k]))
    for k in ("m", "v"):
        np.testing.assert_array_equal(np.asarray(got["opt"][k]),
                                      np.asarray(state["opt"][k]))
    assert int(got["step"]) == 3
    ctx2.shutdown()

    # container inventory: codec attr on params datasets ONLY
    f = _ckpt_file(d, 1)
    import contextlib
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert chkls_main([f, "--json"]) == 0
    inv = json.loads(buf.getvalue())
    by_name = {ds["name"]: ds for ds in inv["datasets"]}
    for name, ds in by_name.items():
        if name.startswith("data/params/"):
            assert ds["attrs"].get("codec") == "int8", name
            assert "roundtrip_crc32" in ds["attrs"], name
        elif name.startswith("data/"):
            assert "codec" not in ds["attrs"], name
    # the int8 payload actually shrinks the params datasets (~4x + scales)
    w = by_name["data/params/w"]
    assert w["nbytes"] < 4096 * 4 / 3


def test_compressed_payload_smaller_and_attrs_complete(tmp_path):
    d = str(tmp_path / "sz")
    n = 64 * 1024
    state = {"params": {"w": jnp.asarray(_int8_exact(n))}}
    ctx = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                             dedicated_thread=False))
    ctx.protect(Protect("params/**", compress="int8"))
    rep_c = ctx.store(state, id=1, level=1)
    ctx.protect(Protect("params/**"))
    rep_u = ctx.store(state, id=2, level=1)
    assert rep_c.bytes_payload < rep_u.bytes_payload / 3
    rd = CHK5Reader(_ckpt_file(d, 1))
    attrs = rd.info("data/params/w")["attrs"]
    assert attrs["codec"] == "int8" and attrs["kind"] == CHK_FULL
    assert attrs["selector"] == "params/**"
    assert attrs["codec_error"] == 0.0          # representable values
    assert rd.info("codecaux/params/w/scale")["shape"] == [n // 1024]
    rd.close()
    ctx.shutdown()


def test_int8_fallbacks_nonfloat_and_max_error(tmp_path):
    """Non-float leaves and payloads above the max_error bound store
    uncompressed with a codec_fallback attr — and restore exactly."""
    d = str(tmp_path / "fb")
    state = {"step": jnp.int32(9), "noisy": jnp.asarray(
        np.random.default_rng(3).normal(size=4096).astype(np.float32))}
    ctx = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                             dedicated_thread=False))
    ctx.protect(Protect("**", compress="int8", max_error=1e-9))
    ctx.store(state, id=1, level=1)
    ctx.shutdown()

    rd = CHK5Reader(_ckpt_file(d, 1))
    assert "int8: non-float" in rd.info("data/step")["attrs"]["codec_fallback"]
    assert "max_error" in rd.info("data/noisy")["attrs"]["codec_fallback"]
    assert "codec" not in rd.info("data/noisy")["attrs"]
    rd.close()

    ctx2 = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                              dedicated_thread=False))
    got = ctx2.load({"step": jnp.int32(0), "noisy": jnp.zeros(4096)})
    assert int(got["step"]) == 9
    np.testing.assert_array_equal(np.asarray(got["noisy"]),
                                  np.asarray(state["noisy"]))
    ctx2.shutdown()


def test_precision_clause_casts_and_restores_template_dtype(tmp_path):
    d = str(tmp_path / "prec")
    w = np.asarray([1.0, 1.0 + 2 ** -10, -3.25], np.float32)
    ctx = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                             dedicated_thread=False))
    ctx.protect(Protect("w", format="chk5", precision="bf16"))
    ctx.store({"w": jnp.asarray(w)}, id=1, level=1)
    ctx.shutdown()

    rd = CHK5Reader(_ckpt_file(d, 1))
    info = rd.info("data/w")
    assert info["dtype"] == "bfloat16"          # stored at clause precision
    assert info["attrs"]["precision"] == "bf16"
    assert info["attrs"]["format"] == "chk5"
    assert info["attrs"]["dtype"] == "<f4"      # original, for cast-back
    rd.close()

    ctx2 = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                              dedicated_thread=False))
    got = ctx2.load({"w": jnp.zeros(3)})
    arr = np.asarray(got["w"])
    assert arr.dtype == np.float32              # template dtype restored
    import ml_dtypes
    np.testing.assert_array_equal(
        arr, w.astype(ml_dtypes.bfloat16).astype(np.float32))
    ctx2.shutdown()


def test_precision_composes_with_int8_and_already_at_target(tmp_path):
    """precision + compress quantizes the precision-limited values (attr
    is honest); precision equal to the leaf dtype keeps the attr with no
    fallback; a custom pack chain without a catch-all fails at pack."""
    import ml_dtypes
    p = str(tmp_path / "pc.chk5")
    w = _int8_exact(2048)
    with CHK5Writer(p) as wtr:
        pack_named(wtr, {"w": w, "z": w},
                   {"w": Protect("w", compress="int8", precision="bf16"),
                    "z": Protect("z", precision="f32")})
    rd = CHK5Reader(p)
    aw = rd.info("data/w")["attrs"]
    assert aw["codec"] == "int8" and aw["precision"] == "bf16"
    assert aw["dtype"] == "<f4"                  # restore target = original
    got = decode_leaf(rd, "data/w")
    assert got.dtype == np.float32
    np.testing.assert_array_equal(                # bf16-limited then int8
        got, w.astype(ml_dtypes.bfloat16).astype(np.float32))
    az = rd.info("data/z")["attrs"]
    assert az["precision"] == "f32" and "precision_fallback" not in az
    rd.close()
    # a pack chain with no catch-all tier must fail loudly at pack time
    from repro.core.tiers import Int8CompressTier
    with pytest.raises(RuntimeError, match="no pack tier"):
        with CHK5Writer(str(tmp_path / "bad.chk5")) as wtr:
            pack_named(wtr, {"plain": w}, {"plain": None},
                       pack_tiers=[Int8CompressTier()])


def test_mixed_kind_diff_chain_replays(tmp_path):
    """Store 2 carries a real params DIFF link + FULL opt in one container;
    restore replays the delta onto the compressed-but-exact base."""
    d = str(tmp_path / "mx")
    state = _mixed_state()
    ctx = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                             dedicated_thread=False,
                                             block_bytes=256))
    ctx.protect(*_protects())
    rep1 = ctx.store(state, id=1, level=1)
    assert rep1.kind == CHK_FULL and rep1.promoted_full   # no base yet
    w2 = state["params"]["w"].at[5].set(-5.0)
    state2 = {"params": {"w": w2, "b": state["params"]["b"]},
              "opt": {"m": jnp.arange(512.0) * 2, "v": state["opt"]["v"]},
              "step": jnp.int32(4)}
    rep2 = ctx.store(state2, id=2, level=1)
    assert rep2.kind == CHK_DIFF and rep2.dirty_ratio < 0.2
    ctx.shutdown()

    rd = CHK5Reader(_ckpt_file(d, 2))
    names = rd.datasets()
    assert any(n.startswith("delta/params/w/") for n in names)
    assert "data/opt/m" in names and "data/step" in names
    assert rd.attrs("")["kind"] == CHK_DIFF     # mixed container walks back
    rd.close()

    ctx2 = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                              dedicated_thread=False,
                                              block_bytes=256))
    got = ctx2.load({"params": {"w": jnp.zeros(4096), "b": jnp.zeros(64)},
                     "opt": {"m": jnp.zeros(512), "v": jnp.zeros(512)},
                     "step": jnp.int32(0)})
    assert float(got["params"]["w"][5]) == -5.0
    np.testing.assert_array_equal(np.asarray(got["params"]["w"][6:]),
                                  np.asarray(state["params"]["w"][6:]))
    np.testing.assert_array_equal(np.asarray(got["opt"]["m"]),
                                  np.arange(512.0) * 2)
    assert int(got["step"]) == 4
    ctx2.shutdown()


def test_store_level_kind_still_uniform_when_clauseless(tmp_path):
    """store(kind=CHK_DIFF) without kind clauses keeps the old whole-tree
    semantics (deprecation-compatible)."""
    d = str(tmp_path / "uni")
    ctx = CheckpointContext(CheckpointConfig(dir=d, backend="fti",
                                             dedicated_thread=False,
                                             block_bytes=256))
    x = jnp.arange(4096.0)
    ctx.store({"x": x}, id=1, level=1)
    rep = ctx.store({"x": x.at[0].set(-1.0)}, id=2, level=1, kind=CHK_DIFF)
    assert rep.kind == CHK_DIFF and not rep.promoted_full
    ctx.shutdown()


def test_decode_leaf_verifies_roundtrip(monkeypatch, tmp_path):
    """Load-side verification: a dequantization that does not reproduce
    the pack-time payload bit-for-bit is refused."""
    p = str(tmp_path / "v.chk5")
    w = _int8_exact(2048)
    with CHK5Writer(p) as wtr:
        pack_named(wtr, {"w": w}, {"w": Protect("w", compress="int8")})
    rd = CHK5Reader(p)
    np.testing.assert_array_equal(decode_leaf(rd, "data/w"), w)  # honest path
    import repro.dist.compression as comp
    real = comp.dequantize_int8_np
    monkeypatch.setattr(comp, "dequantize_int8_np",
                        lambda q, s, shape: real(q, s, shape) + 1.0)
    with pytest.raises(CHK5CorruptionError, match="roundtrip"):
        decode_leaf(rd, "data/w")
    rd.close()


def test_unpack_named_decodes_all_sections(tmp_path):
    p = str(tmp_path / "u.chk5")
    named = {"a": _int8_exact(1024), "b": np.arange(5, dtype=np.int32)}
    with CHK5Writer(p) as w:
        pack_named(w, named, {"a": Protect("a", compress="int8"), "b": None})
    rd = CHK5Reader(p)
    out = unpack_named(rd)
    assert set(out) == {"a", "b"}
    np.testing.assert_array_equal(out["a"], named["a"])
    np.testing.assert_array_equal(out["b"], named["b"])
    rd.close()
