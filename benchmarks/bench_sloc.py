"""Tables 4–6 analogue: SLOC of checkpoint/restart code, per backend.

CR-specific lines in the four heat-2d variants are tagged ``# [CR]``; the
ratio OpenCHK/native reproduces the paper's programmability result
(paper averages: FTI 0.289, SCR 0.056, VeloC 0.359 across their app sets —
one benchmark here, so expect the same ordering, not the exact values).
"""
from __future__ import annotations

import os
from typing import Dict

APPS = {
    "openchk": "benchmarks/apps/heat2d_openchk.py",
    "fti": "benchmarks/apps/heat2d_fti.py",
    "scr": "benchmarks/apps/heat2d_scr.py",
    "veloc": "benchmarks/apps/heat2d_veloc.py",
}


def cr_sloc(path: str) -> int:
    n = 0
    for line in open(path):
        if "[CR]" in line and not line.strip().startswith('"'):
            n += 1
    return n


def run() -> Dict[str, float]:
    base = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    counts = {k: cr_sloc(os.path.join(base, p)) for k, p in APPS.items()}
    out = {f"sloc_{k}": float(v) for k, v in counts.items()}
    for k in ("fti", "scr", "veloc"):
        out[f"ratio_openchk_over_{k}"] = counts["openchk"] / counts[k]
    return out


def rows():
    r = run()
    return [("sloc/" + k, 0.0, v) for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, _, v in rows():
        print(f"{name},{v}")
