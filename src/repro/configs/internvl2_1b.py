"""internvl2-1b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821; hf].

Backbone only per the assignment: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. The InternViT frontend is a stub: ``input_specs`` supplies
patch embeddings (B, 256, d_model) prepended to text tokens; total sequence
length equals the shape's seq_len.
"""
from repro.configs.base import ArchConfig, register

ARCH = register(ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    frontend="vision_stub",
    n_frontend_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2404.16821; hf",
))
