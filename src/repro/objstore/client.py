"""Object-store clients — the key→blob surface the L4 tier talks to.

The abstraction is S3-shaped (put/get/list/delete plus multipart uploads
and conditional writes) so a real S3/GCS client can slot in behind the
same interface later; the two shipped backends are

    ``LocalFSObjectStore``   keys as files under one root directory — the
                             "bucket on a parallel file system" analogue,
                             durable across processes (the restore tests'
                             crash windows run against it)
    ``MemoryObjectStore``    a dict, for unit tests and fault injection

Semantics every backend guarantees:

- **atomic put**: a reader never observes a torn object (LocalFS stages
  to a ``.tmp-`` sibling and ``os.replace``s it in);
- **conditional put**: ``if_match=<etag>`` fails with
  :class:`PreconditionFailed` unless the stored object's etag matches
  (compare-and-swap — the catalog's epoch guard builds on this), and
  ``if_none_match=True`` fails if the key exists at all (create-only);
- **etags are content hashes** (sha256 hex), so CAS survives process
  restarts — no server-side version counter to lose;
- **multipart/resumable put**: ``create_multipart`` → ``upload_part``
  (idempotent per part number; ``list_parts`` tells a restarted uploader
  which parts already landed) → ``complete_multipart`` assembles the
  object atomically, ``abort_multipart`` discards the staging state.
  Nothing is visible under the key until complete.

A real cloud client (``s3:...``) is deliberately *gated*, not faked:
``make_object_store`` raises a clear error naming the missing dependency,
mirroring how ``Protect(format="hdf5")`` gates on h5py.
"""
from __future__ import annotations

import abc
import hashlib
import os
import shutil
import threading
import uuid
from typing import Dict, List, Optional, Tuple

from repro.chaos import inject as chaos


def content_etag(data: bytes) -> str:
    """Etag = sha256 of content (stable across processes and backends)."""
    return hashlib.sha256(data).hexdigest()


class ObjectStoreError(RuntimeError):
    pass


class PreconditionFailed(ObjectStoreError):
    """A conditional put (``if_match`` / ``if_none_match``) lost the race."""


class ObjectStore(abc.ABC):
    """Key→blob store with CAS puts and multipart uploads."""

    # -- whole-object ops ---------------------------------------------- #

    @abc.abstractmethod
    def put(self, key: str, data: bytes, *, if_match: Optional[str] = None,
            if_none_match: bool = False) -> str:
        """Store ``data`` under ``key``; returns the new etag.

        ``if_match``: only overwrite when the current etag equals it
        (``None`` current → fail).  ``if_none_match``: only create —
        fail when the key exists.  Both raise :class:`PreconditionFailed`."""

    @abc.abstractmethod
    def get(self, key: str) -> bytes:
        """Fetch; raises :class:`ObjectStoreError` when absent."""

    @abc.abstractmethod
    def get_with_etag(self, key: str
                      ) -> Tuple[Optional[bytes], Optional[str]]:
        """Fetch data+etag, or ``(None, None)`` when absent (the CAS read)."""

    @abc.abstractmethod
    def exists(self, key: str) -> bool: ...

    @abc.abstractmethod
    def list(self, prefix: str = "") -> List[str]:
        """All keys under ``prefix``, sorted."""

    @abc.abstractmethod
    def delete(self, key: str) -> None:
        """Idempotent delete (absent key is not an error)."""

    # -- multipart / resumable put ------------------------------------- #

    @abc.abstractmethod
    def create_multipart(self, key: str) -> str:
        """Open a multipart upload for ``key`` → upload id."""

    @abc.abstractmethod
    def upload_part(self, key: str, upload_id: str, part_number: int,
                    data: bytes) -> str:
        """Stage one part (1-based part numbers; re-upload overwrites)."""

    @abc.abstractmethod
    def list_parts(self, key: str, upload_id: str) -> List[int]:
        """Part numbers already staged — the resume point after a crash."""

    @abc.abstractmethod
    def complete_multipart(self, key: str, upload_id: str) -> str:
        """Assemble staged parts (in part-number order) into ``key``
        atomically → etag.  The staging state is discarded."""

    @abc.abstractmethod
    def abort_multipart(self, key: str, upload_id: str) -> None: ...


def _check_key(key: str) -> str:
    if not key or key.startswith("/") or ".." in key.split("/"):
        raise ObjectStoreError(f"invalid object key {key!r}")
    return key


class MemoryObjectStore(ObjectStore):
    """In-memory backend for tests (and fault-injection wrappers)."""

    def __init__(self):
        self._objects: Dict[str, bytes] = {}
        self._mpu: Dict[str, Dict[int, bytes]] = {}
        self._lock = threading.RLock()

    def put(self, key, data, *, if_match=None, if_none_match=False):
        _check_key(key)
        data = chaos.fire(chaos.SITES.OBJSTORE_PUT, exc=ObjectStoreError,
                          data=bytes(data), key=key).data
        with self._lock:
            cur = self._objects.get(key)
            self._check_cond(key, cur, if_match, if_none_match)
            self._objects[key] = bytes(data)
            return content_etag(data)

    @staticmethod
    def _check_cond(key, cur, if_match, if_none_match):
        if if_none_match and cur is not None:
            raise PreconditionFailed(f"{key}: already exists")
        if if_match is not None and (
                cur is None or content_etag(cur) != if_match):
            raise PreconditionFailed(f"{key}: etag mismatch")

    def get(self, key):
        with self._lock:
            if key not in self._objects:
                raise ObjectStoreError(f"no such object: {key}")
            blob = self._objects[key]
        return chaos.fire(chaos.SITES.OBJSTORE_GET, exc=ObjectStoreError,
                          data=blob, key=key).data

    def get_with_etag(self, key):
        with self._lock:
            cur = self._objects.get(key)
            return (None, None) if cur is None else (cur, content_etag(cur))

    def exists(self, key):
        with self._lock:
            return key in self._objects

    def list(self, prefix=""):
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key):
        # chaos site: GC sweeps die here mid-delete; "skip" models a
        # delete that silently never lands (orphaned chunk)
        if chaos.fire(chaos.SITES.OBJSTORE_DELETE, exc=ObjectStoreError,
                      key=key).skipped:
            return
        with self._lock:
            self._objects.pop(key, None)

    def create_multipart(self, key):
        _check_key(key)
        uid = uuid.uuid4().hex
        with self._lock:
            self._mpu[uid] = {}
        return uid

    def upload_part(self, key, upload_id, part_number, data):
        with self._lock:
            self._mpu[upload_id][int(part_number)] = bytes(data)
        return content_etag(data)

    def list_parts(self, key, upload_id):
        with self._lock:
            return sorted(self._mpu.get(upload_id, {}))

    def complete_multipart(self, key, upload_id):
        with self._lock:
            parts = self._mpu.pop(upload_id, None)
            if parts is None:
                raise ObjectStoreError(f"no such upload: {upload_id}")
            blob = b"".join(parts[n] for n in sorted(parts))
            self._objects[key] = blob
            return content_etag(blob)

    def abort_multipart(self, key, upload_id):
        with self._lock:
            self._mpu.pop(upload_id, None)


class LocalFSObjectStore(ObjectStore):
    """Keys as files under one root directory.

    Atomicity comes from ``os.replace`` of a staged ``.tmp-`` sibling;
    conditional puts serialize read-compare-write under a process lock
    plus an ``fcntl`` file lock on ``<root>/.cas.lock``, so CAS holds
    across the threads of one process *and* across processes sharing the
    root (the multi-rank catalog merge).  Internal state (multipart
    staging, the lock file) lives under dot-prefixed names that ``list``
    never reports."""

    _MPU_DIR = ".mpu"
    _LOCK_FILE = ".cas.lock"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.RLock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, _check_key(key))

    def _write_atomic(self, path: str, data: bytes) -> None:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = os.path.join(os.path.dirname(path),
                           f".tmp-{uuid.uuid4().hex}-{os.path.basename(path)}")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    class _FileLock:
        def __init__(self, path: str):
            self._path = path

        def __enter__(self):
            self._fd = os.open(self._path, os.O_CREAT | os.O_RDWR)
            try:
                import fcntl
                fcntl.flock(self._fd, fcntl.LOCK_EX)
            except ImportError:          # pragma: no cover (non-posix)
                pass
            return self

        def __exit__(self, *a):
            try:
                import fcntl
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            except ImportError:          # pragma: no cover
                pass
            os.close(self._fd)

    def _cas_lock(self):
        return self._FileLock(os.path.join(self.root, self._LOCK_FILE))

    def put(self, key, data, *, if_match=None, if_none_match=False):
        path = self._path(key)
        data = chaos.fire(chaos.SITES.OBJSTORE_PUT, exc=ObjectStoreError,
                          data=bytes(data), key=key).data
        if if_match is None and not if_none_match:
            self._write_atomic(path, data)
            return content_etag(data)
        with self._lock, self._cas_lock():
            cur = None
            if os.path.exists(path):
                with open(path, "rb") as f:
                    cur = f.read()
            MemoryObjectStore._check_cond(key, cur, if_match, if_none_match)
            self._write_atomic(path, data)
            return content_etag(data)

    def get(self, key):
        try:
            with open(self._path(key), "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            raise ObjectStoreError(f"no such object: {key}") from None
        return chaos.fire(chaos.SITES.OBJSTORE_GET, exc=ObjectStoreError,
                          data=blob, key=key).data

    def get_with_etag(self, key):
        try:
            with open(self._path(key), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return None, None
        return data, content_etag(data)

    def exists(self, key):
        return os.path.isfile(self._path(key))

    def list(self, prefix=""):
        out = []
        for dirpath, dirnames, filenames in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            dirnames[:] = [d for d in dirnames if not d.startswith(".")]
            for name in filenames:
                if name.startswith("."):
                    continue             # lock file / staged tmp writes
                key = name if rel == "." else f"{rel}/{name}".replace(
                    os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def delete(self, key):
        if chaos.fire(chaos.SITES.OBJSTORE_DELETE, exc=ObjectStoreError,
                      key=key).skipped:
            return
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    # -- multipart ------------------------------------------------------ #

    def _mpu_dir(self, upload_id: str) -> str:
        return os.path.join(self.root, self._MPU_DIR, upload_id)

    def create_multipart(self, key):
        _check_key(key)
        uid = uuid.uuid4().hex
        os.makedirs(self._mpu_dir(uid), exist_ok=True)
        with open(os.path.join(self._mpu_dir(uid), "key"), "w") as f:
            f.write(key)
        return uid

    def upload_part(self, key, upload_id, part_number, data):
        d = self._mpu_dir(upload_id)
        if not os.path.isdir(d):
            raise ObjectStoreError(f"no such upload: {upload_id}")
        self._write_atomic(os.path.join(d, f"part-{int(part_number):08d}"),
                           bytes(data))
        return content_etag(data)

    def list_parts(self, key, upload_id):
        d = self._mpu_dir(upload_id)
        if not os.path.isdir(d):
            return []
        return sorted(int(n[len("part-"):]) for n in os.listdir(d)
                      if n.startswith("part-"))

    def complete_multipart(self, key, upload_id):
        d = self._mpu_dir(upload_id)
        parts = self.list_parts(key, upload_id)
        if not os.path.isdir(d) or not parts:
            raise ObjectStoreError(f"no parts staged for upload {upload_id}")
        blob = b"".join(
            open(os.path.join(d, f"part-{n:08d}"), "rb").read()
            for n in parts)
        self._write_atomic(self._path(key), blob)
        shutil.rmtree(d, ignore_errors=True)
        return content_etag(blob)

    def abort_multipart(self, key, upload_id):
        shutil.rmtree(self._mpu_dir(upload_id), ignore_errors=True)


def make_object_store(url: str) -> ObjectStore:
    """``file:<dir>`` → :class:`LocalFSObjectStore`; ``mem:`` → a fresh
    :class:`MemoryObjectStore`; ``s3:``/``gs:`` are gated on their missing
    client libraries (clear error, not a fake)."""
    if url.startswith("file:"):
        return LocalFSObjectStore(url[len("file:"):])
    if url.startswith("mem:"):
        return MemoryObjectStore()
    if url.startswith(("s3:", "gs:")):
        raise ObjectStoreError(
            f"object store {url!r} needs a cloud client (boto3 / "
            f"google-cloud-storage), which this environment does not ship; "
            f"use file:<dir> — the interface is S3-shaped so a real client "
            f"can slot in behind it")
    # a bare path is a local root
    if url.startswith(("/", "./")) or os.path.isdir(url):
        return LocalFSObjectStore(url)
    raise ObjectStoreError(f"unrecognized object-store url {url!r}")
