"""Architecture & shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig` registered in
``ARCH_REGISTRY`` and selectable via ``--arch <id>`` in the launchers.
Each arch carries its own applicable shape set (the assignment's 4 shapes,
minus ``long_500k`` for pure full-attention archs — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# --------------------------------------------------------------------------- #
# Shapes
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape × step-kind) cell of the evaluation grid."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int       # train/prefill: tokens processed; decode: KV-cache length
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPE_BY_NAME = {s.name: s for s in ALL_SHAPES}


# --------------------------------------------------------------------------- #
# Sub-configs
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every_k_layers: int = 1        # MoE MLP on layers where (i % every_k)==0
    capacity_factor: float = 1.25
    group_size: int = 256          # tokens per dispatch group (GShard grouping)
    dispatch: str = "einsum"       # "einsum" (one-hot, MXU) | "scatter" (sort)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"            # "mamba" (SSD chunked) | "rwkv6"
    d_state: int = 16
    head_dim: int = 64             # SSD head size / rwkv head size
    expand: int = 2                # mamba inner expansion
    conv_width: int = 4            # mamba short conv
    chunk: int = 64                # chunked-scan block length


# --------------------------------------------------------------------------- #
# ArchConfig
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    d_head: Optional[int] = None   # default: d_model // n_heads
    attn_kind: str = "gqa"         # gqa | mla | none
    sliding_window: Optional[int] = None
    qkv_bias: bool = False         # qwen-style attention biases
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: repeating layer pattern, e.g. jamba ("attn","mamba"×7)
    hybrid_pattern: Optional[Tuple[str, ...]] = None

    encdec: bool = False           # whisper-style encoder-decoder
    frontend: Optional[str] = None  # "audio_stub" | "vision_stub"
    n_frontend_tokens: int = 0     # patch/frame embeddings prepended (vlm)

    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm_eps: float = 1e-5
    act: str = "silu"              # mlp activation (gated)
    tie_embeddings: bool = False

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # citation tag from the assignment (source; verification tier)
    source: str = ""

    # ----------------------------------------------------------------- #

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is feasible (SSM/hybrid/SWA)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        """Shape cells applicable to this arch (DESIGN.md §5)."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            out.append(LONG_500K)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[ShapeSpec, ...]:
        return tuple(s for s in ALL_SHAPES if s not in self.shapes())

    # approximate parameter count (for 6ND model-flops accounting) --------- #

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; ``active_only`` counts top-k experts only."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla
                assert m is not None
                qk = m.qk_nope_dim + m.qk_rope_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                p += d * (m.kv_lora_rank + m.qk_rope_dim)
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
                return p
            hd = self.head_dim
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp_params(moe_layer: bool) -> int:
            dense = 3 * d * f  # gated mlp
            if not moe_layer or self.moe is None:
                return dense
            e = self.moe.top_k if active_only else self.moe.n_experts
            return e * 3 * d * f + d * self.moe.n_experts  # + router

        def ssm_params() -> int:
            s = self.ssm
            assert s is not None
            if s.kind == "rwkv6":
                # time-mix (r,k,v,g,o: 5·d²) + channel-mix (2·d·f + d²)
                return 6 * d * d + 2 * d * self.d_ff
            di = s.expand * d
            return d * 2 * di + di * s.conv_width + 2 * di * s.d_state + di * d

        total = emb
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            moe_layer = self.moe is not None and (i % self.moe.every_k_layers == 0)
            if kind == "attn":
                total += attn_params() + mlp_params(moe_layer)
            elif self.family == "ssm":
                total += ssm_params()        # rwkv: channel-mix is the FFN
            else:                            # hybrid mamba layers keep an MLP
                total += ssm_params() + mlp_params(moe_layer)
        if self.encdec:  # decoder stack w/ cross attention, same depth
            total += self.n_layers * (2 * attn_params() + mlp_params(False))
        return total

    def flops_param_count(self) -> int:
        """Active, non-input-embedding params — the N of MODEL_FLOPS=6·N·D.
        (lm_head matmul counted; input-embedding gather is not a matmul)."""
        n = self.param_count(active_only=True)
        if not self.tie_embeddings:
            n -= self.vocab_size * self.d_model
        return n

    def model_flops(self, shape: "ShapeSpec") -> float:
        """MODEL_FLOPS per executed step for the roofline's useful-work
        numerator: 6·N·D training, 2·N·D inference-forward (D = tokens)."""
        n = self.flops_param_count()
        if shape.kind == "train":
            return 6.0 * n * shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            return 2.0 * n * shape.global_batch * shape.seq_len
        return 2.0 * n * shape.global_batch        # decode: one token

    def layer_kind(self, i: int) -> str:
        if self.hybrid_pattern is None:
            return "ssm" if self.family == "ssm" else "attn"
        return self.hybrid_pattern[i % len(self.hybrid_pattern)]

    # reduced config for CPU smoke tests ---------------------------------- #

    def reduced(self) -> "ArchConfig":
        """Small same-family config: one fwd/train step runs on CPU."""
        kw = {}
        n_layers = 2
        if self.hybrid_pattern is not None:
            kw["hybrid_pattern"] = ("attn", "mamba")
            n_layers = 2
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=4, top_k=min(2, moe.top_k), group_size=32,
                capacity_factor=2.0)
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, d_state=8, head_dim=16, chunk=16)
        mla = self.mla
        if mla is not None:
            mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                            qk_rope_dim=8, v_head_dim=16)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab_size=512,
            sliding_window=32 if self.sliding_window else None,
            moe=moe,
            ssm=ssm,
            mla=mla,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            **kw,
        )


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

ARCH_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCH_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}") from None
