"""VeloC-like backend: memory-mode protect + asynchronous persist.

Mirrors VeloC's API: ``mem_protect / checkpoint(name, version) /
checkpoint_wait / restart_test / restart``. Async by design (the paper's
§4.2.2 is supported here and in FTI); **no checkpoint kinds** — a CHK_DIFF
request falls back to FULL and is counted in stats (paper §3: "VeloC is
still missing some features ... e.g. different checkpointing types").
Two tiers: scratch (node-local, level ≤3) and persistent (level 4); both
are the shared pipeline's tier stacks — VeloC adds no placement code.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.backends.base import Backend
from repro.core.comm import Communicator
from repro.core.storage import CHK_FULL, StorageConfig, StoreRequest

VELOC_SUCCESS = 0
VELOC_FAILURE = -1


class VeloCBackend(Backend):
    name = "veloc"
    supports_diff = False
    supports_dedicated_thread = True
    supports_incremental = True
    max_level = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 mode: str = "memory",
                 dedicated_thread: bool = True):
        super().__init__(cfg, comm, dedicated_thread=dedicated_thread)
        assert mode in ("memory", "file")
        self.mode = mode
        self._protected: Dict[int, Tuple[str, np.ndarray]] = {}

    # ----------------------- native VeloC-style API -------------------- #

    def mem_protect(self, pid: int, arr, name: str = "region") -> int:
        self._protected[pid] = (name, arr)
        return VELOC_SUCCESS

    def checkpoint(self, name: str, version: int) -> int:
        named = {f"p{pid}/{n}": np.asarray(a)
                 for pid, (n, a) in self._protected.items()}
        level = 1 if self.mode == "memory" else 4
        self.tcl_store(StoreRequest(named=named, ckpt_id=version,
                                    level=level, kind=CHK_FULL))
        return VELOC_SUCCESS

    def checkpoint_wait(self) -> int:
        self.tcl_wait()
        return VELOC_SUCCESS

    def restart_test(self, name: str, version: int = 0) -> int:
        self.checkpoint_wait()
        ids = self.engine.available_ids()
        return ids[-1][0] if ids else VELOC_FAILURE

    def restart(self, name: str, version: int) -> int:
        got = self.engine.load_latest()
        if got is None:
            return VELOC_FAILURE
        named, _ = got
        for pid, (n, _a) in self._protected.items():
            key = f"p{pid}/{n}"
            if key not in named:
                return VELOC_FAILURE
            self._protected[pid] = (n, named[key])
        self.stats["loads"] += 1
        return VELOC_SUCCESS

    def recovered(self, pid: int) -> np.ndarray:
        return self._protected[pid][1]
