"""``repro.objstore.inspect`` — the typed catalog-inspection API.

One read of ``catalog.json`` becomes a :class:`CatalogView`: immutable
:class:`EntryInfo`/:class:`FileInfo` records (id, kind, level, epoch,
file set, chunk stats, chunk digests) instead of the raw JSON dicts the
catalog stores.  Every consumer of catalog *contents* goes through this
surface — the ``chkls`` CLI, the CI-lane inventory assertions, and the
serving control plane (``repro.serve.deploy``) — so nothing outside
``repro.objstore`` parses ``catalog.json`` by hand.

The serving-side primitive is :meth:`CatalogView.diff`: the chunk-level
delta between two entries (digests the target references that the base
does not), which is exactly what a deploy subscriber must *pull* to move
a replica from one published checkpoint to the next — content addressing
makes "what changed" a set difference, no byte comparison involved.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.objstore.catalog import Catalog
from repro.objstore.chunks import FileEntry
from repro.objstore.client import ObjectStore


@dataclass(frozen=True)
class FileInfo:
    """One file of a published entry: its size, chunking mode, and the
    ordered ``(digest, offset, nbytes)`` chunk rows that reassemble it."""
    name: str
    size: int
    mode: str
    chunks: Tuple[Tuple[str, int, int], ...]

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def chunk_sizes(self) -> List[int]:
        return [n for _h, _o, n in self.chunks]

    @property
    def digests(self) -> List[str]:
        return [h for h, _o, _n in self.chunks]

    def file_entry(self) -> FileEntry:
        """The fetch-layer :class:`~repro.objstore.chunks.FileEntry` —
        what ``fetch_file``/``fetch_file_delta`` reassemble from."""
        return FileEntry(name=self.name, size=self.size,
                         chunks=list(self.chunks), mode=self.mode)

    @staticmethod
    def from_entry(fe: FileEntry) -> "FileInfo":
        return FileInfo(name=fe.name, size=int(fe.size),
                        mode=fe.mode, chunks=tuple(fe.chunks))


def _chunk_hist(sizes: List[int]) -> Dict[str, int]:
    """Power-of-two size histogram: bucket ``2^k`` counts chunks with
    ``2^(k-1) < nbytes <= 2^k`` — the CDC spread at a glance."""
    hist: Dict[str, int] = {}
    for n in sizes:
        k = max(int(n) - 1, 0).bit_length()
        label = f"2^{k}"
        hist[label] = hist.get(label, 0) + 1
    return dict(sorted(hist.items(), key=lambda kv: int(kv[0][2:])))


@dataclass(frozen=True)
class EntryInfo:
    """One published checkpoint: identity, the manifest-derived
    kind/level, the file set, and chunk-level statistics."""
    id: int
    pinned: bool
    kind: Optional[str]
    level: Optional[int]
    wall_time: Optional[float]
    manifest: Mapping[str, Any]
    files: Tuple[FileInfo, ...]
    epoch: int = 0                     # catalog epoch this view was read at

    # -- derived -------------------------------------------------------- #

    def file(self, name: str) -> Optional[FileInfo]:
        for f in self.files:
            if f.name == name:
                return f
        return None

    def rank_files(self, rank: int) -> List[FileInfo]:
        """This rank's file set: its container plus its shard files."""
        return [f for f in self.files
                if f.name == f"rank{rank}.chk5"
                or f.name.startswith(f"rank{rank}.shard")]

    @property
    def total_bytes(self) -> int:
        return sum(f.size for f in self.files)

    @property
    def n_chunks(self) -> int:
        return sum(f.n_chunks for f in self.files)

    @property
    def chunk_digests(self) -> frozenset:
        """Every chunk digest this entry references — the unit the deploy
        delta (:meth:`CatalogView.diff`) is computed over."""
        return frozenset(h for f in self.files for h in f.digests)

    @property
    def chunk_sizes(self) -> List[int]:
        return [n for f in self.files for n in f.chunk_sizes]

    @property
    def chunk_hist(self) -> Dict[str, int]:
        return _chunk_hist(self.chunk_sizes)

    def to_inventory(self) -> Dict[str, Any]:
        """The legacy ``catalog_inventory`` per-entry dict shape (what
        ``chkls --json`` emits and existing CI assertions consume)."""
        sizes = self.chunk_sizes
        return {
            "id": self.id, "pinned": self.pinned,
            "kind": self.kind, "level": self.level,
            "wall_time": self.wall_time,
            "files": {f.name: {"size": f.size, "n_chunks": f.n_chunks,
                               "mode": f.mode}
                      for f in self.files},
            "total_bytes": self.total_bytes, "n_chunks": self.n_chunks,
            "chunk_hist": _chunk_hist(sizes),
            "chunk_bytes_min": min(sizes, default=0),
            "chunk_bytes_max": max(sizes, default=0),
        }

    @staticmethod
    def from_json(entry: Dict[str, Any], key: str, epoch: int) -> "EntryInfo":
        man = entry.get("manifest", {}) or {}
        files = tuple(
            FileInfo.from_entry(fe) for _name, fe in
            sorted(Catalog.file_entries(entry).items()))
        lvl = man.get("level")
        return EntryInfo(
            id=int(entry.get("id", key)), pinned=bool(entry.get("pinned")),
            kind=man.get("kind"),
            level=int(lvl) if lvl is not None else None,
            wall_time=man.get("wall_time"), manifest=man,
            files=files, epoch=epoch)


@dataclass(frozen=True)
class ChunkDelta:
    """The chunk-level pull a move from ``base`` to ``target`` costs: the
    digests the target references that the base does not.  With no base
    (cold replica) the delta is the whole target."""
    base_id: Optional[int]
    target_id: int
    digests: frozenset
    bytes_delta: int                   # bytes of the missing chunks
    bytes_total: int                   # total target chunk bytes
    n_chunks_delta: int
    n_chunks_total: int

    @property
    def ratio(self) -> float:
        """Delta bytes over full weight bytes — the fine-tune-publish
        claim (~dedup ratio of the underlying store) and the CI-gated
        ``serve_swap_delta_ratio`` datapoint."""
        return self.bytes_delta / max(self.bytes_total, 1)


class CatalogView:
    """An immutable snapshot of one catalog read: epoch + typed entries.

    ``stored_chunks`` (the bucket-wide chunk count) is filled only by
    :meth:`from_store` with ``count_chunks=True`` — it costs a bucket
    list, which pure metadata readers should not pay."""

    def __init__(self, epoch: int, entries: Dict[int, EntryInfo],
                 stored_chunks: Optional[int] = None):
        self.epoch = int(epoch)
        self.entries: Dict[int, EntryInfo] = dict(
            sorted(entries.items()))
        self.stored_chunks = stored_chunks

    # -- construction --------------------------------------------------- #

    @staticmethod
    def from_json(cat: Dict[str, Any],
                  stored_chunks: Optional[int] = None) -> "CatalogView":
        epoch = int(cat.get("epoch", 0))
        entries = {
            int(k): EntryInfo.from_json(v, k, epoch)
            for k, v in cat.get("entries", {}).items()}
        return CatalogView(epoch, entries, stored_chunks)

    @staticmethod
    def from_store(store: ObjectStore, *,
                   count_chunks: bool = False) -> "CatalogView":
        cat, _etag = Catalog(store).read()
        stored = len(store.list("chunks/")) if count_chunks else None
        return CatalogView.from_json(cat, stored)

    @staticmethod
    def from_root(root: str, *, count_chunks: bool = False) -> "CatalogView":
        from repro.objstore.client import make_object_store
        return CatalogView.from_store(make_object_store(f"file:{root}"),
                                      count_chunks=count_chunks)

    # -- queries -------------------------------------------------------- #

    def ids(self) -> List[int]:
        return list(self.entries)

    def entry(self, ckpt_id: int) -> Optional[EntryInfo]:
        return self.entries.get(int(ckpt_id))

    def latest(self, *, kind: Optional[str] = None,
               level: Optional[int] = None,
               min_id: Optional[int] = None) -> Optional[EntryInfo]:
        """Newest entry matching the filters — the deploy selector's
        resolution primitive."""
        for i in reversed(self.ids()):
            e = self.entries[i]
            if kind is not None and e.kind != kind:
                continue
            if level is not None and e.level != level:
                continue
            if min_id is not None and e.id < min_id:
                continue
            return e
        return None

    # -- the deploy delta ----------------------------------------------- #

    @staticmethod
    def diff(base: Optional[EntryInfo], target: EntryInfo) -> ChunkDelta:
        """Chunk-level delta ``base → target``: what a replica already
        holding ``base``'s chunks must pull to materialize ``target``.
        Content addressing makes this a digest set difference — two
        entries sharing 97% of their chunks (a fine-tune publish against
        the measured ~0.03 dedup ratio) diff to ~3% of the bytes."""
        have = base.chunk_digests if base is not None else frozenset()
        missing = set()
        bytes_delta = bytes_total = 0
        n_total = 0
        for f in target.files:
            for h, _o, n in f.chunks:
                n_total += 1
                bytes_total += n
                if h not in have and h not in missing:
                    missing.add(h)
                    bytes_delta += n
        return ChunkDelta(
            base_id=base.id if base is not None else None,
            target_id=target.id, digests=frozenset(missing),
            bytes_delta=bytes_delta, bytes_total=bytes_total,
            n_chunks_delta=len(missing), n_chunks_total=n_total)

    # -- legacy inventory shape ----------------------------------------- #

    def to_inventory(self, root: str) -> Dict[str, Any]:
        """The exact dict ``tools.chkls.catalog_inventory`` used to build
        by hand — kept as the machine-readable ``chkls --json`` shape."""
        return {"root": root, "epoch": self.epoch,
                "entries": [e.to_inventory() for e in self.entries.values()],
                "stored_chunks": self.stored_chunks
                if self.stored_chunks is not None else 0}
