"""Chaos harness CLI: run the fault-scenario matrix, emit a JSON report.

    python -m repro.chaos.runner --out report.json
    python -m repro.chaos.runner --backend fti --scenario corrupt-chunk

Exit code 0 iff every (scenario × backend) cell passed with zero data
loss — the CI chaos lane gates on it.  The report is machine-readable:

    {"scenarios": [{"name": ..., "backend": ..., "ok": true,
                    "faults_fired": 2, "recovery_path": "partner",
                    "recovery_s": 0.04, "mttr_s": 0.04,
                    "data_loss_bytes": 0, "detail": {...}}, ...],
     "total": 24, "passed": 24, "data_loss_bytes": 0,
     "max_mttr_s": 0.31, "ok": true}

``--include-supervised`` adds the real multi-process kill/restart
scenario (spawns ``launch/train.py --supervise`` workers; slow).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.chaos.scenarios import (BACKENDS, SCENARIOS, SUPERVISED,
                                   run_matrix)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workdir", default=None,
                    help="scratch dir (default: a fresh temp dir)")
    ap.add_argument("--backend", action="append", choices=BACKENDS,
                    help="restrict to backend(s); repeatable")
    ap.add_argument("--scenario", action="append",
                    choices=sorted(SCENARIOS) + sorted(SUPERVISED),
                    help="restrict to scenario(s); repeatable")
    ap.add_argument("--include-supervised", action="store_true",
                    help="also run the supervised multi-process "
                         "kill/restart scenario (slow)")
    ap.add_argument("--out", default=None, help="write JSON report here")
    ap.add_argument("--trace-dir", default=None,
                    help="record a perfetto trace per scenario cell into "
                         "this dir (<name>-<backend>.json); the report's "
                         "detail gains trace_file + a metrics snapshot")
    args = ap.parse_args(argv)

    backends = tuple(args.backend) if args.backend else BACKENDS
    names = args.scenario or None
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
    if args.workdir:
        report = run_matrix(args.workdir, backends, names,
                            include_supervised=args.include_supervised,
                            trace_dir=args.trace_dir)
    else:
        with tempfile.TemporaryDirectory(prefix="openchk-chaos-") as d:
            report = run_matrix(d, backends, names,
                                include_supervised=args.include_supervised,
                                trace_dir=args.trace_dir)

    text = json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    for r in report["scenarios"]:
        print(f"[chaos] {'PASS' if r['ok'] else 'FAIL'} "
              f"{r['name']:<24s} {r['backend']:<6s} "
              f"via={r['recovery_path']:<10s} faults={r['faults_fired']} "
              f"loss={r['data_loss_bytes']}B mttr={r['mttr_s']:.3f}s")
    print(f"[chaos] {report['passed']}/{report['total']} passed, "
          f"total data loss {report['data_loss_bytes']} bytes, "
          f"max mttr {report['max_mttr_s']:.3f}s")
    if not report["ok"]:
        for r in report["scenarios"]:
            if not r["ok"]:
                print(f"[chaos] FAILED {r['name']}×{r['backend']}: "
                      f"{r['detail']}", file=sys.stderr)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
