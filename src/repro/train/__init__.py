"""train substrate."""
