import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract memory/cost/collective analyses for §Dry-run
and §Roofline of EXPERIMENTS.md.

The two lines above MUST precede any jax-importing import: jax locks the
device count at first init, and the dry-run needs 512 placeholder host
devices to build the 16×16 (single-pod) and 2×16×16 (multi-pod) meshes.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]
  (--all spawns one subprocess per cell for isolation/progress persistence)

Perf knobs (the §Perf hillclimb drives these):
  --zero1          shard optimizer moments over the data axes (ZeRO-1)
  --fsdp           additionally shard parameters over data (weight gather)
  --param-dtype    bfloat16|float32 parameter storage
  --moe-dispatch   einsum|scatter
  --no-remat       disable activation checkpointing
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _build_shardings(mesh, model, state_struct, zero1: bool, fsdp: bool,
                     dp_only: bool = False):
    """TrainState shardings: params per rules (+FSDP), moments (+ZeRO-1).

    ``dp_only``: treat the model axis as extra data parallelism — params
    replicated (or FSDP-sharded) over ALL axes, no tensor parallelism. The
    right strategy for small dense models where TP psums dominate (§Perf B).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.context import data_axes
    from repro.dist.sharding import param_shardings

    if dp_only:
        pshard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), state_struct.params)
    else:
        pshard = param_shardings(mesh, state_struct.params)
    dax = data_axes(mesh) + (("model",) if dp_only else ())
    dp = 1
    for a in dax:
        dp *= mesh.shape[a]
    daxis = dax if len(dax) > 1 else dax[0]

    def augment(sharding, leaf):
        """Add the data axes to the first unsharded divisible dim."""
        spec = list(sharding.spec) + [None] * (len(leaf.shape) - len(sharding.spec))
        for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
            if s is None and dim % dp == 0 and dim >= dp:
                spec[i] = daxis
                return NamedSharding(mesh, P(*spec))
        return sharding

    mshard = pshard
    if zero1:
        mshard = jax.tree.map(augment, pshard, state_struct.params)
    if fsdp:
        pshard = jax.tree.map(augment, pshard, state_struct.params)

    scalar = NamedSharding(mesh, P())
    from repro.train.optimizer import AdamWState
    from repro.train.state import TrainState
    return TrainState(
        step=scalar,
        params=pshard,
        opt=AdamWState(count=scalar, mu=mshard, nu=mshard),
        rng=scalar,
        data_state=jax.tree.map(lambda _: scalar, state_struct.data_state),
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             zero1: bool = False, fsdp: bool = False,
             dp_only: bool = False,
             param_dtype: Optional[str] = None,
             moe_dispatch: Optional[str] = None,
             remat: bool = True,
             q_block: Optional[int] = None,
             out_path: Optional[str] = None,
             verbose: bool = True) -> Dict[str, Any]:
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import SHAPE_BY_NAME, get_arch
    from repro.data.synthetic import data_state_struct
    from repro.dist.context import constraint_hints, use_mesh
    from repro.dist.sharding import batch_sharding, cache_shardings
    from repro.launch.mesh import make_production_mesh
    from repro.models.zoo import batch_struct, build_model
    from repro.roofline.analyze import build_report
    from repro.train.optimizer import AdamWConfig
    from repro.train.state import train_state_struct
    from repro.train.step import make_train_step

    cfg = get_arch(arch)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPE_BY_NAME[shape_name]
    if shape not in cfg.shapes():
        out = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "skipped",
               "reason": "full-attention arch: long-context decode N/A "
                         "(DESIGN.md §5)"}
        if out_path:
            os.makedirs(os.path.dirname(out_path), exist_ok=True)
            with open(out_path, "w") as f:
                json.dump(out, f, indent=1)
        return out

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    def _batch_shard(ndim: int):
        if not dp_only:
            return batch_sharding(mesh, ndim)
        # greedy: extend the batch axes only while the batch stays divisible
        axes: list = []
        n = 1
        for a in ("pod", "data", "model"):
            if a in mesh.axis_names and \
                    shape.global_batch % (n * mesh.shape[a]) == 0:
                axes.append(a)
                n *= mesh.shape[a]
        if not axes:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(tuple(axes), *([None] * (ndim - 1))))

    import contextlib
    hint_ctx = constraint_hints(not dp_only) if dp_only else \
        contextlib.nullcontext()
    with use_mesh(mesh), hint_ctx:
        if shape.kind == "train":
            state_struct = train_state_struct(model.param_struct(),
                                              data_state_struct())
            bstruct = batch_struct(cfg, shape.global_batch, shape.seq_len)
            in_shardings = (
                _build_shardings(mesh, model, state_struct, zero1, fsdp,
                                 dp_only=dp_only),
                jax.tree.map(lambda s: _batch_shard(len(s.shape)), bstruct),
            )
            step = make_train_step(model, AdamWConfig(), remat=remat)
            lowered = jax.jit(
                step, in_shardings=in_shardings,
                out_shardings=(in_shardings[0],
                               NamedSharding(mesh, P())),
                donate_argnums=(0,),   # state buffers reused in place
            ).lower(state_struct, bstruct)
        elif shape.kind == "prefill":
            pstruct = model.param_struct()
            pshard = _build_shardings(
                mesh, model, _FakeState(pstruct), zero1=False,
                fsdp=fsdp).params
            bstruct = batch_struct(cfg, shape.global_batch, shape.seq_len)
            bstruct.pop("labels")
            bshard = jax.tree.map(
                lambda s: batch_sharding(mesh, len(s.shape)), bstruct)

            def prefill_step(params, batch):
                logits, _ = model.forward(params, batch, remat=False)
                return jax.numpy.argmax(logits[:, -1], axis=-1)

            lowered = jax.jit(
                prefill_step, in_shardings=(pshard, bshard),
            ).lower(pstruct, bstruct)
        else:  # decode
            from repro.serve.engine import make_serve_step
            pstruct = model.param_struct()
            pshard = _build_shardings(
                mesh, model, _FakeState(pstruct), zero1=False,
                fsdp=fsdp).params
            cstruct = model.cache_struct(shape.global_batch, shape.seq_len)
            seq_sharded = shape.global_batch == 1
            cshard = cache_shardings(mesh, cstruct, shape.global_batch,
                                     seq_axis_sharded=seq_sharded,
                                     protects=model.cache_protects())
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jax.numpy.int32)
            tshard = batch_sharding(
                mesh, 2, batch_divisible=shape.global_batch > 1)
            pos_s = jax.ShapeDtypeStruct((), jax.numpy.int32)
            serve_step = make_serve_step(model)
            lowered = jax.jit(
                serve_step,
                in_shardings=(pshard, tshard, cshard,
                              NamedSharding(mesh, P())),
                out_shardings=(tshard, cshard),
                donate_argnums=(2,),   # KV caches updated in place
            ).lower(pstruct, tok, cstruct, jax.numpy.int32(0))

        compile_t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - compile_t0

    mem = compiled.memory_analysis()
    print(mem)                                  # proves it fits
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):         # older jax: dict per device
        cost = cost[0] if cost else {}
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()

    peak = None
    mem_detail = {}
    if mem is not None:
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_detail[k] = getattr(mem, k, None)
        peak = (mem_detail.get("temp_size_in_bytes") or 0) + \
               (mem_detail.get("argument_size_in_bytes") or 0)

    rep = build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost=cost, hlo_text=hlo,
        model_flops_total=cfg.model_flops(shape),
        peak_memory=peak,
    )
    hlo_diag = rep.to_dict()

    # primary roofline terms: analytic model (HLO cost_analysis counts scan
    # bodies once — see roofline/analytic.py; HLO numbers kept as diagnostics)
    from repro.dist.context import data_axes
    from repro.roofline.analytic import analytic_report
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    if dp_only:
        # effective DP is capped by the global batch (surplus devices
        # replicate — multi-pod dp-only wants global_batch ≥ chips)
        dp, tp = min(dp * tp, shape.global_batch), 1
    ana = analytic_report(cfg, shape, dp=dp, tp=tp, remat=remat,
                          zero1=zero1, fsdp=fsdp)

    out = dict(ana)
    out.update(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        status="ok", compile_seconds=compile_s,
        total_seconds=time.time() - t0, memory=mem_detail,
        peak_memory_per_device=peak,
        hlo_diagnostics={
            "flops_body_once": hlo_diag["flops_per_device"],
            "bytes_body_once": hlo_diag["bytes_per_device"],
            "wire_body_once": hlo_diag["wire_bytes_per_device"],
            "collectives": hlo_diag["collectives"],
        },
        knobs={"zero1": zero1, "fsdp": fsdp, "dp_only": dp_only,
               "param_dtype": param_dtype or cfg.param_dtype,
               "moe_dispatch": moe_dispatch, "remat": remat})
    if verbose:
        print(json.dumps({k: out[k] for k in (
            "arch", "shape", "mesh", "bottleneck", "t_compute", "t_memory",
            "t_collective", "roofline_fraction", "useful_flops_ratio")},
            indent=1))
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1, default=float)
    return out


class _FakeState:
    """Adapter so _build_shardings can shard bare params."""

    def __init__(self, params):
        self.params = params
        from repro.data.synthetic import data_state_struct
        from repro.train.optimizer import AdamWState
        import jax.numpy as jnp
        s = jax.ShapeDtypeStruct((), jnp.int32)
        self.opt = AdamWState(s, params, params)
        self.step = s
        self.rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        self.data_state = data_state_struct()


def _all_cells(args) -> int:
    from repro.configs import ALL_ARCHS, ALL_SHAPES
    failures = []
    for arch in ALL_ARCHS:
        for shape in [s.name for s in ALL_SHAPES]:
            for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                mesh_name = "multi" if mp else "single"
                out = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(out) and not args.force:
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out-file", out]
                if mp:
                    cmd.append("--multi-pod")
                for flag in ("zero1", "fsdp"):
                    if getattr(args, flag):
                        cmd.append(f"--{flag}")
                if args.param_dtype:
                    cmd += ["--param-dtype", args.param_dtype]
                print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh_name))
                    print(r.stdout[-2000:])
                    print(r.stderr[-3000:])
    print(f"done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--param-dtype")
    ap.add_argument("--moe-dispatch")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--out-file")
    args = ap.parse_args()

    if args.all:
        return _all_cells(args)
    assert args.arch and args.shape, "--arch/--shape or --all"
    out = run_cell(args.arch, args.shape, args.multi_pod,
                   zero1=args.zero1, fsdp=args.fsdp, dp_only=args.dp_only,
                   param_dtype=args.param_dtype,
                   moe_dispatch=args.moe_dispatch,
                   remat=not args.no_remat,
                   out_path=args.out_file)
    return 0 if out.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
