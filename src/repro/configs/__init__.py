"""Assigned-architecture configs (``--arch <id>``)."""
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    ARCH_REGISTRY,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SHAPE_BY_NAME,
    ShapeSpec,
    SSMConfig,
    get_arch,
    register,
)

# importing registers each arch
from repro.configs import (  # noqa: F401
    codeqwen1_5_7b,
    granite_moe_3b_a800m,
    internvl2_1b,
    jamba_1_5_large_398b,
    llama3_2_3b,
    minicpm3_4b,
    mixtral_8x7b,
    rwkv6_3b,
    tinyllama_1_1b,
    whisper_small,
)

ALL_ARCHS = tuple(sorted(ARCH_REGISTRY))
