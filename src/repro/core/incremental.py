"""Incremental checkpointing — the paper's §8 Future Work, implemented.

    "a checkpoint is not fully written at one time, but incrementally
     built in several separated write operations that are performed as
     soon as the data is ready […] forces, then velocities, then the
     positions. Overall, all the variables are checkpointed, but the
     write operations are separated in time, to decrease storage
     congestion and maximize parallelization."

The training-loop analogue: gradients→optimizer-moments→params become
valid at different points inside a step (and per layer under pipelining);
each part ships as soon as it is ready instead of as one burst.

API (directive-style)::

    inc = ctx.store_begin(id=step, level=2)     # opens the checkpoint
    inc.add(grads_part,  prefix="opt")          # as soon as it's ready
    inc.add(new_params,  prefix="params")
    inc.commit()                                 # manifest + redundancy

The container stays uncommitted (``.tmp``) until ``commit``; a crash
mid-build leaves no restorable-but-partial checkpoint (same atomicity as
regular stores — tests/test_incremental.py).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import manifest as mf
from repro.core.formats import CHK5Writer, dtype_to_str
from repro.core.protect import flatten_named, to_host
from repro.core.storage import CHK_FULL, StorageEngine, StoreReport


class IncrementalStore:
    def __init__(self, engine: StorageEngine, ckpt_id: int, level: int,
                 extra_meta: Optional[Dict[str, Any]] = None):
        self.engine = engine
        self.ckpt_id = ckpt_id
        self.level = max(1, min(4, level))
        self.extra_meta = dict(extra_meta or {})
        self._t0 = time.time()
        root = engine._tier_root(self.level)
        self._root = root
        d = mf.begin(root, ckpt_id)
        self._path = os.path.join(d, f"rank{engine.comm.rank}.chk5")
        self._writer = CHK5Writer(self._path)
        self._writer.set_attrs("", dict(self.extra_meta, kind=CHK_FULL,
                                        incremental=True))
        self._names: List[str] = []
        self._named_all: Dict[str, np.ndarray] = {}
        self._committed = False

    def add(self, subtree: Any, prefix: str = "") -> "IncrementalStore":
        """Write one part now (device→host snapshot + append to container)."""
        assert not self._committed, "incremental store already committed"
        named, _ = flatten_named(subtree)
        host = to_host(named)
        for name, arr in host.items():
            full = f"{prefix}/{name}" if prefix else name
            if full in self._named_all:
                raise ValueError(f"part {full!r} written twice")
            self._writer.write_dataset(
                f"data/{full}", np.asarray(arr),
                {"dtype": dtype_to_str(arr.dtype),
                 "part_time": time.time() - self._t0})
            self._named_all[full] = arr
            self._names.append(full)
        return self

    def abort(self) -> None:
        if not self._committed:
            self._writer.close()
            mf.abort(self._root, self.ckpt_id)
            self._committed = True

    def commit(self) -> StoreReport:
        """Close the container, apply level redundancy, commit atomically."""
        assert not self._committed
        self._writer.close()
        nbytes = os.path.getsize(self._path)
        eng = self.engine
        d = mf.ckpt_dir(self._root, self.ckpt_id, tmp=True)
        if self.level == 2:
            from repro.redundancy.partner import replicate, store_partner_copy
            replicate(eng.comm, eng.topo, self.ckpt_id,
                      open(self._path, "rb").read())
            eng.comm.barrier()
            store_partner_copy(eng.comm, eng.topo, self.ckpt_id, d)
        elif self.level == 3:
            eng._erasure_encode(self.ckpt_id, d, self._path)
        statuses = eng.comm.allgather(
            {"rank": eng.comm.rank, "ok": True, "nbytes": nbytes})
        mf.write_manifest(self._root, self.ckpt_id, {
            "kind": CHK_FULL, "level": self.level, "world": eng.comm.world,
            "incremental": True, "parts": self._names,
            "ranks": statuses, **self.extra_meta,
        })
        mf.commit(self._root, self.ckpt_id, keep_last=0)
        eng._prune_chains(self._root)
        # keep the diff engine's digests coherent for subsequent CHK_DIFF
        eng.diff.update_digests_full(self._named_all)
        self._committed = True
        return StoreReport(self.ckpt_id, self.level, CHK_FULL, nbytes,
                           time.time() - self._t0)
