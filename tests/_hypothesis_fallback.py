"""Tiny stand-in for ``hypothesis`` so property tests still *run* when the
package is absent (this container has no network access to install it).

Implements just the surface these tests use — ``@given`` with keyword
strategies, ``@settings(max_examples=, deadline=)``, and the ``integers /
floats / sampled_from / lists / booleans`` strategies — drawing
deterministic pseudo-random examples (seeded per test name, endpoints
always included) instead of doing real shrinking/coverage search.  With
``hypothesis`` installed (requirements-dev.txt) the real library is used
and this module is never imported.
"""
from __future__ import annotations

import inspect
import zlib
from typing import Any, Callable, List

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 endpoints: List[Any] = ()):  # always-tried boundary cases
        self._draw = draw
        self.endpoints = list(endpoints)

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class _St:
    """The ``strategies`` namespace."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            endpoints=[min_value, max_value])

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        def draw(rng):
            # log-uniform when the range spans decades (mirrors how
            # hypothesis probes magnitudes), else uniform
            if min_value > 0 and max_value / min_value > 1e3:
                lo, hi = np.log(min_value), np.log(max_value)
                return float(np.exp(rng.uniform(lo, hi)))
            return float(rng.uniform(min_value, max_value))
        return _Strategy(draw, endpoints=[min_value, max_value])

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                         endpoints=seq[:2])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw, endpoints=[[]] if min_size == 0 else [])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)),
                         endpoints=[False, True])


st = _St()


class _Unsatisfied(Exception):
    pass


def assume(condition: bool) -> None:
    """Skip this example when its precondition doesn't hold."""
    if not condition:
        raise _Unsatisfied()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Record max_examples on the (given-wrapped) test function."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    """Run the test over deterministic drawn examples.

    Fixture parameters pass through untouched; strategy keywords are
    filled per example.  The first examples exercise strategy endpoints
    (min/max/empty), the rest are seeded draws."""
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            names = list(strategies)
            # endpoint examples first: k-th example takes each strategy's
            # k-th endpoint (when it has one), seeded draws fill the rest
            n_end = max((len(s.endpoints) for s in strategies.values()),
                        default=0)
            base = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            for i in range(max(1, n)):
                rng = np.random.default_rng(base + 7919 * i)
                drawn = {}
                for name in names:
                    s = strategies[name]
                    if i < n_end and i < len(s.endpoints):
                        drawn[name] = s.endpoints[i]
                    else:
                        drawn[name] = s.draw(rng)
                try:
                    fn(*args, **kwargs, **drawn)
                    ran += 1
                except _Unsatisfied:
                    continue
            if ran == 0:      # mirror hypothesis: unsatisfiable is an error
                raise RuntimeError(
                    f"{fn.__name__}: assume() rejected all {max(1, n)} "
                    "examples — no assertion ever ran")
        # hide strategy params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = inspect.Signature(
            [p for name, p in sig.parameters.items()
             if name not in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        if hasattr(fn, "_fallback_max_examples"):   # @settings below @given
            wrapper._fallback_max_examples = fn._fallback_max_examples
        return wrapper
    return deco


class HealthCheck:
    """Placeholder so ``suppress_health_check=[...]`` settings parse."""
    too_slow = data_too_large = filter_too_much = all = None
