"""§4.2.2 analogue: CP-dedicated threads — store-call blocking time.

With a dedicated thread, the training thread pays only the device→host
snapshot; serialization + redundancy + I/O overlap with compute. The
benchmark measures the synchronous portion of ``ctx.store`` both ways.
"""
from __future__ import annotations

import shutil
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.context import CheckpointConfig, CheckpointContext

MB = 32


def _blocking_time(dedicated: bool, root: str, stores: int = 5) -> float:
    shutil.rmtree(root, ignore_errors=True)
    state = {"arr": jnp.asarray(
        np.random.RandomState(0).randn(MB * 2**18).astype(np.float32))}
    ctx = CheckpointContext(CheckpointConfig(
        dir=root, backend="fti", dedicated_thread=dedicated))
    # warmup
    ctx.store(state, id=0, level=1)
    ctx.wait()
    ts = []
    for i in range(stores):
        t0 = time.time()
        ctx.store(state, id=i + 1, level=1)
        ts.append(time.time() - t0)
        ctx.wait()           # drain between samples: isolate the sync part
    ctx.shutdown()
    shutil.rmtree(root, ignore_errors=True)
    return float(np.median(ts))


def run() -> Dict[str, float]:
    sync = _blocking_time(False, "/tmp/ba-sync")
    dedicated = _blocking_time(True, "/tmp/ba-ded")
    return {
        "store_blocking_sync_s": sync,
        "store_blocking_dedicated_s": dedicated,
        "speedup": sync / max(dedicated, 1e-9),
    }


def rows():
    r = run()
    return [("async/" + k, v * 1e6 if k.endswith("_s") else 0.0, v)
            for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
