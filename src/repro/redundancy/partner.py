"""L2 partner-copy replication (FTI/SCR PARTNER scheme).

Each rank ships its checkpoint payload to its ring partner, which stores it
next to its own (``rank<k>.partner<j>.chk5``). A lost node's state is then
recovered from its partner's node-local storage — no PFS round-trip.

Sharded stores replicate their whole multi-file set: each sibling shard
file ``rank<j>.shard<s>.chk5`` ships under its own tag and lands on the
partner as ``rank<k>.partner<j>.shard<s>.chk5`` (the shard-file resolver in
core/resharding.py knows both names).
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict, Optional

from repro.core.comm import Communicator
from repro.redundancy.groups import Topology

_SHARD_RE = re.compile(r"^rank(\d+)\.shard(\d+)\.chk5$")


def partner_tag(ckpt_id: int, fname: Optional[str] = None) -> str:
    return f"partner:{ckpt_id}" + (f":{fname}" if fname else "")


def replicate(comm: Communicator, topo: Topology, ckpt_id: int,
              payload: bytes,
              extra: Optional[Dict[str, bytes]] = None) -> int:
    """Send my payload (and any sibling shard files, by basename) to my
    partner; returns the partner rank."""
    partner = topo.partner_of(comm.rank)
    comm.post(partner_tag(ckpt_id), partner, payload)
    names = sorted(extra) if extra else []
    comm.post(partner_tag(ckpt_id, "__files__"), partner,
              json.dumps(names).encode())
    for n in names:
        comm.post(partner_tag(ckpt_id, n), partner, extra[n])
    return partner


def _write(path: str, payload: bytes) -> str:
    with open(path, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    return path


def store_partner_copy(comm: Communicator, topo: Topology, ckpt_id: int,
                       tier_dir: str) -> Optional[str]:
    """Collect the replica set posted *to me* and persist it locally."""
    # whoever has me as partner:
    src = next((r for r in range(comm.world) if topo.partner_of(r) == comm.rank),
               None)
    if src is None:
        return None
    payload = comm.collect(partner_tag(ckpt_id), src)
    if payload is None:
        return None
    os.makedirs(tier_dir, exist_ok=True)
    path = _write(os.path.join(tier_dir, f"rank{comm.rank}.partner{src}.chk5"),
                  payload)
    raw = comm.collect(partner_tag(ckpt_id, "__files__"), src)
    for fname in (json.loads(raw) if raw else []):
        m = _SHARD_RE.match(fname)
        blob = comm.collect(partner_tag(ckpt_id, fname), src)
        if m is None or blob is None:
            continue
        _write(os.path.join(
            tier_dir,
            f"rank{comm.rank}.partner{m.group(1)}.shard{m.group(2)}.chk5"),
            blob)
    return path


def find_partner_copy(topo: Topology, ckpt_dir_path: str, lost_rank: int
                      ) -> Optional[str]:
    """Locate the replica of ``lost_rank`` inside a checkpoint directory."""
    holder = topo.partner_of(lost_rank)
    path = os.path.join(ckpt_dir_path, f"rank{holder}.partner{lost_rank}.chk5")
    return path if os.path.exists(path) else None
