"""Fig. 12 analogue: wall-time overhead of OpenCHK vs native backends.

Methodology reproduced from §6.1: first run with a fault injected at 90 %
progress, then restart to completion; time the whole process. Ratio
OpenCHK/native should be ≈1 (paper: within noise, <2 % worst case).
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import textwrap
import time
from typing import Dict

from benchmarks.apps import heat2d_fti, heat2d_openchk, heat2d_scr, heat2d_veloc
from repro.ft.failures import FaultInjector, SimulatedFault

STEPS = 200
N = 768             # 2.25 MB grid → checkpoint I/O is non-trivial
EVERY = 20          # 10 checkpoints per run, like the paper's 1/minute × 10


def timed_run_with_fault(mod, ckpt_dir, backend=None) -> float:
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    # warm the jit cache so compile time isn't charged to the first variant
    from benchmarks.apps.heat2d_common import heat_step, init_grid
    heat_step(init_grid(N)).block_until_ready()
    t0 = time.time()
    inj = FaultInjector(total_steps=STEPS, at_progress=0.9)
    try:
        mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                injector=inj, backend=backend)
    except SimulatedFault:
        # a real abort kills the CP thread with the process; the in-process
        # simulation must drain it so the restart doesn't race an orphan
        from repro.core.async_engine import drain_all
        drain_all()
    out = mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                  backend=backend)
    assert out["restarted"], "restart did not engage"
    dt = time.time() - t0
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    return dt


def compressed_store(repeats: int = 3) -> Dict[str, float]:
    """Compressed-store datapoint: payload ratio and store-path overhead
    of an int8-compressed FULL store (Pack-side Int8CompressTier,
    ``Protect(compress="int8")``) vs an uncompressed FULL store of the
    same state.  Synchronous fti so the Pack tail is inside the timing.

    The byte ratio is deterministic (~0.25 + scale/index overhead); the
    time ratio pays the quantize+roundtrip-verify cost against a 4x
    smaller write — CI gates both (check_overhead_regression.py)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext, Protect

    n = 1 << 22                      # 16 MiB of f32 payload
    rng = np.random.default_rng(0)
    state = {"params": {"w": jnp.asarray(rng.normal(size=n)
                                         .astype(np.float32))}}
    best: Dict[str, tuple] = {}
    variants = {"full": [Protect("params/**")],
                "int8": [Protect("params/**", compress="int8")]}
    for tag, protects in variants.items():
        times, nbytes = [], 0
        for r in range(repeats):
            d = f"/tmp/bo-compress-{tag}"
            shutil.rmtree(d, ignore_errors=True)
            ctx = CheckpointContext(CheckpointConfig(
                dir=d, backend="fti", dedicated_thread=False))
            ctx.protect(*protects)
            t0 = time.time()
            rep = ctx.store(state, id=1, level=1)
            times.append(time.time() - t0)
            nbytes = rep.bytes_payload
            ctx.shutdown()
            shutil.rmtree(d, ignore_errors=True)
        best[tag] = (min(times), nbytes)
    return {
        "compress_full_store_s": best["full"][0],
        "compress_int8_store_s": best["int8"][0],
        "compress_ratio_int8": best["int8"][1] / best["full"][1],
        "compress_store_overhead_int8": best["int8"][0] / best["full"][0],
    }


def telemetry_overhead(repeats: int = 3) -> Dict[str, float]:
    """Telemetry-plane overhead datapoint: wall time of a traced L4 store
    (span recorder + metrics registry live, so every instrumented stage —
    Plan/Pack/Place/Commit spans, chunk-upload spans, metric increments —
    records for real) vs the same store with telemetry disabled (the
    no-op fast path).  Synchronous fti, interleaved repeats; the ratio is
    the min over per-round (on/off) pairs — adjacent runs share whatever
    the box was doing, so pairing cancels drift that a min-of-mins ratio
    eats whole, while a systematic cost still shows in every round.
    ``telemetry_overhead_ratio`` is hard-gated at 1.05 in
    check_overhead_regression.py — the plane's contract is that
    observability never costs real store time."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.telemetry import trace as ttrace

    n = 1 << 22                      # 16 MiB of f32 payload
    rng = np.random.default_rng(0)
    state = {"params": {"w": jnp.asarray(rng.normal(size=n)
                                         .astype(np.float32))}}

    def one_store(tag: str) -> float:
        d = f"/tmp/bo-telemetry-{tag}"
        shutil.rmtree(d, ignore_errors=True)
        ctx = CheckpointContext(CheckpointConfig(
            dir=d, backend="fti", dedicated_thread=False))
        t0 = time.time()
        ctx.store(state, id=1, level=4)
        dt = time.time() - t0
        ctx.shutdown()
        shutil.rmtree(d, ignore_errors=True)
        return dt

    def arm(tag: str) -> None:
        if tag == "on":
            ttrace.tracer().reset()  # keep the event list from compounding
            ttrace.enable()
        else:
            ttrace.disable()

    variants = ("off", "on")
    times: Dict[str, list] = {t: [] for t in variants}
    try:
        for tag in variants:                      # warmup: jit + page cache
            arm(tag)
            one_store(tag)
        for _ in range(max(repeats, 5)):          # interleave: shared drift
            for tag in variants:                  # hits both variants alike
                arm(tag)
                times[tag].append(one_store(tag))
    finally:
        ttrace.disable()
        ttrace.tracer().reset()
    ratios = [on / off for off, on in zip(times["off"], times["on"])]
    return {
        "telemetry_off_store_s": min(times["off"]),
        "telemetry_on_store_s": min(times["on"]),
        "telemetry_overhead_ratio": min(ratios),
    }


def objstore_store(repeats: int = 3) -> Dict[str, float]:
    """Object-store L4 datapoint: wall time of a chunked+cataloged store
    (``objstore_store_s``), the store-path goodput
    (``objstore_goodput_bps`` = payload bytes / first-store wall time —
    the zero-stall fused Pack → upload path keeps this near local write
    bandwidth because Place never re-reads staged files) and the dedup
    ratio — a second store after a small param delta must upload <30% of
    the first's bytes (unchanged content-addressed chunks upload
    nothing; both gated in check_overhead_regression.py).  Synchronous
    fti so the Place uploads + Commit catalog publish are inside the
    timing."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext

    n = 1 << 23                      # 32 MiB of f32 payload → 32 chunks
    rng = np.random.default_rng(0)
    base = rng.normal(size=n).astype(np.float32)
    times, ratios, goodputs = [], [], []
    for r in range(repeats):
        d = "/tmp/bo-objstore"
        shutil.rmtree(d, ignore_errors=True)
        ctx = CheckpointContext(CheckpointConfig(
            dir=d, backend="fti", dedicated_thread=False))
        tier = ctx.tcl.backend.engine.objstore_tier()
        t0 = time.time()
        rep = ctx.store({"params": {"w": jnp.asarray(base)}}, id=1, level=4)
        dt = time.time() - t0
        times.append(dt)
        goodputs.append(rep.bytes_payload / max(dt, 1e-9))
        up1 = tier.uploader.stats["bytes_uploaded"]
        delta = base.copy()
        delta[:4096] += 1.0          # a small param delta
        ctx.store({"params": {"w": jnp.asarray(delta)}}, id=2, level=4)
        ratios.append((tier.uploader.stats["bytes_uploaded"] - up1)
                      / max(up1, 1))
        ctx.shutdown()
        shutil.rmtree(d, ignore_errors=True)
    return {"objstore_store_s": min(times),
            "objstore_goodput_bps": max(goodputs),
            "objstore_dedup_ratio": min(ratios)}


def objstore_shift_dedup() -> Dict[str, float]:
    """Boundary-shift dedup datapoint (deterministic, byte-level — no
    timing): 16 MiB of random bytes, then the same payload with 1 KiB
    inserted at the 25 % mark, streamed through the CDC chunk uploader.
    A fixed-size chunker re-uploads every chunk after the insertion
    point (offsets shift); content-defined cuts re-synchronize within
    ~one average chunk, so the re-uploaded delta must be well under the
    fixed-size cost.  ``objstore_shift_dedup_vs_fixed`` = CDC delta
    bytes / fixed-size delta bytes, gated hard at 0.30 in
    check_overhead_regression.py."""
    import hashlib
    import numpy as np
    from repro.objstore.cdc import CDCParams
    from repro.objstore.chunks import ChunkUploader, DEFAULT_CHUNK_BYTES
    from repro.objstore.client import MemoryObjectStore

    rng = np.random.default_rng(7)
    v1 = rng.integers(0, 256, 16 << 20, dtype=np.uint8).tobytes()
    insert = rng.integers(0, 256, 1024, dtype=np.uint8).tobytes()
    at = len(v1) // 4
    v2 = v1[:at] + insert + v1[at:]

    up = ChunkUploader(MemoryObjectStore(), cdc=CDCParams())
    for tag, payload in (("v1", v1), ("v2", v2)):
        before = up.stats["bytes_uploaded"]
        s = up.open_stream(tag)
        s.write(payload)
        s.finish()
        s.pending().result()
        if tag == "v2":
            cdc_delta = up.stats["bytes_uploaded"] - before
    up.close()

    def fixed_digests(buf):
        return [(hashlib.sha256(buf[o:o + DEFAULT_CHUNK_BYTES]).hexdigest(),
                 len(buf[o:o + DEFAULT_CHUNK_BYTES]))
                for o in range(0, len(buf), DEFAULT_CHUNK_BYTES)]
    seen = {h for h, _ in fixed_digests(v1)}
    fixed_delta = sum(n for h, n in fixed_digests(v2) if h not in seen)
    return {"objstore_shift_dedup_vs_fixed": cdc_delta / max(fixed_delta, 1)}


def serve_swap_delta() -> Dict[str, float]:
    """Checkpoint-as-deployment datapoint (deterministic, byte-level — no
    timing): publish a FULL checkpoint, publish a fine-tuned successor
    (small param delta), then pull the successor into a replica whose
    chunk cache already holds the first — exactly what a rolling hot-swap
    (``repro.serve.deploy``) does between consecutive deploys.
    ``serve_swap_delta_ratio`` = fetched / (fetched + cached) bytes of
    the second pull; content addressing makes it ~the dedup ratio of the
    underlying store, hard-gated at 0.30 in check_overhead_regression.py
    alongside the catalog-level prediction (``CatalogView.diff``)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.objstore.client import make_object_store
    from repro.objstore.inspect import CatalogView
    from repro.serve.deploy import EntryPuller

    n = 1 << 23                      # 32 MiB of f32 payload
    rng = np.random.default_rng(0)
    base = rng.normal(size=n).astype(np.float32)
    d = "/tmp/bo-serve-swap"
    shutil.rmtree(d, ignore_errors=True)
    ctx = CheckpointContext(CheckpointConfig(
        dir=d, backend="fti", dedicated_thread=False))
    ctx.store({"params": {"w": jnp.asarray(base)}}, id=1, level=4)
    tuned = base.copy()
    tuned[:4096] += 1.0              # a small fine-tune delta
    ctx.store({"params": {"w": jnp.asarray(tuned)}}, id=2, level=4)
    ctx.shutdown()

    store = make_object_store("file:" + os.path.join(d, "objstore"))
    view = CatalogView.from_store(store)
    puller = EntryPuller(store, os.path.join(d, "replica-cache"))
    puller.pull(view.entry(1))       # the replica deployed v1 earlier
    got = puller.pull(view.entry(2))
    fetched, cached = got["bytes_fetched"], got["bytes_cached"]
    predicted = CatalogView.diff(view.entry(1), view.entry(2)).ratio
    shutil.rmtree(d, ignore_errors=True)
    return {"serve_swap_delta_ratio": fetched / max(fetched + cached, 1),
            "serve_swap_delta_predicted": predicted}


def cadence_datapoints() -> Dict[str, float]:
    """Daly cadence datapoint (deterministic — no timing): drive the
    CadenceController with the reference platform's inputs (store cost
    observations, failures at exact MTBF spacing — comd-ft's 1000-node
    point: delta 48.64 s, MTBF 31557.6 s) and surface its L4 schedule
    against the closed-form optimum.

    - ``cadence_interval_vs_optimum`` — controller interval / closed-form
      Daly optimum; hard-gated to [0.9, 1.1] in
      check_overhead_regression.py (the estimator sees 200 failures at
      exact spacing, so drifting past 10% means the estimator or the
      interval math broke, not noise).
    - ``checkpoint_efficiency`` — best achievable progress fraction at
      the controller's schedule; floor-gated against the committed
      baseline.
    - ``progress_rate`` — progress fraction at the (clamped) interval
      actually scheduled."""
    from repro.chaos.cadence import (
        REFERENCE, CadenceConfig, CadenceController, daly_interval)

    p = REFERENCE.platform(1000)
    ctl = CadenceController(CadenceConfig(max_interval_s=1e9))
    for _ in range(8):
        ctl.note_store(4, p.delta_s)           # measured store cost
    ctl.note_step(0.0)
    for i in range(1, 201):                    # failures at exact spacing
        ctl.note_failure(i * p.mtbf_s)
    dp = ctl.datapoints(4)
    ref = daly_interval(p.delta_s, p.mtbf_s)
    return {
        "progress_rate": dp["progress_rate"],
        "checkpoint_efficiency": dp["checkpoint_efficiency"],
        "cadence_interval_vs_optimum": dp["cadence_interval_s"] / ref,
    }


def chaos_mttr(repeats: int = 3) -> Dict[str, float]:
    """Compound-fault recovery datapoint: run the node-loss-mid-store
    chaos scenario (real store → torn mid-flight store → node kill →
    partner restore, fti backend) and surface best-of-N MTTR plus the
    zero-loss invariant.

    - ``chaos_mttr_s`` — wall time from node death to a verified
      bit-exact partner restore; best-of-N to shed scheduler noise, and
      gated in check_overhead_regression.py with an absolute floor
      (sub-second restores never fail) plus a wide regression multiple.
    - ``chaos_data_loss_bytes`` — must be exactly 0 (hard gate: the
      scenario contract is that faults may cost time, never data)."""
    import tempfile

    from repro.chaos.scenarios import run_scenario

    best = None
    loss = 0.0
    for _ in range(max(repeats, 1)):
        with tempfile.TemporaryDirectory(prefix="bo-chaos-") as d:
            r = run_scenario("node-loss-mid-store", "fti", d)
            if not r.ok:
                raise RuntimeError(f"chaos scenario failed: {r.detail}")
            loss += float(r.data_loss_bytes)
            m = r.mttr_s if r.mttr_s is not None else r.recovery_s
            best = m if best is None else min(best, m)
    return {"chaos_mttr_s": best, "chaos_data_loss_bytes": loss}


_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json, time, shutil
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.context import CheckpointConfig, CheckpointContext

    repeats = max(int(sys.argv[1]), 5)
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    n = 1 << 12                       # 4096x4096 f32 = 64 MiB of payload
    host = np.arange(n * n, dtype=np.float32).reshape(n, n)
    sh = NamedSharding(mesh, P("data", "model"))

    # veloc (sync): no digest bookkeeping, so the timing isolates the
    # snapshot+pack+commit path the datapoint is about; a fresh device
    # array per repeat keeps jax's cached host copy from flattering the
    # gather variant
    def one_store(tag, sharded):
        w = jax.device_put(host, sh)
        jax.block_until_ready(w)
        d = f"/tmp/bo-shard-{tag}"
        shutil.rmtree(d, ignore_errors=True)
        ctx = CheckpointContext(CheckpointConfig(
            dir=d, backend="veloc", dedicated_thread=False,
            sharded_snapshot=sharded))
        os.sync()       # settle writeback: fsync inside the store must not
        t0 = time.time()    # pay for the previous variant's dirty pages
        ctx.store({"w": w}, id=1, level=1)
        dt = time.time() - t0
        ctx.shutdown()
        shutil.rmtree(d, ignore_errors=True)
        return dt

    variants = (("sharded", True), ("gathered", False))
    for tag, sharded in variants:
        one_store(tag, sharded)                   # warmup: jit + page cache
    times = {tag: [] for tag, _ in variants}
    for r in range(repeats):                      # interleave: shared drift
        for tag, sharded in variants:             # hits both variants alike
            times[tag].append(one_store(tag, sharded))
    out = {f"{tag}_store_s": min(ts) for tag, ts in times.items()}
    print("RESULT " + json.dumps(out))
""")


def sharded_store(repeats: int = 3) -> Dict[str, float]:
    """Sharded-store datapoint on the forced-16-device mesh: one store of
    a 64 MiB leaf sharded 4x4, snapshotting per-shard (shard-local Plan +
    parallel shard-file writes) vs gathering the full array to host.  The
    sharded path must not be slower — it moves the same bytes but skips
    the global host buffer and writes chunks in parallel.  Runs in a
    subprocess (device count locks at jax init)."""
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT, str(repeats)],
                       capture_output=True, text=True, timeout=900, env=env)
    lines = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")]
    assert r.returncode == 0 and lines, (
        f"sharded-store bench subprocess failed (rc={r.returncode}):\n"
        f"{r.stdout[-2000:]}\n{r.stderr[-3000:]}")
    got = json.loads(lines[0][len("RESULT "):])
    return {
        "sharded_store_s": got["sharded_store_s"],
        "gathered_store_s": got["gathered_store_s"],
        "sharded_store_speedup":
            got["gathered_store_s"] / max(got["sharded_store_s"], 1e-9),
    }


def run(repeats: int = 3) -> Dict[str, float]:
    natives = {"fti": heat2d_fti, "scr": heat2d_scr, "veloc": heat2d_veloc}
    out: Dict[str, float] = {}
    for backend, native_mod in natives.items():
        # interleave native/openchk repeats (like the sharded-store bench)
        # so shared machine drift hits both variants alike — sequential
        # blocks bias the ratio by whatever the host was doing during the
        # second block
        t_native, t_openchk = [], []
        for _ in range(repeats):
            t_native.append(timed_run_with_fault(
                native_mod, f"/tmp/bo-native-{backend}"))
            t_openchk.append(timed_run_with_fault(
                heat2d_openchk, f"/tmp/bo-openchk-{backend}", backend=backend))
        out[f"native_{backend}_s"] = min(t_native)
        out[f"openchk_{backend}_s"] = min(t_openchk)
        out[f"overhead_ratio_{backend}"] = min(t_openchk) / min(t_native)
    out.update(compressed_store(repeats=repeats))
    out.update(telemetry_overhead(repeats=repeats))
    out.update(sharded_store(repeats=repeats))
    out.update(objstore_store(repeats=repeats))
    out.update(objstore_shift_dedup())
    out.update(serve_swap_delta())
    out.update(cadence_datapoints())
    out.update(chaos_mttr(repeats=repeats))
    return out


def rows(repeats: int = 2):
    r = run(repeats)
    return [("overhead/" + k, v * 1e6 if k.endswith("_s") else 0.0, v)
            for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
