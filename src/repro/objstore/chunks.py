"""Content-addressed chunk layer — DIFF semantics at the storage layer.

Checkpoint files (CHK5 containers and their sibling shard files) are
split into chunks; each chunk is stored under its sha256
(``chunks/<h[:2]>/<h>``), so a chunk that already exists in the store is
never uploaded again.  Chunking is **content-defined** by default
(:mod:`repro.objstore.cdc` — gear rolling-hash boundaries with
min/avg/max bounds): boundaries re-synchronize after an insertion, so a
leaf-size change re-uploads only the neighboring chunks instead of the
whole container tail.  ``FileEntry`` records ``(digest, offset,
length)`` per chunk, so variable-size chunks stay randomly addressable
(``ObjectStoreTier.recover`` byte-range verification, region reads).
The pre-CDC fixed-size mode survives as ``mode="fixed"`` — both for
config opt-out and for catalogs written before the change
(:func:`iter_file_chunks` is the legacy splitter/decoder).

Two upload paths share one transfer pool
(``StorageConfig.objstore_transfers``):

- **streaming** (:class:`ChunkStream`, via ``ChunkUploader.open_stream``)
  — the fused Pack path.  CHK5 writers tee every written byte into the
  stream; the moment a CDC boundary lands the chunk's sha256 is taken
  and, when missing from the store, its upload is submitted — packing,
  hashing and transfers overlap, and the staged file is never re-read.
  In-flight chunk bytes are bounded by a semaphore (the stream uploads
  from memory, so backpressure replaces the file-path's pread).
- **file-based** (:meth:`ChunkUploader.submit_file`) — payloads staged
  outside Pack (SCR ``route_file``, incremental ``add``): the file is
  scanned with the *same* chunker (layout-consistent with streamed
  containers) and workers ``pread`` each chunk.

Both return a :class:`PendingFile` at Place; ``result()`` joins at
Commit — submit-at-Place / join-at-Commit ordering is preserved, the
streaming path just starts its transfers earlier (during Pack).

Content addressing is also the resume story: re-running an interrupted
upload re-splits the same bytes and skips every chunk that already
landed — no partial-object state to reconcile.
"""
from __future__ import annotations

import hashlib
import os
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.chaos import inject as chaos
from repro.objstore.cdc import CDCParams, Chunker
from repro.objstore.client import ObjectStore, ObjectStoreError
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace

DEFAULT_CHUNK_BYTES = 1 << 20

#: chunking modes a catalog entry may record
MODE_CDC = "cdc"
MODE_FIXED = "fixed"

#: in-flight streamed chunks per transfer thread (memory backpressure)
_INFLIGHT_PER_TRANSFER = 4


def chunk_key(digest: str) -> str:
    return f"chunks/{digest[:2]}/{digest}"


def iter_file_chunks(path: str, chunk_bytes: int
                     ) -> Iterator[Tuple[str, bytes]]:
    """→ (sha256 hex, chunk bytes) for every fixed-size chunk of ``path``
    — the legacy (pre-CDC) splitter, kept as the ``mode="fixed"`` path
    and the decoder reference for catalogs written before offsets were
    recorded."""
    with open(path, "rb") as f:
        while True:
            data = f.read(chunk_bytes)
            if not data:
                break
            yield hashlib.sha256(data).hexdigest(), data


@dataclass
class FileEntry:
    """One file of a catalog entry: its size, the chunking mode, and the
    ordered chunk list ``(digest, offset, nbytes)`` that reassembles it.

    Legacy 2-tuple ``(digest, nbytes)`` rows (pre-CDC catalogs and old
    callers) normalize to 3-tuples by accumulating offsets — fixed-size
    chunks tile the file contiguously, so the offsets are implied."""
    name: str
    size: int
    chunks: List[Tuple[str, int, int]]
    mode: str = MODE_FIXED

    def __post_init__(self):
        norm, off = [], 0
        for row in self.chunks:
            if len(row) == 2:
                h, n = row
                norm.append((h, off, int(n)))
            else:
                h, o, n = row
                norm.append((h, int(o), int(n)))
            off = norm[-1][1] + norm[-1][2]
        self.chunks = norm

    def to_json(self) -> Dict:
        return {"size": self.size, "mode": self.mode,
                "chunks": [[h, o, n] for h, o, n in self.chunks]}

    @staticmethod
    def from_json(name: str, d: Dict) -> "FileEntry":
        # pre-CDC entries carry [digest, nbytes] rows and no mode key:
        # they were written by the fixed-size splitter
        return FileEntry(name=name, size=int(d["size"]),
                         chunks=[tuple(row) for row in d["chunks"]],
                         mode=d.get("mode", MODE_FIXED))


@dataclass
class PendingFile:
    """An in-flight chunked upload: metadata is final, transfers may not
    be — ``result()`` joins them (raising the first failure).  File-based
    uploads hold the source file open until then (transfer workers
    ``pread`` from it, so the upload survives the stage dir's commit-time
    rename); streamed uploads carry their bytes in the futures."""
    name: str
    size: int
    chunks: List[Tuple[str, int, int]]
    mode: str = MODE_FIXED
    futures: List[Future] = field(default_factory=list)
    _file: object = None

    def result(self) -> FileEntry:
        try:
            for f in self.futures:
                f.result()
        finally:
            if self._file is not None:
                self._file.close()
                self._file = None
        return FileEntry(self.name, self.size, self.chunks, mode=self.mode)


class ChunkUploader:
    """Dedup-aware parallel chunk uploads against one object store.

    ``cdc=None`` keeps the legacy fixed-size layout (``chunk_bytes``);
    passing :class:`~repro.objstore.cdc.CDCParams` switches every path —
    streamed and file-based — to content-defined boundaries."""

    def __init__(self, store: ObjectStore,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES, transfers: int = 4,
                 cdc: Optional[CDCParams] = None):
        self.store = store
        self.chunk_bytes = int(chunk_bytes)
        self.transfers = max(1, int(transfers))
        self.cdc = cdc
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self._inflight = threading.BoundedSemaphore(
            self.transfers * _INFLIGHT_PER_TRANSFER)
        # region key → recorded chunk lengths: the device-digest pre-seed
        # cache ChunkStream replays for unchanged leaves (see open_stream)
        self._layouts: "OrderedDict[str, List[int]]" = OrderedDict()
        self._layout_cap = 512
        # digests known present-or-in-flight: atomic check-and-mark, so a
        # chunk repeated within one store (or racing across concurrent
        # shard streams) uploads exactly once instead of racing the
        # exists-check against its own first upload — and repeat digests
        # skip the exists round-trip entirely
        self._known: "OrderedDict[str, bool]" = OrderedDict()
        self._known_cap = 1 << 16
        self.stats: Dict[str, int] = {
            "chunks_uploaded": 0, "chunks_deduped": 0,
            "bytes_uploaded": 0, "bytes_deduped": 0,
            "regions_reused": 0, "bytes_scan_skipped": 0,
        }

    @property
    def mode(self) -> str:
        return MODE_CDC if self.cdc is not None else MODE_FIXED

    def _executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.transfers,
                    thread_name_prefix="objstore-up")
            return self._pool

    def close(self) -> None:
        """Join in-flight transfers and shut the pool down.  Optional —
        the pool is lazily recreated by the next submission."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # -- layout cache (digest pre-seeds) -------------------------------- #

    def cached_layout(self, key: str) -> Optional[List[int]]:
        with self._lock:
            got = self._layouts.get(key)
            if got is not None:
                self._layouts.move_to_end(key)
            return list(got) if got is not None else None

    def remember_layout(self, key: str, lengths: Sequence[int]) -> None:
        with self._lock:
            self._layouts[key] = list(lengths)
            self._layouts.move_to_end(key)
            while len(self._layouts) > self._layout_cap:
                self._layouts.popitem(last=False)

    # -- transfer submission -------------------------------------------- #

    def _put_chunk(self, fd: int, offset: int, nbytes: int,
                   digest: str) -> None:
        # re-read in the worker (os.pread — positionless, thread-safe):
        # capturing the chunk bytes in the executor queue would hold the
        # whole un-deduped payload in RAM at once on a first store
        data = os.pread(fd, nbytes, offset)
        try:
            with ttrace.span("chunk.upload", digest=digest[:12],
                             bytes=nbytes, path="file"):
                self.store.put(chunk_key(digest), data)
        except BaseException:
            self._forget_chunk(digest)
            raise
        self._note_upload(nbytes)

    def _put_stream_chunk(self, digest: str, data: bytes) -> None:
        # streamed chunks upload from memory; the semaphore acquired at
        # submit time bounds how many can sit in the queue at once
        try:
            try:
                with ttrace.span("chunk.upload", digest=digest[:12],
                                 bytes=len(data), path="stream"):
                    self.store.put(chunk_key(digest), data)
            except BaseException:
                self._forget_chunk(digest)
                raise
            self._note_upload(len(data))
        finally:
            self._inflight.release()

    def _note_upload(self, nbytes: int) -> None:
        with self._lock:
            self.stats["chunks_uploaded"] += 1
            self.stats["bytes_uploaded"] += nbytes
        tmetrics.counter("openchk_chunks_uploaded_total").inc()
        tmetrics.counter("openchk_chunk_bytes_uploaded_total").inc(nbytes)

    def _note_dedup(self, nbytes: int) -> None:
        with self._lock:
            self.stats["chunks_deduped"] += 1
            self.stats["bytes_deduped"] += nbytes
        tmetrics.counter("openchk_chunks_deduped_total").inc()
        tmetrics.counter("openchk_chunk_bytes_deduped_total").inc(nbytes)

    def _chunk_known(self, digest: str, nbytes: int) -> bool:
        """Atomic check-and-mark: True ⇒ the chunk is already stored or
        already submitted (counted as dedup, skip the upload); False ⇒
        the caller owns the upload — the digest is marked before the
        exists-check returns, so a second emitter of the same content
        (repeated chunk in one file, concurrent shard streams) dedups
        against the in-flight transfer instead of racing it."""
        with self._lock:
            if digest in self._known:
                self._known.move_to_end(digest)
                hit = True
            else:
                self._known[digest] = True
                while len(self._known) > self._known_cap:
                    self._known.popitem(last=False)
                hit = False
        if hit:
            self._note_dedup(nbytes)
            return True
        try:
            if self.store.exists(chunk_key(digest)):
                self._note_dedup(nbytes)
                return True
        except BaseException:
            self._forget_chunk(digest)
            raise
        return False

    def _forget_chunk(self, digest: str) -> None:
        """Drop a marked digest whose upload never landed (put failed) —
        a later store must retry it, not dedup against a phantom."""
        with self._lock:
            self._known.pop(digest, None)

    # -- file-based path (payloads staged outside Pack) ------------------ #

    def _iter_cuts(self, path: str) -> Iterator[Tuple[str, int, int]]:
        """→ (digest, offset, nbytes) per chunk of ``path``, using the
        uploader's chunking mode.  CDC reads the file in bounded blocks
        through the incremental chunker — same cuts as the streamed
        path for the same bytes."""
        if self.cdc is None:
            off = 0
            for digest, data in iter_file_chunks(path, self.chunk_bytes):
                yield digest, off, len(data)
                off += len(data)
            return
        chunker = Chunker(self.cdc)
        off = 0
        with open(path, "rb") as f:
            while True:
                blk = f.read(self.cdc.max_bytes)
                done = not blk
                pieces = chunker.finish() if done else chunker.push(blk)
                for piece in pieces:
                    yield (hashlib.sha256(piece).hexdigest(), off,
                           len(piece))
                    off += len(piece)
                if done:
                    break

    def submit_file(self, path: str, name: Optional[str] = None
                    ) -> PendingFile:
        """Split ``path`` and submit every *missing* chunk to the transfer
        pool; chunks already in the store are skipped (dedup).  Returns
        immediately — the caller joins via :meth:`PendingFile.result`."""
        pend = PendingFile(name=name or os.path.basename(path),
                           size=os.path.getsize(path), chunks=[],
                           mode=self.mode)
        pend._file = open(path, "rb")
        fd = pend._file.fileno()
        ex = self._executor()
        for digest, offset, nbytes in self._iter_cuts(path):
            pend.chunks.append((digest, offset, nbytes))
            if not self._chunk_known(digest, nbytes):
                pend.futures.append(
                    ex.submit(self._put_chunk, fd, offset, nbytes, digest))
        return pend

    def upload_file(self, path: str, name: Optional[str] = None) -> FileEntry:
        """Synchronous convenience: submit + join."""
        return self.submit_file(path, name).result()

    # -- streaming path (the fused Pack sink) ---------------------------- #

    def open_stream(self, name: str) -> "ChunkStream":
        return ChunkStream(self, name)


class ChunkStream:
    """The Pack-side push sink: a CHK5 writer tees every written byte in
    via :meth:`write`; chunks upload the moment a boundary lands.

    Region hooks carry the device-digest pre-seeds: ``begin_region(key)``
    force-cuts the pending bytes (so the region's chunk layout depends
    only on the region's own bytes) and, when the uploader has a recorded
    layout for ``key`` (same leaf, same Protect spec, same device-side
    blockhash digests ⇒ same encoded bytes), replays the recorded chunk
    lengths verbatim — the CDC boundary scan is skipped for the whole
    region.  Chunk sha256s are still taken from the actual bytes, so a
    replayed layout can never mis-address content: at worst a stale
    layout yields suboptimal cuts, which reassemble correctly regardless
    (every chunk records its own offset/length).  ``end_region`` records
    the fresh layout for the next store.

    ``cut()`` is a soft boundary hint (dataset starts): honored only when
    the pending span already reached ``min_bytes``, so small datasets
    don't shatter into tiny chunks."""

    def __init__(self, uploader: ChunkUploader, name: str):
        self.uploader = uploader
        self.name = name
        self._chunker = (Chunker(uploader.cdc)
                         if uploader.cdc is not None else None)
        self._fixed_buf = bytearray()
        self._offset = 0
        self._chunks: List[Tuple[str, int, int]] = []
        self._futures: List[Future] = []
        self._replay: List[int] = []       # pending replay lengths (hit)
        self._replay_buf = bytearray()     # bytes of the replaying chunk
        self._region_key: Optional[str] = None
        self._region_start = 0             # chunk index the region began at
        self._pending: Optional[PendingFile] = None

    @property
    def finished(self) -> bool:
        return self._pending is not None

    # ------------------------------------------------------------------ #

    def write(self, buf) -> int:
        if self._pending is not None:
            raise ObjectStoreError(f"stream {self.name}: write after finish")
        n = len(buf)
        if not n:
            return 0
        if self._chunker is None:
            self._fixed_buf += buf
            cb = self.uploader.chunk_bytes
            while len(self._fixed_buf) >= cb:
                self._emit(bytes(self._fixed_buf[:cb]))
                del self._fixed_buf[:cb]
        elif self._replay:
            self._write_replay(buf)
        else:
            for piece in self._chunker.push(buf):
                self._emit(piece)
        return n

    def _write_replay(self, buf) -> None:
        """Region-cache hit: split incoming bytes at the recorded lengths
        without scanning (a private buffer, never the chunker — the
        chunker would impose its own cuts).  An exhausted replay falls
        back to the chunker mid-stream — correctness never depends on the
        cache, only layout stability does."""
        up = self.uploader
        pos, n = 0, len(buf)
        while pos < n and self._replay:
            need = self._replay[0] - len(self._replay_buf)
            piece = buf[pos:pos + need]
            self._replay_buf += piece
            pos += len(piece)
            if len(self._replay_buf) == self._replay[0]:
                self._replay.pop(0)
                with up._lock:
                    up.stats["bytes_scan_skipped"] += len(self._replay_buf)
                self._emit(bytes(self._replay_buf))
                self._replay_buf.clear()
        if pos < n:
            for piece in self._chunker.push(buf[pos:]):
                self._emit(piece)

    def cut(self) -> None:
        """Soft boundary hint (dataset start): force a cut only when the
        pending span already satisfies the minimum chunk size."""
        if self._chunker is None or self._replay:
            return
        if self._chunker.pending_bytes >= self._chunker.params.min_bytes:
            for piece in self._chunker.flush():
                self._emit(piece)

    def begin_region(self, key: str) -> None:
        """Start a digest-keyed region: hard cut, then replay the cached
        layout when the key is known (unchanged leaf — no CDC scan)."""
        if self._chunker is None:
            return                         # fixed mode keeps legacy layout
        self.end_region()                  # close any open region first
        for piece in self._chunker.flush():
            self._emit(piece)
        self._region_key = key
        self._region_start = len(self._chunks)
        cached = self.uploader.cached_layout(key)
        if cached:
            self._replay = cached
            with self.uploader._lock:
                self.uploader.stats["regions_reused"] += 1

    def end_region(self) -> None:
        if self._chunker is None or self._region_key is None:
            return
        if self._replay_buf:
            # region ended mid-replay (bytes changed length despite equal
            # digests — defensive): the partial chunk re-enters the chunker
            self._chunker.push(bytes(self._replay_buf))
            self._replay_buf.clear()
        self._replay = []
        for piece in self._chunker.flush():
            self._emit(piece)
        self.uploader.remember_layout(
            self._region_key,
            [n for _h, _o, n in self._chunks[self._region_start:]])
        self._region_key = None

    def finish(self) -> PendingFile:
        """Flush the tail chunk and freeze the metadata.  Idempotent —
        the CHK5 writer calls this at close; the tier reads the result."""
        if self._pending is not None:
            return self._pending
        self.end_region()
        if self._chunker is not None:
            for piece in self._chunker.finish():
                self._emit(piece)
        elif self._fixed_buf:
            self._emit(bytes(self._fixed_buf))
            self._fixed_buf.clear()
        self._pending = PendingFile(
            name=self.name, size=self._offset, chunks=self._chunks,
            mode=self.uploader.mode, futures=self._futures)
        return self._pending

    def pending(self) -> PendingFile:
        if self._pending is None:
            raise ObjectStoreError(
                f"stream {self.name}: not finished (writer crashed before "
                f"close?)")
        return self._pending

    # ------------------------------------------------------------------ #

    def _emit(self, data: bytes) -> None:
        up = self.uploader
        # chaos site at the chunk boundary: error-mode kills the store
        # mid-stream, corrupt-mode flips the bytes BEFORE digesting — the
        # digest then matches the corrupted content, so restore-side
        # integrity (container checksums) is what must catch it
        data = chaos.fire(chaos.SITES.CHUNK_EMIT, exc=ObjectStoreError,
                          data=data, name=self.name,
                          seq=len(self._chunks)).data
        digest = hashlib.sha256(data).hexdigest()
        ttrace.instant("chunk.emit", stream=self.name,
                       seq=len(self._chunks), bytes=len(data))
        self._chunks.append((digest, self._offset, len(data)))
        self._offset += len(data)
        if up._chunk_known(digest, len(data)):
            return
        # bounded in-flight bytes: uploads come from memory here, so the
        # semaphore is the backpressure the file path gets from pread
        up._inflight.acquire()
        try:
            fut = up._executor().submit(up._put_stream_chunk, digest, data)
        except BaseException:
            up._inflight.release()
            raise
        self._futures.append(fut)


def fetch_file(store: ObjectStore, entry: FileEntry, dest: str) -> None:
    """Reassemble ``entry`` at ``dest``, verifying every chunk's digest,
    length and recorded offset (a corrupt or truncated chunk fails the
    fetch, never a silent torn file — the staged ``.part`` only replaces
    ``dest`` when complete)."""
    os.makedirs(os.path.dirname(dest), exist_ok=True)
    tmp = dest + ".part"
    with open(tmp, "wb") as f:
        pos = 0
        for digest, offset, nbytes in entry.chunks:
            if offset != pos:
                raise ObjectStoreError(
                    f"chunk {digest[:12]}… of {entry.name}: recorded "
                    f"offset {offset} does not tile the file (at {pos})")
            data = store.get(chunk_key(digest))
            if len(data) != nbytes or \
                    hashlib.sha256(data).hexdigest() != digest:
                raise ObjectStoreError(
                    f"chunk {digest[:12]}… of {entry.name} is corrupt "
                    f"({len(data)} bytes vs recorded {nbytes})")
            f.write(data)
            pos += nbytes
    if os.path.getsize(tmp) != entry.size:
        raise ObjectStoreError(
            f"{entry.name}: reassembled size {os.path.getsize(tmp)} != "
            f"recorded {entry.size}")
    os.replace(tmp, dest)


class ChunkCache:
    """A node-local content-addressed chunk cache: files named by sha256
    digest under ``root``, so "do I already hold this chunk" is a stat
    and every hit is **re-verified by digest on read** — a cache file
    corrupted on disk is evicted and reads as a miss (forcing a refetch)
    rather than poisoning a reassembled checkpoint.

    This is what makes a deploy swap a *delta*: chunks pulled for entry
    N stay cached, so entry N+1 only fetches the digests it does not
    share with N (the dedup ratio of the underlying store, ~3% on a
    fine-tune publish)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, digest: str) -> str:
        return os.path.join(self.root, digest)

    def __contains__(self, digest: str) -> bool:
        return os.path.exists(self._path(digest))

    def get(self, digest: str, nbytes: int) -> Optional[bytes]:
        """→ verified chunk bytes, or ``None`` on miss *or* corruption
        (the corrupt file is removed so the caller's refetch repairs the
        cache)."""
        try:
            with open(self._path(digest), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return None
        if len(data) != nbytes or hashlib.sha256(data).hexdigest() != digest:
            try:
                os.remove(self._path(digest))
            except OSError:
                pass
            return None
        return data

    def put(self, digest: str, data: bytes) -> None:
        """Atomic insert (tmp + rename): a crash mid-put never leaves a
        torn cache file that a later get would have to evict."""
        tmp = self._path(digest) + f".tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._path(digest))


def fetch_file_delta(store: ObjectStore, entry: FileEntry, dest: str,
                     cache: ChunkCache) -> Dict[str, int]:
    """:func:`fetch_file` through a :class:`ChunkCache`: cached chunks
    are read (and digest-re-verified) locally, only absent ones hit the
    object store, and every pulled chunk lands in the cache for the next
    entry's delta.  Same torn-file guarantee — the staged ``.part`` only
    replaces ``dest`` when every chunk verified.

    → transfer stats: ``bytes_fetched``/``chunks_fetched`` (pulled from
    the store), ``bytes_cached``/``chunks_cached`` (served locally), and
    ``chunks_corrupt`` (cache hits that failed digest verify and were
    refetched) — the numerator of the ``serve_swap_delta_ratio`` gate."""
    stats = {"bytes_fetched": 0, "chunks_fetched": 0,
             "bytes_cached": 0, "chunks_cached": 0, "chunks_corrupt": 0}
    os.makedirs(os.path.dirname(dest), exist_ok=True)
    tmp = dest + ".part"
    with open(tmp, "wb") as f:
        pos = 0
        for digest, offset, nbytes in entry.chunks:
            if offset != pos:
                raise ObjectStoreError(
                    f"chunk {digest[:12]}… of {entry.name}: recorded "
                    f"offset {offset} does not tile the file (at {pos})")
            had = digest in cache
            data = cache.get(digest, nbytes)
            if data is None:
                if had:
                    stats["chunks_corrupt"] += 1
                data = store.get(chunk_key(digest))
                if len(data) != nbytes or \
                        hashlib.sha256(data).hexdigest() != digest:
                    raise ObjectStoreError(
                        f"chunk {digest[:12]}… of {entry.name} is corrupt "
                        f"({len(data)} bytes vs recorded {nbytes})")
                cache.put(digest, data)
                stats["bytes_fetched"] += nbytes
                stats["chunks_fetched"] += 1
            else:
                stats["bytes_cached"] += nbytes
                stats["chunks_cached"] += 1
            f.write(data)
            pos += nbytes
    if os.path.getsize(tmp) != entry.size:
        raise ObjectStoreError(
            f"{entry.name}: reassembled size {os.path.getsize(tmp)} != "
            f"recorded {entry.size}")
    os.replace(tmp, dest)
    return stats
