"""Portability (paper §6.3): identical application code runs on FTI, SCR,
or VeloC — the backend comes from OPENCHK_BACKEND, zero source changes.

Run:  PYTHONPATH=src python examples/multibackend_portability.py
"""
import os
import shutil

import jax.numpy as jnp

from repro.core.context import CheckpointConfig, CheckpointContext


def application(ckpt_dir: str) -> dict:
    """The app: no backend name anywhere in this function."""
    state = {"x": jnp.zeros(8), "step": jnp.int32(0)}
    ctx = CheckpointContext(CheckpointConfig(dir=ckpt_dir))
    state = ctx.load(state)
    for t in range(int(state["step"]), 20):
        state = {"x": state["x"] + 1.0, "step": jnp.int32(t + 1)}
        ctx.store(state, id=t + 1, level=1, if_=(t + 1) % 5 == 0)
    ctx.wait()
    stats = dict(ctx.stats)
    ctx.shutdown()
    return {"x0": float(state["x"][0]), "restarted": ctx.restarted,
            "stats": stats}


def main():
    for backend in ("fti", "scr", "veloc"):
        d = f"/tmp/openchk-port-{backend}"
        shutil.rmtree(d, ignore_errors=True)
        os.environ["OPENCHK_BACKEND"] = backend      # the ONLY difference
        first = application(d)
        again = application(d)                       # restart path
        print(f"{backend:6s} x0={first['x0']:.0f} "
              f"restart-detected={again['restarted']} stats={first['stats']}")
        shutil.rmtree(d, ignore_errors=True)
    print("same source, three backends ✓")


if __name__ == "__main__":
    main()
