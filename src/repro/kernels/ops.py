"""Jit'd public wrappers for the checkpoint kernels.

Dispatch: Pallas kernels on TPU; vectorized jnp oracle (ref.py) on CPU —
so the diff engine runs everywhere, and tests can force the Pallas path in
``interpret=True`` mode to validate the kernels bit-exactly against ref.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import blockhash as bh
from repro.kernels import diffpack as dp
from repro.kernels import ref

DEFAULT_BLOCK_BYTES = 65_536      # 64 KiB — FTI dCP-scale block granularity


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def as_u32_blocks(x: jnp.ndarray, block_bytes: int = DEFAULT_BLOCK_BYTES
                  ) -> Tuple[jnp.ndarray, int]:
    """Bitcast any array to (n_blocks, block_elems) uint32, zero-padded.
    Returns (blocks, n_blocks). Pads so the Pallas tile grid divides evenly."""
    assert block_bytes % 4 == 0
    be = block_bytes // 4
    flat = x.reshape(-1)
    itemsize = jnp.dtype(flat.dtype).itemsize
    if itemsize == 2:
        # bit-PACK pairs into u32 (little-endian, raw-byte-consistent with
        # numpy .tobytes() — required so diff payloads replay into raw
        # byte buffers on restore)
        u16 = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        pad = (-u16.shape[0]) % 2
        u16 = jnp.pad(u16, (0, pad))
        flat = jax.lax.bitcast_convert_type(u16.reshape(-1, 2), jnp.uint32)
    elif itemsize == 4:
        flat = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif itemsize == 8:
        flat = jax.lax.bitcast_convert_type(
            flat.reshape(-1, 1), jnp.uint32).reshape(-1)
    elif itemsize == 1:
        u8 = jax.lax.bitcast_convert_type(flat, jnp.uint8) \
            if flat.dtype != jnp.uint8 else flat
        pad = (-u8.shape[0]) % 4
        u8 = jnp.pad(u8, (0, pad))
        flat = jax.lax.bitcast_convert_type(u8.reshape(-1, 4), jnp.uint32)
    else:
        raise TypeError(f"unsupported dtype {x.dtype}")
    n = flat.shape[0]
    n_blocks = max(1, -(-n // be))
    pad_rows = (-n_blocks) % bh.BR if _use_pallas() else 0
    total = (n_blocks + pad_rows) * be
    flat = jnp.pad(flat, (0, total - n))
    return flat.reshape(n_blocks + pad_rows, be), n_blocks


@functools.partial(jax.jit, static_argnames=("block_bytes",))
def blockhash(x: jnp.ndarray, block_bytes: int = DEFAULT_BLOCK_BYTES
              ) -> jnp.ndarray:
    """Array → (n_blocks, 2) uint32 digest (64-bit per block)."""
    blocks, n_blocks = as_u32_blocks(x, block_bytes)
    if _use_pallas() and blocks.shape[1] % bh.BE == 0:
        h = bh.blockhash2_pallas(blocks)
    else:
        h = ref.blockhash2_ref(blocks)
    return h[:n_blocks]


@functools.partial(jax.jit, static_argnames=("block_bytes", "n_dirty"))
def pack_dirty(x: jnp.ndarray, dirty_idx: jnp.ndarray, n_dirty: int,
               block_bytes: int = DEFAULT_BLOCK_BYTES) -> jnp.ndarray:
    """Gather ``n_dirty`` blocks (static count — pad idx with 0s and slice
    host-side) → (n_dirty, block_elems) uint32."""
    blocks, _ = as_u32_blocks(x, block_bytes)
    idx = dirty_idx[:n_dirty]
    if _use_pallas():
        return dp.diffpack_pallas(blocks, idx)
    return ref.diffpack_ref(blocks, idx)


def dirty_indices(h_new: np.ndarray, h_old: Optional[np.ndarray]) -> np.ndarray:
    """Host-side dirty map: blocks whose 64-bit digest changed."""
    if h_old is None:
        return np.arange(h_new.shape[0], dtype=np.int32)
    neq = np.any(np.asarray(h_new) != np.asarray(h_old), axis=1)
    return np.nonzero(neq)[0].astype(np.int32)


def digest_fingerprint(digests) -> str:
    """Collapse a per-block digest table (the device blockhash output)
    into one short hex key.  blake2b over the raw digest bytes: the table
    is tiny (16 B per 64 KiB block), so this costs microseconds while
    standing in for a content hash of the whole leaf — the identity the
    fused upload path uses to reuse chunk layouts without host hashing."""
    import hashlib
    raw = np.ascontiguousarray(np.asarray(digests)).tobytes()
    return hashlib.blake2b(raw, digest_size=16).hexdigest()
