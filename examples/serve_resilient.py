"""Resilient serving: checkpoint the decode state, kill the server, resume
generation without re-running prefill.

Run:  PYTHONPATH=src python examples/serve_resilient.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.context import CheckpointConfig, CheckpointContext
from repro.models.zoo import build_model
from repro.serve.engine import ServingEngine, WeightsHandle

CKPT = "/tmp/openchk-serve-example"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 cfg.vocab_size, jnp.int32)

    # server #1: prefill, generate 10 tokens, checkpoint, "crash".
    # Weights are an explicit epoch-tagged handle — set_weights is the
    # only mutation path (the deploy subscriber swaps through it too)
    eng = ServingEngine(model, WeightsHandle(params=params), batch=2,
                        max_len=64)
    eng.prefill(prompts)
    first = eng.generate(10)
    ctx = CheckpointContext(CheckpointConfig(dir=CKPT))
    ctx.store(eng.get_state(), id=int(eng.get_state().pos), level=1)
    ctx.wait()
    ctx.shutdown()
    print(f"server 1 generated: {first[0].tolist()} … crash!")

    # server #2: fresh process — restore, NO prefill, continue.  The
    # weights arrive via the one mutation path: an atomic handle swap
    eng2 = ServingEngine(model, model.init(jax.random.PRNGKey(9)),
                         batch=2, max_len=64)
    swapped = eng2.set_weights(WeightsHandle(params=params))
    assert eng2.weights.epoch == swapped.epoch > 0
    template = eng2.model  # engine state template comes from a cold cache
    cold = type(eng.get_state())(
        caches=model.init_caches(2, 64),
        pos=jnp.int32(0),
        last_token=jnp.zeros((2, 1), jnp.int32))
    ctx2 = CheckpointContext(CheckpointConfig(dir=CKPT))
    restored = ctx2.load(cold)
    assert ctx2.restarted, "no serving checkpoint found"
    eng2.set_state(restored)
    ctx2.shutdown()
    more = eng2.generate(10)
    print(f"server 2 resumed at pos {int(restored.pos)}, "
          f"continued: {more[0].tolist()}")

    # ground truth: uninterrupted generation matches
    eng3 = ServingEngine(model, params, batch=2, max_len=64)
    eng3.prefill(prompts)
    full = eng3.generate(20)
    assert full[:, 10:].tolist() == more.tolist(), "divergence after restore!"
    print("resumed continuation matches uninterrupted generation ✓")


if __name__ == "__main__":
    main()
