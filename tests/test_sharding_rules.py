"""Sharding rules need a multi-device mesh → run the assertions in a
subprocess with forced host devices (device count locks at jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_arch
    from repro.dist.context import use_mesh, resolve_spec, data_axes
    from repro.dist.sharding import param_shardings, batch_sharding
    from repro.models.zoo import build_model
    from jax.tree_util import tree_flatten_with_path
    from repro.dist.sharding import _path_str

    mesh = jax.make_mesh((4, 4), ("data", "model"))

    # 1) divisibility-aware resolve_spec
    assert resolve_spec(mesh, ("model",), (16,)) == P("model")
    assert resolve_spec(mesh, ("model",), (14,)) is None        # 14 % 4 != 0
    assert resolve_spec(mesh, (None, "model"), (3, 8)) == P(None, "model")
    assert resolve_spec(mesh, ("data", "model"), (8, 14)) == P("data", None)

    # 2) param rules: attention/mlp weights sharded on flat feature dims
    cfg = get_arch("mixtral-8x7b")
    m = build_model(cfg)
    ps = param_shardings(mesh, m.param_struct())
    leaves = {(_path_str(p)): s for p, s in
              tree_flatten_with_path(ps)[0]}
    def spec(name):
        return next(v.spec for k, v in leaves.items() if k.endswith(name))
    # stacked layer params carry a leading (n_groups,) dim → leading None
    assert spec("attn/wq") == P(None, None, "model")
    assert spec("attn/wo") == P(None, "model", None)
    # mixtral E=8, 8%4==0 → expert-parallel over E (dim 1 after stack dim)
    assert spec("moe_w_gate") == P(None, "model", None, None)
    assert spec("embed") == P("model", None)      # 32000 % 4 == 0
    assert spec("lm_head") == P(None, "model")

    # 3) granite vocab 49155 NOT divisible → falls to hidden dim
    cfg2 = get_arch("granite-moe-3b-a800m")
    ps2 = param_shardings(mesh, build_model(cfg2).param_struct())
    leaves2 = {(_path_str(p)): s for p, s in tree_flatten_with_path(ps2)[0]}
    emb = next(v.spec for k, v in leaves2.items() if k.endswith("embed"))
    assert emb == P(None, "model"), emb

    # 4) batch sharding folds pod into data on multi-pod meshes
    bs = batch_sharding(mesh, 2)
    assert bs.spec == P("data", None)
    mesh3 = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
    bs3 = batch_sharding(mesh3, 2)
    assert bs3.spec == P(("pod", "data"), None)

    # 5) shard_hint no-ops without active mesh / disabled hints
    from repro.dist.context import shard_hint, constraint_hints
    x = jnp.ones((8, 8))
    assert shard_hint(x, "data", None) is x      # no active mesh
    with use_mesh(mesh):
        y = shard_hint(x, "data", None)
        assert y is not x
        with constraint_hints(False):
            assert shard_hint(x, "data", None) is x

    # 6) cache shardings: an explicit Protect axis clause pins the batch
    # dim; the size heuristic only covers unmatched leaves.  Ambiguous
    # case: global_batch == n_groups == 4, so the heuristic would shard
    # the layer-stack dim (dim 0) instead of batch (dim 1).
    from repro.core.protect import Protect
    from repro.dist.sharding import cache_shardings
    amb = {"kv": jnp.zeros((4, 8, 4, 64))}    # (n_groups, B=8, heads, dh)
    cs_h = jax.tree.leaves(cache_shardings(mesh, amb, 4))[0]
    assert cs_h.spec == P("data", None, "model", None), cs_h.spec
    cs_e = jax.tree.leaves(cache_shardings(
        mesh, amb, 4, protects=[Protect("**", axis={"batch": 1})]))[0]
    assert cs_e.spec == P(None, "data", None, "model"), cs_e.spec
    # out-of-range explicit dim (cache-union placeholders) → heuristic
    ph = jax.tree.leaves(cache_shardings(
        mesh, {"z": jnp.zeros((0,))}, 4,
        protects=[Protect("**", axis={"batch": 1})]))[0]
    assert ph.spec == P(None) or ph.spec == P(), ph.spec
    # the cache constructors publish the metadata (models/zoo carrier)
    from repro.models.zoo import build_model as bm
    mdl = bm(get_arch("mixtral-8x7b"))
    specs = mdl.cache_protects()
    assert specs and specs[0].axis == {"batch": 1}

    print("SHARDING-OK")
""")


def test_sharding_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420, cwd=".")
    assert "SHARDING-OK" in r.stdout, r.stdout + r.stderr
