"""Unified telemetry plane: span tracing, metrics registry, live health.

Three stdlib-only leaves every layer of the stack can import without
cycles (the same layering rule as ``repro.chaos.inject``):

- :mod:`repro.telemetry.trace` — thread-safe span/instant recorder with a
  no-op fast path when disabled, exporting Chrome trace-event JSON
  (perfetto-loadable).  The CP thread, transfer-pool workers, supervisor
  and serving replicas land on one timeline; multi-process runs merge
  per-process files from a shared ``OPENCHK_TRACE_DIR``.
- :mod:`repro.telemetry.metrics` — counter/gauge/histogram registry fed
  by the same instrumentation points, with a JSON snapshot and
  Prometheus text exposition.
- :mod:`repro.telemetry.health` — a real stdlib HTTP endpoint
  (``/healthz`` / ``/readyz`` / ``/metrics``) per serving replica and
  per supervisor; readiness flips with ``WeightsHandle`` epoch swaps.

``repro.tools.chktrace`` summarizes an exported trace (critical path of
a store, goodput timeline, span-measured MTTR) with ``--json`` for CI.
"""
from repro.telemetry import health, metrics, trace

__all__ = ["trace", "metrics", "health"]
