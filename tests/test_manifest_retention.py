"""Manifest commit protocol, crash-window recovery, diff-chain retention."""
import os

import numpy as np
import pytest

from repro.core import manifest as mf
from repro.core.comm import LocalComm
from repro.core.storage import CHK_DIFF, CHK_FULL, StorageConfig, StorageEngine


def test_commit_is_atomic(tmp_path):
    root = str(tmp_path)
    d = mf.begin(root, 1)
    open(os.path.join(d, "rank0.chk5"), "wb").write(b"x")
    # not committed yet → invisible
    assert mf.list_committed(root) == []
    with pytest.raises(RuntimeError):
        mf.commit(root, 1)              # no manifest → refuse
    mf.write_manifest(root, 1, {"kind": CHK_FULL})
    mf.commit(root, 1)
    assert mf.list_committed(root) == [1]
    assert mf.latest_id(root) == 1


def test_uncommitted_tmp_ignored_after_crash(tmp_path):
    """A crash between begin() and commit() leaves a .tmp dir that restart
    logic must ignore."""
    root = str(tmp_path)
    mf.begin(root, 7)                   # crashed mid-write
    assert mf.list_committed(root) == []
    assert mf.latest_id(root) is None
    mf.abort(root, 7)
    assert not os.path.exists(mf.ckpt_dir(root, 7, tmp=True))


def test_latest_pointer_fallback(tmp_path):
    """Stale/corrupt 'latest' falls back to scanning committed dirs."""
    root = str(tmp_path)
    for i in (1, 2):
        d = mf.begin(root, i)
        open(os.path.join(d, "rank0.chk5"), "wb").write(b"x")
        mf.write_manifest(root, i, {"kind": CHK_FULL})
        mf.commit(root, i)
    open(os.path.join(root, mf.LATEST), "w").write("999")   # bogus
    assert mf.latest_id(root) == 2


def test_merge_commit_shared_tier(tmp_path):
    """Second rank committing to an existing dir merges instead of clobbering."""
    root = str(tmp_path)
    d = mf.begin(root, 3)
    open(os.path.join(d, "rank0.chk5"), "wb").write(b"a")
    mf.write_manifest(root, 3, {"kind": CHK_FULL})
    mf.commit(root, 3)
    d = mf.begin(root, 3)
    open(os.path.join(d, "rank1.chk5"), "wb").write(b"b")
    mf.write_manifest(root, 3, {"kind": CHK_FULL})
    mf.commit(root, 3)
    files = sorted(os.listdir(mf.ckpt_dir(root, 3)))
    assert "rank0.chk5" in files and "rank1.chk5" in files


def _engine(tmp_path, **kw):
    cfg = StorageConfig(root=str(tmp_path / "shared"), **kw)
    return StorageEngine(cfg, LocalComm(str(tmp_path / "nl")))


def test_diff_chain_retention_keeps_base(tmp_path):
    """Pruning must never drop the FULL base of a retained diff chain."""
    eng = _engine(tmp_path, keep_last_full=1, block_bytes=256)
    arr = {"x": np.arange(4096, dtype=np.float32)}
    eng.store(arr, 1, level=1, kind=CHK_FULL)
    for i in range(2, 6):
        arr = {"x": arr["x"].copy()}
        arr["x"][i] = -1.0
        eng.store(arr, i, level=1, kind=CHK_DIFF)
    ids = mf.list_committed(eng.local_root)
    assert 1 in ids, "FULL base pruned while diffs depend on it"
    named, meta = eng.load_latest()
    assert named["x"][5] == -1.0 and named["x"][4] == -1.0


def test_retention_prunes_old_chains(tmp_path):
    eng = _engine(tmp_path, keep_last_full=2, block_bytes=256)
    arr = np.arange(1024, dtype=np.float32)
    for i in range(1, 8):
        eng.store({"x": arr + i}, i, level=1, kind=CHK_FULL)
    ids = mf.list_committed(eng.local_root)
    assert len(ids) == 2 and ids == [6, 7]
    named, _ = eng.load_latest()
    assert named["x"][0] == 7.0


def test_corrupt_latest_checkpoint_falls_back(tmp_path):
    """A corrupted newest checkpoint must not block restart — the engine
    walks back to the previous restorable one."""
    eng = _engine(tmp_path, keep_last_full=3)
    eng.store({"x": np.float32(1.0)}, 1, level=1)
    eng.store({"x": np.float32(2.0)}, 2, level=1)
    # corrupt ckpt-2's payload
    p = os.path.join(mf.ckpt_dir(eng.local_root, 2), "rank0.chk5")
    raw = bytearray(open(p, "rb").read())
    raw[12] ^= 0xFF
    open(p, "wb").write(raw)
    named, meta = eng.load_latest()
    assert named["x"] == np.float32(1.0)
    assert meta["id"] == 1
