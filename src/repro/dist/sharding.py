"""Path-based parameter sharding rules over ``param_struct()`` pytrees.

Rule resolution order (per leaf):

1. The leaf's *name* (last path component) selects an ordered list of
   candidate axis templates. Templates describe the **trailing** dims of
   the leaf; any extra leading dims (the stacked-layer ``(n_groups, ...)``
   dim from ``lax.scan`` stacking, encdec's ``(L, ...)``) are replicated —
   so one rule covers a layer whether it is stacked or not.
2. Candidates are tried in order through the divisibility-aware
   ``resolve_spec``; the first template that keeps at least one axis wins.
   This is how the vocab-parallel embedding falls back to hidden-dim
   sharding when the vocabulary does not divide the model axis (granite's
   49155), and how expert-parallel MoE weights fall back to feature-dim
   sharding when the expert count does not.
3. No rule, or every candidate dissolved → fully replicated.

Conventions follow Megatron/MaxText tensor parallelism: projections *into*
the sharded dimension are column-parallel (output features on MODEL),
projections back to the residual stream are row-parallel (input features
on MODEL), embeddings are vocab-parallel when divisible.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path

# the canonical slash-path form is core/protect.py's — one implementation,
# so selector matching and rule lookup agree on names like "w.q" that the
# old per-module strip("[]'\".") mangled
from repro.core.protect import Protect, _path_str
from repro.dist.context import MODEL, data_axes, resolve_spec


# column-parallel (output features on MODEL) / row-parallel (input features)
_COL: List[Tuple] = [(None, MODEL)]
_ROW: List[Tuple] = [(MODEL, None)]
# expert-parallel over E first, feature-parallel fallback
_MOE_IN = [(MODEL, None, None), (None, None, MODEL)]
_MOE_OUT = [(MODEL, None, None), (None, MODEL, None)]

_RULES = {
    # embeddings: vocab-parallel, hidden-dim fallback
    "embed": [(MODEL, None), (None, MODEL)],
    "lm_head": [(None, MODEL), (MODEL, None)],
    # attention (GQA + rwkv share wk/wv/wo names; shapes differ, rules don't)
    "wq": _COL, "wk": _COL, "wv": _COL, "wo": _ROW,
    "bq": [(MODEL,)], "bk": [(MODEL,)], "bv": [(MODEL,)],
    # MLA projections
    "w_dq": _COL, "w_uq": _COL, "w_dkv": _COL, "w_uk": _COL, "w_uv": _COL,
    # dense MLP
    "w_gate": _COL, "w_up": _COL, "w_down": _ROW,
    # MoE experts (leading E dim)
    "moe_w_gate": _MOE_IN, "moe_w_up": _MOE_IN, "moe_w_down": _MOE_OUT,
    # rwkv time-mix / mamba in-projections
    "wr": _COL, "wg": _COL, "w_z": _COL, "w_x": _COL,
}


def _resolve_rules(mesh: Mesh, name: str, shape: Sequence[int]) -> Optional[P]:
    for template in _RULES.get(name, ()):
        if len(template) > len(shape):
            continue
        full = (None,) * (len(shape) - len(template)) + tuple(template)
        spec = resolve_spec(mesh, full, shape)
        if spec is not None:
            return spec
    return None


def param_shardings(mesh: Mesh, params: Any) -> Any:
    """Pytree of ``NamedSharding`` matching ``params`` (arrays or
    ShapeDtypeStructs), resolved through the rule table; unmatched leaves
    (norm scales, routers, decay params, scalars) are replicated."""

    def one(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        spec = _resolve_rules(mesh, name, leaf.shape)
        return NamedSharding(mesh, spec if spec is not None else P())

    return tree_map_with_path(one, params)


def _folded_data(mesh: Mesh):
    dax = data_axes(mesh)
    if not dax:
        return None
    return dax[0] if len(dax) == 1 else dax


def batch_sharding(mesh: Mesh, ndim: int, *,
                   batch_divisible: bool = True) -> NamedSharding:
    """Batch-dim sharding over the folded data axes (``pod`` folds into
    ``data`` on multi-pod meshes). ``batch_divisible=False`` (e.g. a
    global batch of 1) replicates."""
    daxis = _folded_data(mesh)
    if daxis is None or not batch_divisible:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(daxis, *([None] * (ndim - 1))))


def cache_shardings(mesh: Mesh, caches: Any, global_batch: int, *,
                    seq_axis_sharded: bool = False,
                    protects: Optional[Sequence[Protect]] = None) -> Any:
    """Decode-cache shardings (stacked ``(L, B, C, ...)`` leaves).

    The batch dim is located by **explicit axis metadata first**: a
    ``Protect(selector, axis={"batch": d})`` spec from the cache
    constructor (``models/zoo.Model.cache_protects``) pins the batch dim
    for every matching leaf; only leaves with no governing spec fall back
    to the size-match heuristic (first dim equal to ``global_batch`` —
    ambiguous when e.g. a head count or window equals the batch size).
    An explicit dim outside the leaf's rank (shape-(0,) cache-union
    placeholders) falls back too.

    Default: shard the batch dim over the folded data axes. With
    ``seq_axis_sharded`` (long-context, batch too small to split) the
    cache-sequence dim — the dim after batch — is sharded instead, which
    is what makes ``shard_decode_kv``'s partial-softmax decode line up
    with the cache layout. A head dim two past batch shards on MODEL when
    divisible; everything that does not divide stays replicated.
    """
    daxis = _folded_data(mesh)
    dsize = 1
    if daxis is not None:
        for a in (daxis if isinstance(daxis, tuple) else (daxis,)):
            dsize *= mesh.shape[a]
    tp = mesh.shape.get(MODEL, 1)
    specs = [s for s in (protects or []) if s.axis and "batch" in s.axis]

    def one(path, leaf):
        shape = leaf.shape
        dims: list = [None] * len(shape)
        p = _path_str(path)
        bdim = None
        for spec in specs:
            if spec.matches(p):
                d = spec.axis["batch"]
                if 0 <= d < len(shape):
                    bdim = d
                break
        if bdim is None:
            bdim = next((i for i, d in enumerate(shape)
                         if d == global_batch), None)
        if bdim is not None and daxis is not None:
            if seq_axis_sharded:
                sdim = bdim + 1
                if sdim < len(shape) and shape[sdim] % dsize == 0:
                    dims[sdim] = daxis
            elif shape[bdim] % dsize == 0:
                dims[bdim] = daxis
        if bdim is not None and MODEL in mesh.axis_names:
            hdim = bdim + 2
            if (hdim < len(shape) and dims[hdim] is None
                    and shape[hdim] % tp == 0):
                dims[hdim] = MODEL
        return NamedSharding(mesh, P(*dims))

    return tree_map_with_path(one, caches)
