"""FTI-like backend: memory-mode, multi-level L1–L4, differential
checkpointing, CP-dedicated threads (the feature superset — §3 of the paper).

Native API mirrors FTI: ``protect / status / recover / checkpoint /
finalize``. Protect registers (id, name, array); checkpoint writes all
protected regions; recover returns them by id after a restart.

The heavy lifting is the shared pipeline (Plan → Pack → Place → Commit);
this class only translates FTI's protect-registry call protocol onto it.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.backends.base import Backend
from repro.core.comm import Communicator
from repro.core.storage import (
    CHK_DIFF,
    CHK_FULL,
    StorageConfig,
    StoreReport,
    StoreRequest,
)


class FTIBackend(Backend):
    name = "fti"
    supports_diff = True
    supports_dedicated_thread = True
    supports_incremental = True
    max_level = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 dedicated_thread: bool = True):
        super().__init__(cfg, comm, dedicated_thread=dedicated_thread)
        self._protected: Dict[int, Tuple[str, np.ndarray]] = {}

    # ----------------------- native FTI-style API ---------------------- #

    def protect(self, pid: int, name: str, arr) -> None:
        self._protected[pid] = (name, arr)

    def status(self) -> bool:
        """FTI_Status: is there anything to recover?"""
        self.tcl_wait()
        return self.engine.load_latest() is not None

    def recover(self) -> Dict[int, np.ndarray]:
        """FTI_Recover: refill protected regions from the newest checkpoint."""
        self.tcl_wait()
        got = self.engine.load_latest()
        if got is None:
            raise RuntimeError("FTI: no checkpoint to recover")
        named, _ = got
        out: Dict[int, np.ndarray] = {}
        for pid, (name, _old) in self._protected.items():
            key = f"p{pid}/{name}"
            if key not in named:
                raise RuntimeError(f"FTI: protected id {pid} ({name}) missing")
            out[pid] = named[key]
        self.stats["loads"] += 1
        return out

    def checkpoint(self, ckpt_id: int, level: int,
                   differential: bool = False) -> Optional[StoreReport]:
        named = {f"p{pid}/{name}": np.asarray(arr)
                 for pid, (name, arr) in self._protected.items()}
        return self.tcl_store(StoreRequest(
            named=named, ckpt_id=ckpt_id, level=level,
            kind=CHK_DIFF if differential else CHK_FULL))

    def checkpoint_wait(self) -> None:
        self.tcl_wait()

    def finalize(self) -> None:
        self.tcl_finalize()
