"""Declarative fault scenarios: store → inject → restart → verify-bit-exact.

Each scenario drives the *real* stack — backends built via
``make_backend`` over a :class:`~repro.core.comm.SimulatedCluster` (or a
single-rank ``LocalComm``), faults armed through the chaos registry at the
same sites production code hits — and ends with a bit-exact comparison of
the restored state against what was stored.  The contract every scenario
asserts is the one "Checkpoint-Restart Libraries Must Become More Fault
Tolerant" demands: a fault may cost time (a retry, a weaker tier), it may
never cost *data* — ``data_loss_bytes`` is 0 or the scenario fails.

The matrix (× fti/scr/veloc backends):

    node-loss-mid-store   a node dies while another rank's store is in
                          flight; the victim restores its last committed
                          state from the partner replica
    straggler-demotion    a straggler's store dies before its partner
                          replica ships; its incomplete checkpoint blocks
                          nobody (quorum), and the straggler falls back
                          one id with zero loss vs its last commit
    mesh-shrink           world 4 → 2 after losing two nodes: the
                          survivors resume from the sharded checkpoint
                          via ft/elastic without re-initialization
    objstore-outage       the bucket goes dark: catalog discovery falls
                          back to directory tiers, an L4 store degrades
                          to global-dir durability (nothing lost), and
                          the post-outage publish restores from the
                          bucket alone
    corrupt-chunk         a chunk fetched on restore is corrupted in
                          transit: digest verification rejects it (no
                          silent bad bits), the retry restores bit-exact

Compound scenarios overlap two faults at once — the regime where a
checkpoint library's recovery paths actually interact:

    node-loss-during-outage   a node dies while the bucket is dark:
                          partner recovery works mid-outage, and the
                          post-outage bucket alone restores everything
    corrupt-chunk-straggler   one store is both slow (straggling upload)
                          and silently corrupted pre-digest; restore
                          rejects the poisoned container and falls back
                          one id, bit-exact
    heartbeat-loss-mid-gc a worker goes silent exactly while the
                          retention GC dies mid-sweep; the stale mark
                          resumes safely and the heartbeat gap registers
                          as a real MTBF failure observation

``supervised-kill`` (in :data:`SUPERVISED`, spawned on demand) runs the
real multi-process path: ``launch/train.py --supervise`` workers killed
by an ``OPENCHK_CHAOS`` exit spec, asserting kill-detect → backoff →
resume-from-checkpoint with restart-durable fault counters.

Reports are machine-readable dicts: faults fired, recovery path taken,
recovery wall time, MTTR, data loss in bytes.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.backends.registry import make_backend
from repro.chaos import inject as chaos
from repro.chaos.cadence import MTBFEstimator
from repro.core import manifest as mf
from repro.core.comm import LocalComm, SimulatedCluster
from repro.core.resharding import save_sharded
from repro.core.storage import CHK_FULL, StorageConfig
from repro.ft.detector import Heartbeat
from repro.ft.elastic import rescale_restore
from repro.ft.straggler import commit_if_quorum, validate_quorum
from repro.objstore.client import ObjectStoreError
from repro.objstore.gc import GC_MARK_KEY
from repro.redundancy.groups import Topology
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace

BACKENDS = ("fti", "scr", "veloc")
WORLD = 4


@dataclass
class ScenarioResult:
    name: str
    backend: str
    ok: bool
    faults_fired: int
    recovery_path: str
    recovery_s: float
    data_loss_bytes: int
    #: mean time to repair — death/fault detection to verified recovery;
    #: defaults to recovery_s for scenarios whose restore walk IS the repair
    mttr_s: Optional[float] = None
    detail: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "backend": self.backend, "ok": self.ok,
            "faults_fired": self.faults_fired,
            "recovery_path": self.recovery_path,
            "recovery_s": round(self.recovery_s, 4),
            "mttr_s": round(self.mttr_s if self.mttr_s is not None
                            else self.recovery_s, 4),
            "data_loss_bytes": self.data_loss_bytes,
            "detail": self.detail,
        }


SCENARIOS: Dict[str, Callable[[str, str], ScenarioResult]] = {}
#: scenarios that spawn real supervised worker processes — opt-in (slow),
#: run once (not per backend matrix cell) via ``--include-supervised``
SUPERVISED: Dict[str, Callable[[str, str], ScenarioResult]] = {}


def scenario(name: str, table: Optional[Dict[str, Callable]] = None):
    def deco(fn):
        (SCENARIOS if table is None else table)[name] = fn
        fn.scenario_name = name
        return fn
    return deco


# -- helpers ----------------------------------------------------------------
def _payload(rank: int, ckpt_id: int) -> Dict[str, np.ndarray]:
    """Deterministic per-(rank, id) state — the bit-exact reference."""
    base = float(rank * 1000 + ckpt_id)
    return {
        "w": (np.arange(512, dtype=np.float32) + base),
        "m": np.full((16, 16), base / 7.0, np.float32),
        "step": np.asarray(np.int64(ckpt_id)),
    }


def _loss_bytes(expect: Dict[str, np.ndarray],
                got: Optional[Dict[str, Any]]) -> int:
    """Bytes of *expect* not bit-exactly reproduced in *got*."""
    if got is None:
        return sum(np.asarray(v).nbytes for v in expect.values())
    loss = 0
    for k, v in expect.items():
        v = np.asarray(v)
        g = got.get(k)
        if g is None:
            loss += v.nbytes
            continue
        g = np.asarray(g)
        if g.shape != v.shape or g.dtype != v.dtype:
            loss += v.nbytes
        elif v.nbytes:
            vb = np.frombuffer(v.tobytes(), np.uint8)
            gb = np.frombuffer(g.tobytes(), np.uint8)
            loss += int(np.count_nonzero(vb != gb))
    return loss


def _cluster_backends(workdir: str, backend: str, world: int = WORLD):
    cluster = SimulatedCluster(os.path.join(workdir, "cluster"), world)
    cfg = StorageConfig(root=os.path.join(workdir, "shared"), group_size=4)
    kw = {"dedicated_thread": False} if backend == "fti" else {}
    backends = [make_backend(cfg, c, backend, **kw) for c in cluster.comms]
    return cluster, cfg, backends, kw


def _restart_backend(cfg, comm, backend: str, kw):
    """A fresh backend over the same comm — the restarted process."""
    return make_backend(cfg, comm, backend, **kw)


def _store_all(backends, ckpt_id: int, level: int) -> None:
    for r, b in enumerate(backends):
        b.tcl_store(_payload(r, ckpt_id), ckpt_id, level, CHK_FULL)
        b.tcl_wait()


# -- scenarios --------------------------------------------------------------
@scenario("node-loss-mid-store")
def node_loss_mid_store(workdir: str, backend: str) -> ScenarioResult:
    """Node 2 dies while rank 3's next store is mid-place; rank 2 restores
    its last committed checkpoint from the partner replica."""
    cluster, cfg, backends, kw = _cluster_backends(workdir, backend)
    _store_all(backends, 1, level=2)
    _store_all(backends, 2, level=2)          # the last good commit
    # rank 3's store of id=3 dies in Place — a torn .tmp that must not
    # shadow the committed id=2
    chaos.arm("tier.place", mode="error", match={"rank": 3})
    torn = False
    try:
        backends[3].tcl_store(_payload(3, 3), 3, 2, CHK_FULL)
        backends[3].tcl_wait()
    except Exception:
        torn = True
    cluster.kill_node(2)                      # node loss
    t0 = time.time()
    b2 = _restart_backend(cfg, cluster.comms[2], backend, kw)
    got = b2.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(2, 2), named)
    ok = torn and loss == 0 and meta.get("recovered_via") == "partner"
    return ScenarioResult(
        "node-loss-mid-store", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss,
        detail={"torn_store_detected": torn,
                "restored_id": meta.get("id", 2)})


@scenario("straggler-demotion")
def straggler_demotion(workdir: str, backend: str) -> ScenarioResult:
    """Rank 2's id=2 store dies before its partner replica ships: the
    straggler's torn store blocks nobody, and rank 2 restarts one id back
    with zero loss vs its last commit.  The quorum rule itself is
    exercised on a shared-dir shard set (partner covers a lost shard)."""
    cluster, cfg, backends, kw = _cluster_backends(workdir, backend)
    _store_all(backends, 1, level=2)
    for r in (0, 1, 3):
        backends[r].tcl_store(_payload(r, 2), 2, 2, CHK_FULL)
        backends[r].tcl_wait()
    # the straggler: slow (delay at local place), then dead before the
    # partner tier ships its replica
    chaos.arm("tier.place", mode="delay", delay_s=0.05,
              match={"rank": 2, "tier": "local"})
    chaos.arm("tier.place", mode="error", match={"rank": 2, "tier": "partner"})
    demoted = False
    try:
        backends[2].tcl_store(_payload(2, 2), 2, 2, CHK_FULL)
        backends[2].tcl_wait()
    except Exception:
        demoted = True
    cluster.kill_node(2)
    t0 = time.time()
    b2 = _restart_backend(cfg, cluster.comms[2], backend, kw)
    got = b2.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(2, 1), named)   # last commit = id 1
    # the survivors' id=2 is intact
    survivors_ok = all(
        _loss_bytes(_payload(r, 2), backends[r].engine.load_latest()[0]) == 0
        for r in (0, 1, 3))
    # quorum commit over a multi-file shard set: rank 2's own shard 1 is
    # lost, the partner replica covers it
    topo = Topology(world=WORLD)
    qroot = os.path.join(workdir, "quorum")
    d = mf.begin(qroot, 9)
    for r in (0, 1, 3):
        open(os.path.join(d, f"rank{r}.chk5"), "wb").write(b"c" * 8)
        open(os.path.join(d, f"rank{r}.shard0.chk5"), "wb").write(b"s" * 8)
    h = topo.partner_of(2)
    open(os.path.join(d, f"rank{h}.partner2.chk5"), "wb").write(b"p")
    open(os.path.join(d, f"rank{h}.partner2.shard0.chk5"), "wb").write(b"p")
    rep = validate_quorum(d, topo)
    quorum_ok = (rep.restorable and 2 in rep.covered_by_partner
                 and (2, 0) in rep.shards_covered
                 and commit_if_quorum(qroot, 9, topo))
    ok = (demoted and loss == 0 and survivors_ok and quorum_ok)
    return ScenarioResult(
        "straggler-demotion", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss,
        detail={"demoted": demoted, "survivors_ok": survivors_ok,
                "quorum_shard_covered": quorum_ok})


@scenario("mesh-shrink")
def mesh_shrink(workdir: str, backend: str) -> ScenarioResult:
    """World 4 → 2 after two node losses: survivors resume their slices of
    the sharded checkpoint via ft/elastic — no full re-initialization."""
    cluster, cfg, backends, kw = _cluster_backends(workdir, backend)
    _store_all(backends, 1, level=2)          # per-backend baseline store
    # the sharded global state: each rank wrote its axis-0 slice
    g = (np.arange(64 * 8, dtype=np.float32).reshape(64, 8) * 0.5) - 3.0
    d = mf.begin(cfg.global_root, 2)
    rows = 64 // WORLD
    for r in range(WORLD):
        save_sharded(os.path.join(d, f"rank{r}.chk5"),
                     {"g": g[r * rows:(r + 1) * rows]},
                     {"g": r * rows}, {"g": [64, 8]})
    mf.write_manifest(cfg.global_root, 2,
                      {"kind": CHK_FULL, "level": 4, "world": WORLD})
    mf.commit(cfg.global_root, 2)
    cluster.kill_node(2)                      # the shrink: two nodes gone
    cluster.kill_node(3)
    t0 = time.time()
    loss = 0
    ckpt_ids = []
    new_world = 2
    for new_rank in range(new_world):
        got = rescale_restore([cfg.global_root], new_world, new_rank)
        if got is None:
            loss += g.nbytes // new_world
            continue
        named, ckpt_id = got
        ckpt_ids.append(ckpt_id)
        expect = g[new_rank * (64 // new_world):(new_rank + 1) * (64 // new_world)]
        loss += _loss_bytes({"g": expect}, named)
    dt = time.time() - t0
    ok = loss == 0 and ckpt_ids == [2, 2]
    return ScenarioResult(
        "mesh-shrink", backend, ok,
        faults_fired=2,                       # the two node losses
        recovery_path="elastic", recovery_s=dt, data_loss_bytes=loss,
        detail={"old_world": WORLD, "new_world": new_world,
                "restored_ids": ckpt_ids})


@scenario("objstore-outage")
def objstore_outage(workdir: str, backend: str) -> ScenarioResult:
    """The bucket goes dark: discovery falls back to directory tiers, an
    L4 store degrades to global-dir durability (zero loss), and after the
    outage a publish restores from the bucket alone."""
    cfg = StorageConfig(root=os.path.join(workdir, "shared"), group_size=1)
    comm = LocalComm(os.path.join(workdir, "node-local"))
    kw = {"dedicated_thread": False} if backend == "fti" else {}
    b = make_backend(cfg, comm, backend, **kw)
    b.tcl_store(_payload(0, 1), 1, 4, CHK_FULL)
    b.tcl_wait()
    # outage: every objstore op fails until disarmed
    outage = [chaos.arm("objstore.*", mode="error", every=1, times=None)]
    store_degraded = False
    try:
        b.tcl_store(_payload(0, 2), 2, 4, CHK_FULL)
        b.tcl_wait()
    except ObjectStoreError:
        store_degraded = True
    except Exception:
        # some backends wrap the tier error at the wait fence
        store_degraded = True
    # catalog fallback: discovery + restore must still work mid-outage
    t0 = time.time()
    b_mid = _restart_backend(cfg, comm, backend, kw)
    got_mid = b_mid.engine.load_latest()
    named_mid, meta_mid = got_mid if got_mid is not None else (None, {})
    # the store that "failed" lost nothing: its manifest committed to the
    # global dir before the publish step hit the outage
    loss_mid = _loss_bytes(_payload(0, 2), named_mid)
    mid_path = str(meta_mid.get("recovered_via"))
    # outage ends; a fresh publish, then wipe every directory tier
    chaos.registry().disarm_all()
    del outage
    b.tcl_store(_payload(0, 3), 3, 4, CHK_FULL)
    b.tcl_wait()
    shutil.rmtree(comm.node_local_dir, ignore_errors=True)
    os.makedirs(comm.node_local_dir, exist_ok=True)
    shutil.rmtree(cfg.global_root, ignore_errors=True)
    b_post = _restart_backend(cfg, comm, backend, kw)
    got = b_post.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(0, 3), named)
    ok = (store_degraded and loss_mid == 0 and loss == 0
          and meta.get("recovered_via") == "objstore")
    return ScenarioResult(
        "objstore-outage", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss + loss_mid,
        detail={"store_degraded_not_lost": store_degraded and loss_mid == 0,
                "mid_outage_recovery": mid_path})


@scenario("corrupt-chunk")
def corrupt_chunk(workdir: str, backend: str) -> ScenarioResult:
    """A chunk is corrupted in transit on restore: digest verification
    refuses it (the load fails cleanly — no silent bad bits), and the
    retry restores bit-exact from the bucket."""
    cfg = StorageConfig(root=os.path.join(workdir, "shared"), group_size=1)
    comm = LocalComm(os.path.join(workdir, "node-local"))
    kw = {"dedicated_thread": False} if backend == "fti" else {}
    b = make_backend(cfg, comm, backend, **kw)
    b.tcl_store(_payload(0, 1), 1, 4, CHK_FULL)
    b.tcl_wait()
    tier = b.engine.objstore_tier()
    chunk_keys = tier.store.list("chunks/")
    # wipe every directory tier: the bucket is the only source
    shutil.rmtree(comm.node_local_dir, ignore_errors=True)
    os.makedirs(comm.node_local_dir, exist_ok=True)
    shutil.rmtree(cfg.global_root, ignore_errors=True)
    chaos.arm("objstore.get", mode="corrupt", times=1,
              match={"key": chunk_keys[0]})
    t0 = time.time()
    b1 = _restart_backend(cfg, comm, backend, kw)
    first = b1.engine.load_latest()           # hits the corrupted fetch
    corrupt_detected = first is None or _loss_bytes(
        _payload(0, 1), first[0]) == 0
    silent_corruption = first is not None and _loss_bytes(
        _payload(0, 1), first[0]) != 0
    # the retry (spec exhausted after times=1) must restore bit-exact
    b2 = _restart_backend(cfg, comm, backend, kw)
    got = b2.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(0, 1), named)
    fired = chaos.registry().fired_count("objstore.get")
    ok = (fired >= 1 and not silent_corruption and corrupt_detected
          and loss == 0 and meta.get("recovered_via") == "objstore")
    return ScenarioResult(
        "corrupt-chunk", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss,
        detail={"chunks_in_bucket": len(chunk_keys),
                "first_load_failed_cleanly": first is None,
                "silent_corruption": silent_corruption})


# -- compound scenarios (two overlapping faults) ----------------------------
@scenario("node-loss-during-outage")
def node_loss_during_outage(workdir: str, backend: str) -> ScenarioResult:
    """Node 2 dies *while* the bucket is dark: a degraded L4 store loses
    nothing, the victim restores mid-outage from its partner replica, and
    once the outage lifts the bucket alone restores a post-outage store."""
    cluster, cfg, backends, kw = _cluster_backends(workdir, backend)
    _store_all(backends, 1, level=4)          # all ranks published to bucket
    _store_all(backends, 2, level=2)          # local + partner only
    chaos.arm("objstore.*", mode="error", every=1, times=None)
    store_degraded = False
    try:                                      # L4 store mid-outage degrades
        backends[3].tcl_store(_payload(3, 3), 3, 4, CHK_FULL)
        backends[3].tcl_wait()
    except Exception:
        store_degraded = True
    cluster.kill_node(2)                      # second fault, same window
    t0 = time.time()
    b2 = _restart_backend(cfg, cluster.comms[2], backend, kw)
    got_mid = b2.engine.load_latest()
    mttr = time.time() - t0
    named_mid, meta_mid = got_mid if got_mid is not None else (None, {})
    loss_mid = _loss_bytes(_payload(2, 2), named_mid)
    partner_ok = meta_mid.get("recovered_via") == "partner"
    # outage ends: a fresh publish must make the bucket whole again
    chaos.registry().disarm_all()
    backends[0].tcl_store(_payload(0, 4), 4, 4, CHK_FULL)
    backends[0].tcl_wait()
    for c in cluster.comms:                   # bucket is the only survivor
        shutil.rmtree(c.node_local_dir, ignore_errors=True)
        os.makedirs(c.node_local_dir, exist_ok=True)
    shutil.rmtree(cfg.global_root, ignore_errors=True)
    b0 = _restart_backend(cfg, cluster.comms[0], backend, kw)
    got = b0.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(0, 4), named)
    ok = (store_degraded and partner_ok and loss_mid == 0 and loss == 0
          and meta.get("recovered_via") == "objstore")
    return ScenarioResult(
        "node-loss-during-outage", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss + loss_mid, mttr_s=mttr,
        detail={"store_degraded_not_lost": store_degraded and loss_mid == 0,
                "mid_outage_recovery": str(meta_mid.get("recovered_via"))})


@scenario("corrupt-chunk-straggler")
def corrupt_chunk_straggler(workdir: str, backend: str) -> ScenarioResult:
    """One store is slow AND silently poisoned: a straggling upload plus a
    pre-digest chunk corruption (the chunk digest *matches* the bad bytes,
    so transport verification cannot catch it).  Restore-side container
    verification rejects the poisoned id and the walk falls back one id
    with zero loss vs the last good commit."""
    cfg = StorageConfig(root=os.path.join(workdir, "shared"), group_size=1)
    comm = LocalComm(os.path.join(workdir, "node-local"))
    kw = {"dedicated_thread": False} if backend == "fti" else {}
    b = make_backend(cfg, comm, backend, **kw)
    b.tcl_store(_payload(0, 1), 1, 4, CHK_FULL)   # the last good commit
    b.tcl_wait()
    # both faults hit id=2's store: the payload bytes flip BEFORE the
    # transport digest sees them — pre-digest on the streamed chunk path
    # (fused-pack backends), at-put for backends that upload staged files
    # — and one chunk upload straggles.  Only NEW chunks upload (dedup),
    # so id=1's published chunks cannot be the poisoned ones.
    chaos.arm("chunkstream.emit", mode="corrupt", times=1)
    chaos.arm("objstore.put", mode="corrupt", times=1)
    chaos.arm("objstore.put", mode="delay", delay_s=0.05, times=1)
    b.tcl_store(_payload(0, 2), 2, 4, CHK_FULL)   # "succeeds" — poisoned
    b.tcl_wait()
    poisoned = (chaos.registry().fired_count("chunkstream.emit")
                + chaos.registry().fired_count("objstore.put")) >= 2
    chaos.registry().disarm_all()
    # bucket is the only source; the poisoned id=2 must not restore
    shutil.rmtree(comm.node_local_dir, ignore_errors=True)
    os.makedirs(comm.node_local_dir, exist_ok=True)
    shutil.rmtree(cfg.global_root, ignore_errors=True)
    t0 = time.time()
    b2 = _restart_backend(cfg, comm, backend, kw)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)   # the expected fallback
        got = b2.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(0, 1), named)
    silent_corruption = (named is not None
                         and _loss_bytes(_payload(0, 2), named) == 0)
    ok = (poisoned and not silent_corruption and loss == 0
          and meta.get("id") == 1
          and meta.get("recovered_via") == "objstore")
    return ScenarioResult(
        "corrupt-chunk-straggler", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss, mttr_s=dt,
        detail={"poisoned_store": poisoned,
                "fell_back_to_id": meta.get("id"),
                "silent_corruption": silent_corruption})


@scenario("heartbeat-loss-mid-gc")
def heartbeat_loss_mid_gc(workdir: str, backend: str) -> ScenarioResult:
    """The worker goes silent exactly while retention GC dies mid-sweep:
    the stale GC mark resumes safely on the next store (never deleting a
    live chunk), the silent span registers as a *real* failure in the
    MTBF estimator, and the surviving newest id restores bit-exact."""
    cfg = StorageConfig(root=os.path.join(workdir, "shared"), group_size=1,
                        objstore_keep_last=2)
    comm = LocalComm(os.path.join(workdir, "node-local"))
    kw = {"dedicated_thread": False} if backend == "fti" else {}
    b = make_backend(cfg, comm, backend, **kw)
    hb = Heartbeat(os.path.join(workdir, "heartbeat"))
    est = MTBFEstimator(prior_mtbf_s=3600.0, gap_failure_s=0.2)
    hb.beat(1)
    est.note_progress()
    b.tcl_store(_payload(0, 1), 1, 4, CHK_FULL)
    b.tcl_wait()
    b.tcl_store(_payload(0, 2), 2, 4, CHK_FULL)
    b.tcl_wait()
    # both faults in one window: heartbeat writes stop landing, and the
    # GC sweep triggered by id=3's commit (which retires id=1) dies on
    # its first chunk delete — AFTER the id=3 entry is durable
    chaos.arm("heartbeat.beat", mode="skip", every=1, times=None)
    chaos.arm("objstore.delete", mode="error", at=1)
    gc_died = False
    try:
        b.tcl_store(_payload(0, 3), 3, 4, CHK_FULL)
        b.tcl_wait()
    except Exception:
        gc_died = True
    time.sleep(0.25)                          # the silent span
    hb.beat(3)                                # skipped — never lands
    est.note_progress()                       # gap > gap_failure_s
    stale = hb.stale_s()
    chaos.registry().disarm_all()
    t0 = time.time()
    b.tcl_store(_payload(0, 4), 4, 4, CHK_FULL)   # resumes the stale mark
    b.tcl_wait()
    tier = b.engine.objstore_tier()
    mark_cleared = tier.store.get_with_etag(GC_MARK_KEY)[0] is None
    shutil.rmtree(comm.node_local_dir, ignore_errors=True)
    os.makedirs(comm.node_local_dir, exist_ok=True)
    shutil.rmtree(cfg.global_root, ignore_errors=True)
    b2 = _restart_backend(cfg, comm, backend, kw)
    got = b2.engine.load_latest()
    dt = time.time() - t0
    named, meta = got if got is not None else (None, {})
    loss = _loss_bytes(_payload(0, 4), named)
    mtbf_moved = est.failures >= 1 and est.estimate() < est.prior_mtbf_s
    ok = (gc_died and mark_cleared and mtbf_moved and loss == 0
          and stale is not None and stale >= 0.25
          and meta.get("recovered_via") == "objstore")
    return ScenarioResult(
        "heartbeat-loss-mid-gc", backend, ok,
        faults_fired=chaos.registry().fired_count(),
        recovery_path=str(meta.get("recovered_via")), recovery_s=dt,
        data_loss_bytes=loss, mttr_s=dt,
        detail={"gc_died_mid_sweep": gc_died,
                "stale_mark_cleared": mark_cleared,
                "heartbeat_stale_s": round(stale or -1.0, 3),
                "mtbf_failures": est.failures,
                "mtbf_estimate_s": round(est.estimate(), 1)})


# -- supervised multi-process scenario ---------------------------------------
@scenario("supervised-kill", table=SUPERVISED)
def supervised_kill(workdir: str, backend: str) -> ScenarioResult:
    """Real kill/restart supervision: spawn ``launch/train.py --supervise``
    with an ``OPENCHK_CHAOS`` exit spec that hard-kills the worker at step
    8 (checkpoints at 3 and 6).  Asserts kill-detect → backoff → resume
    from the last checkpoint (never step 0), that the restart-durable
    fault counters keep the exhausted spec from re-killing the restarted
    child, and that the supervisor's MTBF feed recorded the real death."""
    ckpt_dir = os.path.join(workdir, "ckpt")
    os.makedirs(ckpt_dir, exist_ok=True)
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    spec = chaos.FaultSpec(site="train.step", mode="exit", every=8, times=1)
    state_path = os.path.join(ckpt_dir, "chaos-state.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.update(chaos.env_for_specs([spec], state_path=state_path))
    cmd = [sys.executable, "-m", "repro.launch.train", "--supervise",
           "--arch", "tinyllama-1.1b", "--steps", "12", "--batch", "2",
           "--seq", "32", "--ckpt-every", "3", "--no-dedicated-thread",
           "--ckpt-dir", ckpt_dir, "--restart-backoff", "0.2",
           "--restart-backoff-max", "1.0", "--backend", backend]
    t0 = time.time()
    p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=420)
    dt = time.time() - t0
    out = p.stdout + p.stderr
    resumed = "resuming from step 6" in out
    restarted_once = "attempt 2" in out and "attempt 3" not in out
    backed_off = "backing off" in out
    finished = "'final_step': 12" in out
    fired_total = 0
    try:
        with open(state_path, "r", encoding="utf-8") as f:
            fired_total = sum(int(v.get("fired", 0))
                              for v in json.load(f).values())
    except (OSError, ValueError, AttributeError):
        pass
    feed: Dict[str, Any] = {}
    try:
        with open(os.path.join(ckpt_dir, "mtbf-feed.json"),
                  encoding="utf-8") as f:
            feed = json.load(f)
    except (OSError, ValueError):
        pass
    feed_ok = (feed.get("deaths") == 1 and feed.get("failures", 0) >= 1
               and feed.get("estimate_s", 1e18) < 3600.0
               and len(feed.get("mttr_s") or []) == 1)
    mttr = (feed.get("mttr_s") or [dt])[0]
    ok = (p.returncode == 0 and resumed and restarted_once and backed_off
          and finished and fired_total == 1 and feed_ok)
    return ScenarioResult(
        "supervised-kill", backend, ok,
        faults_fired=fired_total,
        recovery_path="supervised", recovery_s=dt,
        data_loss_bytes=0 if (resumed and finished) else -1, mttr_s=mttr,
        detail={"returncode": p.returncode, "resumed_from_step_6": resumed,
                "exactly_one_restart": restarted_once,
                "backoff_paced": backed_off, "finished": finished,
                "state_fired": fired_total, "feed": feed})


def run_scenario(name: str, backend: str, workdir: str,
                 trace_dir: Optional[str] = None) -> ScenarioResult:
    """Run one scenario with a clean chaos registry, always disarming.

    With *trace_dir*, the cell runs traced: this process records into
    ``<trace_dir>/<name>-<backend>/trace-<pid>.json``, spawned supervised
    workers inherit ``OPENCHK_TRACE_DIR`` and contribute their own files,
    and afterwards everything folds into ``<trace_dir>/<name>-<backend>.json``
    — ``detail.trace_file`` points there and ``detail.metrics`` embeds the
    cell's metrics-registry snapshot."""
    chaos.reset()
    os.makedirs(workdir, exist_ok=True)
    cell = f"{name}-{backend}"
    raw_dir = None
    prev_env: Dict[str, Optional[str]] = {}
    if trace_dir is not None:
        raw_dir = os.path.join(trace_dir, cell)
        os.makedirs(raw_dir, exist_ok=True)
        prev_env = {k: os.environ.get(k)
                    for k in (ttrace.TRACE_ENV, ttrace.TRACE_DIR_ENV)}
        os.environ.pop(ttrace.TRACE_ENV, None)
        os.environ[ttrace.TRACE_DIR_ENV] = raw_dir  # children inherit
        tmetrics.reset()
        ttrace.tracer().reset()
        ttrace.enable(os.path.join(raw_dir, f"trace-{os.getpid()}.json"))
    try:
        fn = SCENARIOS.get(name) or SUPERVISED[name]
        result = fn(workdir, backend)
    except Exception as e:  # a crashed scenario is a failed scenario
        result = ScenarioResult(
            name, backend, False,
            faults_fired=chaos.registry().fired_count(),
            recovery_path="error", recovery_s=0.0, data_loss_bytes=-1,
            detail={"error": f"{type(e).__name__}: {e}"})
    finally:
        chaos.reset()
        if raw_dir is not None:
            ttrace.flush()
            ttrace.disable()
            ttrace.tracer().reset()
            for k, v in prev_env.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
    if raw_dir is not None:
        result.detail["trace_file"] = ttrace.merge_dir(
            raw_dir, os.path.join(trace_dir, f"{cell}.json"))
        result.detail["metrics"] = tmetrics.snapshot()
    return result


def run_matrix(workdir: str,
               backends=BACKENDS,
               names: Optional[List[str]] = None,
               include_supervised: bool = False,
               trace_dir: Optional[str] = None) -> Dict[str, Any]:
    """The full scenario × backend matrix → machine-readable report.

    Supervised scenarios spawn real worker processes, so they run once
    (first backend) instead of per matrix cell, and only when named
    explicitly or requested via *include_supervised*."""
    names = list(names or SCENARIOS)
    if include_supervised:
        names += [n for n in SUPERVISED if n not in names]
    results = []
    for n in names:
        if n in SUPERVISED:
            d = os.path.join(workdir, f"{n}-{backends[0]}")
            results.append(run_scenario(n, backends[0], d, trace_dir))
            continue
        for be in backends:
            d = os.path.join(workdir, f"{n}-{be}")
            results.append(run_scenario(n, be, d, trace_dir))
    return {
        "scenarios": [r.to_dict() for r in results],
        "total": len(results),
        "passed": sum(r.ok for r in results),
        "data_loss_bytes": sum(r.data_loss_bytes for r in results),
        "max_mttr_s": round(max(
            (r.mttr_s if r.mttr_s is not None else r.recovery_s)
            for r in results), 4) if results else 0.0,
        "ok": all(r.ok for r in results),
    }
