"""Table 1 analogue: cyclomatic complexity of the heat-2d CR variants.

CC = 1 + decision points (if/for/while/except/boolop/ternary/comprehension),
computed with ``ast`` over each variant's ``run`` function (the paper used
Lizard; same metric definition).
"""
from __future__ import annotations

import ast
import os
from typing import Dict

from benchmarks.bench_sloc import APPS


def cyclomatic_complexity(path: str, func: str = "run") -> int:
    tree = ast.parse(open(path).read())
    target = None
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == func:
            target = node
            break
    assert target is not None, f"no {func}() in {path}"
    cc = 1
    for node in ast.walk(target):
        if isinstance(node, (ast.If, ast.For, ast.While, ast.IfExp,
                             ast.ExceptHandler, ast.Assert,
                             ast.ListComp, ast.DictComp, ast.SetComp,
                             ast.GeneratorExp)):
            cc += 1
        elif isinstance(node, ast.BoolOp):
            cc += len(node.values) - 1
    return cc


def run() -> Dict[str, float]:
    base = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return {f"cc_{k}": float(cyclomatic_complexity(os.path.join(base, p)))
            for k, p in APPS.items()}


def rows():
    return [("complexity/" + k, 0.0, v) for k, v in sorted(run().items())]


if __name__ == "__main__":
    for name, _, v in rows():
        print(f"{name},{v}")
