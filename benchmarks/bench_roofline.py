"""§Roofline aggregation: read reports/dryrun/*.json → per-cell terms table.

Run ``python -m repro.launch.dryrun --all --both-meshes`` first (the final
EXPERIMENTS.md tables are generated from the same reports via
benchmarks/make_experiments_tables.py).
"""
from __future__ import annotations

import glob
import json
from typing import Dict, List


def load_reports(pattern: str = "reports/dryrun/*.json") -> List[Dict]:
    out = []
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            out.append(json.load(f))
    return out


def rows():
    reports = load_reports()
    out = []
    n_ok = n_skip = 0
    for r in reports:
        if r.get("status") == "skipped":
            n_skip += 1
            continue
        n_ok += 1
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        out.append((f"roofline/{cell}/t_bound_us",
                    max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
                    r["roofline_fraction"]))
    out.append(("roofline/cells_compiled", 0.0, float(n_ok)))
    out.append(("roofline/cells_skipped_by_design", 0.0, float(n_skip)))
    return out


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
