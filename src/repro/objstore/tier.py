"""``ObjectStoreTier`` — the object store as the real L4 rung.

Write side (composes with :class:`~repro.core.tiers.GlobalTier` in the
level-4 stack):

    Place    every staged file of the checkpoint (the rank container plus
             its sibling shard files) is split into content-addressed
             chunks and the *missing* chunks are submitted to the
             transfer-thread pool — uploads overlap the rest of the store
             tail (and, on a CP-dedicated-thread backend, training);
             chunks shared with previous checkpoints upload nothing.
    Commit   runs after the local atomic rename: joins the transfers
             (surfacing the first failure), then CAS-publishes the
             catalog entry (manifest + file→chunk map).  A crash anywhere
             before the publish leaves the previous catalog entry
             authoritative — the store never advertises a checkpoint
             whose chunks are not all durable.  Retention + GC
             (``keep_last``/``keep_every``) run after a successful
             publish.

Read side: the tier answers the recovery ladder *below* ``global`` — it
resolves the catalog entry, reassembles this rank's file set (manifest,
container, shard files) into a node-local cache directory
(``<node-local>/objstore-cache/ckpt-<id>/``), and returns the rank
payload.  Because the whole file set is materialized in a directory the
pipeline's recovery-dir scan includes, sharded leaves restore through the
ordinary ``resolve_shard_refs`` → :class:`ElasticLoader` region reads —
a 4×4 store restores onto a 2×8 mesh from the object store alone, with
L1–L3 (and even the L4 global directory) wiped.

Known limitation (ROADMAP): dedup's exists-check and GC's sweep are not
transactional against each other across *concurrent* writers — a real
multi-writer deployment needs upload pinning (grace-period leases on
young chunks) before GC can run concurrently with stores.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Tuple

from repro.core import manifest as mf
from repro.core.formats import CHK5CorruptionError, CHK5Reader
from repro.core.tiers import Tier, TierContext
from repro.objstore import gc as objgc
from repro.objstore.catalog import Catalog
from repro.objstore.cdc import CDCParams
from repro.objstore.chunks import (
    ChunkCache,
    ChunkUploader,
    PendingFile,
    fetch_file_delta,
)
from repro.objstore.client import ObjectStoreError, make_object_store


def default_objstore_url(root: str) -> str:
    return "file:" + os.path.join(root, "objstore")


def _cache_matches(path: str, entry) -> bool:
    """Is the cached file byte-identical to the catalog entry?  Verified
    by re-chunking at the entry's recorded (offset, nbytes) ranges and
    comparing digests — size alone would silently reuse a stale cache
    (e.g. a checkpoint id re-stored after its old entry was retired) or
    keep returning a corrupt file instead of refetching the healthy
    bucket.  Offsets make this layout-independent: fixed and CDC entries
    verify identically."""
    try:
        if os.path.getsize(path) != entry.size:
            return False
        with open(path, "rb") as f:
            for digest, offset, nbytes in entry.chunks:
                f.seek(offset)
                data = f.read(nbytes)
                if len(data) != nbytes or \
                        hashlib.sha256(data).hexdigest() != digest:
                    return False
        return True
    except OSError:
        return False


class ObjectStoreTier(Tier):
    """L4 via a content-addressed object store + checkpoint catalog."""

    name = "objstore"
    level = 5                      # last rung of the recovery ladder

    def __init__(self, ctx: TierContext):
        super().__init__(ctx)
        cfg = ctx.cfg
        url = getattr(cfg, "objstore_url", None) or \
            default_objstore_url(cfg.root)
        self.store = make_object_store(url)
        self.catalog = Catalog(self.store)
        cdc = None
        if getattr(cfg, "objstore_chunking", "cdc") == "cdc":
            cdc = CDCParams(
                min_bytes=getattr(cfg, "objstore_cdc_min_bytes", 256 << 10),
                avg_bytes=getattr(cfg, "objstore_cdc_avg_bytes", 1 << 20),
                max_bytes=getattr(cfg, "objstore_cdc_max_bytes", 4 << 20))
        self.uploader = ChunkUploader(
            self.store,
            chunk_bytes=getattr(cfg, "objstore_chunk_bytes", 1 << 20),
            transfers=getattr(cfg, "objstore_transfers", 4),
            cdc=cdc)
        self.keep_last = getattr(cfg, "objstore_keep_last", None)
        self.keep_every = getattr(cfg, "objstore_keep_every", None)
        self._pending: Dict[int, List[PendingFile]] = {}
        #: ckpt_id → basename → in-flight ChunkStream (the fused Pack path)
        self._streams: Dict[int, Dict[str, object]] = {}
        self.stats: Dict[str, int] = {"stores": 0, "restores": 0,
                                      "gc_deleted": 0, "bytes_fetched": 0,
                                      "bytes_cached": 0}
        # payload reads from the cache go through this tier's digest
        # verification, not the byte-oblivious LocalTier
        ctx.catalog_roots.add(self.root)

    # ------------------------------------------------------------------ #

    @property
    def root(self) -> str:
        """The node-local cache dir restored file sets land in (NOT a
        write-path root — Pack never stages here)."""
        return os.path.join(self.ctx.comm.node_local_dir, "objstore-cache")

    # -- write side ----------------------------------------------------- #

    def pack_sink(self, ckpt_id: int, basename: str):
        """Hand Pack a streaming chunk sink for the staged file
        ``basename``: CHK5 writers tee every byte into it, so chunking,
        digesting and the missing-chunk uploads all overlap container
        writing — Place then only *collects* the streams instead of
        re-reading staged files from disk.

        Stores are serialized per pipeline, so a registration for a new
        checkpoint id drops any stale stream set (a store whose tail
        failed between pack and commit)."""
        if ckpt_id not in self._streams:
            self._streams = {ckpt_id: {}}
        stream = self.uploader.open_stream(basename)
        self._streams[ckpt_id][basename] = stream
        return stream

    def place(self, ckpt_id, stage_dir, payload_path, extra_files=()):
        """Collect the Pack-time chunk streams (uploads already in
        flight); fall back to reading + chunking the staged file for any
        payload Pack did not stream (e.g. externally produced files
        entering at Place).  Commit joins.

        Stores are serialized per pipeline (the CP queue), so only one
        upload set is ever in flight: dropping any stale pending entry
        here frees the file handles of a store whose tail failed between
        Place and the commit hook."""
        streams = self._streams.pop(ckpt_id, {})
        self._streams = {}
        pend = []
        for p in (payload_path, *extra_files):
            s = streams.get(os.path.basename(p))
            if s is not None and s.finished:
                pend.append(s.pending())
            else:
                pend.append(self.uploader.submit_file(p))
        self._pending = {ckpt_id: pend}

    def commit(self, ckpt_id: int, manifest: Dict) -> None:
        """After the local atomic rename: join uploads, publish the
        catalog entry, then apply retention + GC."""
        pend = self._pending.pop(ckpt_id, [])
        if not pend:
            return
        files = {p.name: p.result() for p in pend}   # raises on failed put
        self.catalog.publish(ckpt_id, manifest, files)
        self.stats["stores"] += 1
        if self.keep_last is not None or self.keep_every is not None:
            # "retired" sweep: condemn only chunks the retired entries
            # referenced — never a chunk a peer rank of an in-flight
            # coordinated store has uploaded but not yet published, and
            # O(retired) instead of a full bucket walk per store.
            # Orphans from crashed uploads are reclaimed by the offline
            # pass (objstore.gc.collect(..., sweep="bucket")).
            got = objgc.collect(self.store, self.catalog,
                                keep_last=self.keep_last,
                                keep_every=self.keep_every,
                                sweep="retired")
            self.stats["gc_deleted"] += got["deleted"] + \
                got["resumed_deleted"]

    # -- read side ------------------------------------------------------ #

    def list_ids(self) -> List[Tuple[int, str]]:
        """Catalog checkpoint ids, rooted at the cache dir (a wiped run
        discovers its checkpoints from the catalog, not a directory
        scan)."""
        try:
            return [(i, self.root) for i in self.catalog.ids()]
        except (ObjectStoreError, ValueError, KeyError):
            return []

    def recover(self, ckpt_id, rank, root, manifest, dirs):
        if root != self.root:
            return None                  # only answer for the catalog root
        try:
            entry = self.catalog.entry(ckpt_id)
        except (ObjectStoreError, ValueError, KeyError):
            return None
        if entry is None:
            return None
        files = Catalog.file_entries(entry)
        container = f"rank{rank}.chk5"
        if container not in files:
            return None
        d = mf.ckpt_dir(self.root, ckpt_id)
        os.makedirs(d, exist_ok=True)
        try:
            # chunk-level cache shared across entries: recovering entry
            # N+1 after N pulls only the chunks the two do not share
            cache = ChunkCache(os.path.join(self.root, "chunks"))
            mine = [n for n in files
                    if n == container or n.startswith(f"rank{rank}.shard")]
            for name in mine:
                dest = os.path.join(d, name)
                if _cache_matches(dest, files[name]):
                    continue             # already materialized, verified
                got = fetch_file_delta(self.store, files[name], dest, cache)
                self.stats["bytes_fetched"] += got["bytes_fetched"]
                self.stats["bytes_cached"] += got["bytes_cached"]
        except ObjectStoreError:
            return None
        # the manifest rides the catalog entry; materializing it makes the
        # cache dir a normal committed checkpoint dir for the restore
        # walk.  Always rewritten: the cache may hold a stale manifest
        # from an earlier entry that reused this checkpoint id.
        man_path = os.path.join(d, mf.MANIFEST)
        tmp = man_path + ".part"
        with open(tmp, "w") as f:
            json.dump(entry.get("manifest", {}), f, indent=1,
                      sort_keys=True)
        os.replace(tmp, man_path)
        path = os.path.join(d, container)
        try:
            CHK5Reader(path).close()
        except (OSError, CHK5CorruptionError):
            return None
        self.stats["restores"] += 1
        with open(path, "rb") as f:
            return f.read()
