"""data substrate."""
