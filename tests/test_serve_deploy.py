"""Checkpoint-as-deployment: the typed catalog-inspection API, the epoch
subscriber, chunk-delta pulls through the node-local cache, the engine's
atomic WeightsHandle swap, and the rolling fleet deployer under injected
faults — a killed replica, a corrupted cached chunk, and an objstore
outage all pin the affected replica on its current epoch (no torn params
ever observable from ``generate()``) and the rollout converges once the
fault clears."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.comm import LocalComm
from repro.core.protect import flatten_named
from repro.core.storage import StorageConfig, StorageEngine
from repro.models.zoo import build_model
from repro.objstore.catalog import Catalog
from repro.objstore.chunks import (
    ChunkCache,
    ChunkUploader,
    FileEntry,
    fetch_file_delta,
)
from repro.objstore.client import (
    MemoryObjectStore,
    ObjectStoreError,
    make_object_store,
)
from repro.objstore.inspect import CatalogView, EntryInfo
from repro.objstore.subscriber import CatalogSubscriber, DeploySelector
from repro.serve.deploy import EntryPuller, FleetDeployer, Replica
from repro.serve.engine import ServingEngine, WeightsHandle

# ------------------------------------------------------------------ #
# inspect: typed views + the chunk diff
# ------------------------------------------------------------------ #


def _publish(cat, ckpt_id, chunks, kind="FULL", level=4, name="rank0.chk5"):
    size = sum(n for _h, n in chunks)
    cat.publish(ckpt_id, {"kind": kind, "level": level, "id": ckpt_id},
                {name: FileEntry(name, size, list(chunks))})


def test_catalog_view_entries_latest_and_diff():
    st = MemoryObjectStore()
    cat = Catalog(st)
    _publish(cat, 1, [("a", 100), ("b", 100), ("c", 100)])
    _publish(cat, 2, [("a", 100), ("b", 100), ("d", 50)])
    _publish(cat, 3, [("x", 10)], kind="DIFF", level=1)
    view = CatalogView.from_store(st)
    assert view.ids() == [1, 2, 3]
    assert view.epoch == cat.epoch()
    # selector resolution: newest FULL, not the newer DIFF
    assert view.latest(kind="FULL").id == 2
    assert view.latest(kind="DIFF").id == 3
    assert view.latest(kind="FULL", min_id=3) is None
    e2 = view.entry(2)
    assert e2.kind == "FULL" and e2.level == 4
    assert e2.total_bytes == 250 and e2.n_chunks == 3
    assert e2.chunk_digests == {"a", "b", "d"}
    # the deploy delta: only the digest the base lacks is pulled
    d = CatalogView.diff(view.entry(1), e2)
    assert d.digests == {"d"} and d.bytes_delta == 50
    assert d.bytes_total == 250 and d.ratio == pytest.approx(0.2)
    # cold fleet: the delta is the whole entry
    cold = CatalogView.diff(None, e2)
    assert cold.bytes_delta == cold.bytes_total == 250


def test_inventory_shim_keeps_legacy_shape(tmp_path):
    eng = _engine(tmp_path)
    eng.store({"w": np.arange(4096, dtype=np.float32)}, ckpt_id=7, level=4)
    root = os.path.join(str(tmp_path / "shared"), "objstore")
    from repro.tools.chkls import catalog_inventory
    inv = catalog_inventory(root)
    view = CatalogView.from_root(root, count_chunks=True)
    assert inv == view.to_inventory(root)
    e = inv["entries"][0]
    assert e["id"] == 7 and e["kind"] == "FULL"
    assert set(e) == {"id", "pinned", "kind", "level", "wall_time", "files",
                      "total_bytes", "n_chunks", "chunk_hist",
                      "chunk_bytes_min", "chunk_bytes_max"}
    assert sum(e["chunk_hist"].values()) == e["n_chunks"] > 0
    assert inv["stored_chunks"] >= e["n_chunks"]


# ------------------------------------------------------------------ #
# subscriber: epoch watch + selector
# ------------------------------------------------------------------ #


def test_subscriber_polls_epochs_and_tracks_deployed():
    st = MemoryObjectStore()
    cat = Catalog(st)
    sub = CatalogSubscriber(st)
    assert sub.poll() is None                     # empty catalog
    _publish(cat, 1, [("a", 10)])
    t1 = sub.poll()
    assert t1 is not None and t1.id == 1
    assert sub.poll() is None                     # epoch unchanged: no read
    sub.mark_deployed(t1)
    _publish(cat, 2, [("a", 10), ("b", 4)])
    t2 = sub.poll()
    assert t2.id == 2
    assert sub.delta(t2).digests == {"b"}         # diff vs deployed base
    sub.mark_deployed(t2)
    # a DIFF publish moves the epoch but resolves to the already-deployed
    # FULL entry — nothing to do
    _publish(cat, 3, [("z", 1)], kind="DIFF")
    assert sub.poll() is None
    # selector filters
    sub2 = CatalogSubscriber(st, DeploySelector(kind="DIFF"))
    assert sub2.poll().id == 3


def test_subscriber_outage_propagates():
    st = MemoryObjectStore()
    Catalog(st).publish(1, {"kind": "FULL"}, {})
    sub = CatalogSubscriber(st)

    class _Dead:
        def get_with_etag(self, key):
            raise ObjectStoreError("outage")
    sub.catalog.store = _Dead()
    with pytest.raises(ObjectStoreError, match="outage"):
        sub.poll()


# ------------------------------------------------------------------ #
# chunk cache + delta fetch
# ------------------------------------------------------------------ #


def test_fetch_file_delta_uses_cache_and_refetches_corruption(tmp_path):
    st = MemoryObjectStore()
    up = ChunkUploader(st, chunk_bytes=1024, transfers=2)
    payload = os.urandom(8192)
    src = str(tmp_path / "src")
    with open(src, "wb") as f:
        f.write(payload)
    entry = up.upload_file(src)
    cache = ChunkCache(str(tmp_path / "cache"))
    s1 = fetch_file_delta(st, entry, str(tmp_path / "out1"), cache)
    assert open(str(tmp_path / "out1"), "rb").read() == payload
    assert s1["chunks_fetched"] == 8 and s1["chunks_cached"] == 0
    # second fetch: everything served from the local cache
    s2 = fetch_file_delta(st, entry, str(tmp_path / "out2"), cache)
    assert s2["chunks_fetched"] == 0 and s2["chunks_cached"] == 8
    # corrupt one cached chunk in place: digest verify evicts + refetches
    victim = entry.chunks[3][0]
    with open(os.path.join(str(tmp_path / "cache"), victim), "r+b") as f:
        f.write(b"\x00garbage\x00")
    s3 = fetch_file_delta(st, entry, str(tmp_path / "out3"), cache)
    assert open(str(tmp_path / "out3"), "rb").read() == payload
    assert s3["chunks_corrupt"] == 1 and s3["chunks_fetched"] == 1
    # a chunk corrupt in the BUCKET fails loudly, leaves no torn file
    st._objects[f"chunks/{victim[:2]}/{victim}"] = b"bad"
    cache2 = ChunkCache(str(tmp_path / "cache2"))
    with pytest.raises(ObjectStoreError, match="corrupt"):
        fetch_file_delta(st, entry, str(tmp_path / "out4"), cache2)
    assert not os.path.exists(str(tmp_path / "out4"))


# ------------------------------------------------------------------ #
# engine: the WeightsHandle contract
# ------------------------------------------------------------------ #


def _tiny():
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def test_engine_weights_handle_is_the_only_mutation_path():
    _cfg, model, params = _tiny()
    eng = ServingEngine(model, params, batch=2, max_len=16)
    assert isinstance(eng.weights, WeightsHandle)
    assert eng.weights.epoch == 0 and eng.weights.entry_id is None
    assert eng.params is eng.weights.params
    with pytest.raises(AttributeError):
        eng.params = params                       # bare attribute is gone
    with pytest.raises(TypeError, match="WeightsHandle"):
        eng.set_weights(params)
    h1 = eng.set_weights(WeightsHandle(params=params, entry_id=42))
    assert h1.epoch == 1 and eng.weights.entry_id == 42
    # epochs are stamped monotonically even when the caller passes 0
    h2 = eng.set_weights(WeightsHandle(params=params))
    assert h2.epoch == 2
    swaps = []
    eng.swap_hook = lambda old, new: swaps.append((old.epoch, new.epoch))
    eng.set_weights(WeightsHandle(params=params))
    assert swaps == [(2, 3)]


def test_prefill_empty_prompt_raises_clearly():
    _cfg, model, params = _tiny()
    eng = ServingEngine(model, params, batch=2, max_len=16)
    with pytest.raises(ValueError, match="prompt_len=0"):
        eng.prefill(jnp.zeros((2, 0), jnp.int32))


def test_generate_finishes_inflight_batch_on_old_weights():
    cfg, model, params = _tiny()
    params_b = jax.tree.map(lambda x: x + 0.05, params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size, jnp.int32)

    eng = ServingEngine(model, params, batch=2, max_len=32)
    eng.prefill(prompts)
    st0 = eng.get_state()

    # ground truth: the full batch on OLD weights, then new weights after
    ref_old = ServingEngine(model, params, batch=2, max_len=32)
    ref_old.set_state(st0)
    want_old = ref_old.generate(4)
    ref_new = ServingEngine(model, params_b, batch=2, max_len=32)
    ref_new.set_state(ref_old.get_state())
    want_new = ref_new.generate(3)

    # swap fires mid-batch: the in-flight batch must finish on the
    # handle it captured; the NEXT batch serves the new weights
    orig_step, calls = eng._step, []

    def step(p, tok, caches, pos):
        calls.append(1)
        if len(calls) == 2:
            eng.set_weights(WeightsHandle(params=params_b, entry_id=9))
        return orig_step(p, tok, caches, pos)

    eng._step = step
    got_old = eng.generate(4)
    np.testing.assert_array_equal(np.asarray(got_old), np.asarray(want_old))
    assert eng.weights.entry_id == 9
    got_new = eng.generate(3)
    np.testing.assert_array_equal(np.asarray(got_new), np.asarray(want_new))


# ------------------------------------------------------------------ #
# fleet deployer: rolling swap + failure matrix
# ------------------------------------------------------------------ #


def _engine(tmp_path, tag="pub", **cfg_kw):
    cfg_kw.setdefault("objstore_chunk_bytes", 4096)
    cfg_kw.setdefault("objstore_cdc_min_bytes", 1024)
    cfg_kw.setdefault("objstore_cdc_avg_bytes", 4096)
    cfg_kw.setdefault("objstore_cdc_max_bytes", 16384)
    cfg = StorageConfig(root=str(tmp_path / "shared"), block_bytes=256,
                        **cfg_kw)
    return StorageEngine(cfg, LocalComm(str(tmp_path / f"nl-{tag}")))


class _FaultStore:
    """Store wrapper with two injectable faults: a count-down kill on
    chunk gets (a replica dying mid-pull) and a global outage flag."""

    def __init__(self, inner):
        self._inner = inner
        self.die_after = None
        self.outage = False

    def _check(self, key):
        if self.outage:
            raise ObjectStoreError("objstore outage (injected)")
        if self.die_after is not None and key.startswith("chunks/"):
            if self.die_after == 0:
                raise ObjectStoreError("replica killed mid-pull (injected)")
            self.die_after -= 1

    def get(self, key):
        self._check(key)
        return self._inner.get(key)

    def get_with_etag(self, key):
        self._check(key)
        return self._inner.get_with_etag(key)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _Fleet:
    """Three real ServingEngines (shared tiny model) + a deployer wired
    to a publishing StorageEngine's bucket, on an injectable clock."""

    def __init__(self, tmp_path, n=3):
        self.cfg, self.model, self.params = _tiny()
        self.pub = _engine(tmp_path)
        self.store = _FaultStore(make_object_store(
            "file:" + os.path.join(str(tmp_path / "shared"), "objstore")))
        self.t = 0.0
        self.replicas = [
            Replica(name=f"r{i}",
                    engine=ServingEngine(self.model, self.params,
                                         batch=2, max_len=32),
                    cache_root=str(tmp_path / f"cache-{i}"),
                    prefix="params")
            for i in range(n)]
        self.dep = FleetDeployer(self.store, self.replicas,
                                 backoff_s=1.0, time_fn=lambda: self.t)

    def publish(self, ckpt_id, params):
        named, _ = flatten_named({"params": params})
        state = {name: np.asarray(v) for name, v in named.items()}
        state["step"] = np.int32(ckpt_id)
        self.pub.store(state, ckpt_id=ckpt_id, level=4)

    def actions(self, n):
        out = []
        for _ in range(n):
            out.append(self.dep.poll()["action"])
        return out

    def entry_ids(self):
        return sorted(set(self.dep.fleet_epochs().values()),
                      key=lambda x: (x is None, x))


def _leaf0(tree):
    return np.asarray(jax.tree.leaves(tree)[0])


def test_rolling_swap_one_replica_per_poll_and_delta_ratio(tmp_path):
    f = _Fleet(tmp_path)
    f.publish(1, f.params)
    st = f.dep.poll()
    assert st["action"] == "started" and st["entry"] == 1
    # cold fleet: the first delta is (essentially) the whole entry — only
    # digest-identical chunks within the entry itself can dedup
    assert st["delta"].ratio > 0.9
    # exactly one replica swaps per poll; mid-rollout the fleet serves at
    # most two distinct epochs (old None, new 1) — never a third
    assert f.dep.poll()["action"] == "swapped"
    assert f.entry_ids() == [1, None]
    assert f.actions(2) == ["swapped", "swapped"]
    assert f.dep.poll()["action"] == "converged"
    assert f.dep.fleet_epochs() == {"r0": 1, "r1": 1, "r2": 1}
    assert f.dep.poll()["action"] == "idle"

    # fine-tune publish: nudge ONE leaf, everything else chunk-dedups
    named, _ = flatten_named(f.params)
    name0 = sorted(named)[0]
    tuned_named = dict(named)
    tuned_named[name0] = named[name0] + 0.01
    from repro.core.protect import unflatten_named
    tuned = unflatten_named(None, tuned_named, f.params)
    f.publish(2, tuned)

    st = f.dep.poll()
    assert st["action"] == "started" and st["entry"] == 2
    # the catalog-level chunk diff already promises a small pull
    assert st["delta"].ratio < 0.30, st["delta"]
    pre = dict(f.dep.stats)
    assert f.actions(3) == ["swapped"] * 3
    assert f.dep.poll()["action"] == "converged"
    pulled = f.dep.stats["bytes_fetched"] - pre["bytes_fetched"]
    total = f.dep.stats["bytes_cached"] - pre["bytes_cached"] + pulled
    assert pulled < 0.30 * total, (pulled, total)
    # the swap actually installed the tuned weights, bit-exact
    for r in f.replicas:
        got, _ = flatten_named(r.engine.params)
        np.testing.assert_array_equal(np.asarray(got[name0]),
                                      np.asarray(tuned_named[name0]))
        assert r.engine.weights.entry_id == 2


def test_replica_killed_mid_pull_fleet_keeps_old_epoch_then_converges(
        tmp_path):
    f = _Fleet(tmp_path)
    f.publish(1, f.params)
    assert f.actions(5) == ["started", "swapped", "swapped", "swapped",
                            "converged"]
    old_leaf = _leaf0(f.replicas[1].engine.params).copy()

    f.publish(2, jax.tree.map(lambda x: x + 0.5, f.params))
    assert f.dep.poll()["action"] == "started"
    assert f.dep.poll()["action"] == "swapped"    # r0 (canary) fine
    f.store.die_after = 2                         # r1 dies 2 chunks in
    st = f.dep.poll()
    assert st["action"] == "pinned" and st["replica"] == "r1"
    assert "killed mid-pull" in st["error"]
    # invariant: r1 still serves entry 1, bit-identical — no torn tree
    assert f.dep.fleet_epochs() == {"r0": 2, "r1": 1, "r2": 1}
    np.testing.assert_array_equal(
        _leaf0(f.replicas[1].engine.params), old_leaf)
    # rollout holds at r1 (canary discipline): r2 is NOT advanced past it
    f.t += 0.5
    assert f.dep.poll()["action"] == "waiting"    # backoff not elapsed
    # fault clears (the "revived" replica re-pulls; its cache survived)
    f.store.die_after = None
    f.t += 1.0
    st = f.dep.poll()
    assert st["action"] == "swapped" and st["replica"] == "r1"
    assert f.actions(2) == ["swapped", "converged"]
    assert f.dep.fleet_epochs() == {"r0": 2, "r1": 2, "r2": 2}


def test_corrupt_cached_chunk_is_refetched_during_swap(tmp_path):
    f = _Fleet(tmp_path, n=1)
    f.publish(1, f.params)
    assert f.actions(3) == ["started", "swapped", "converged"]
    # corrupt every cached chunk in place (same sizes, wrong bytes) —
    # any chunk the fine-tune swap tries to reuse must be caught
    cache_dir = os.path.join(str(tmp_path / "cache-0"), "chunks")
    for victim in os.listdir(cache_dir):
        p = os.path.join(cache_dir, victim)
        size = os.path.getsize(p)
        with open(p, "wb") as fh:
            fh.write(b"\xa5" * size)
    # fine-tune: one leaf changes, the rest would be served from cache
    named, _ = flatten_named(f.params)
    name0 = sorted(named)[0]
    from repro.core.protect import unflatten_named
    tuned_named = dict(named)
    tuned_named[name0] = named[name0] + 0.25
    f.publish(2, unflatten_named(None, tuned_named, f.params))
    assert f.dep.poll()["action"] == "started"
    st = f.dep.poll()
    # digest verify forced a refetch; the swap still completed cleanly
    assert st["action"] == "swapped" and st["chunks_corrupt"] >= 1
    assert f.dep.poll()["action"] == "converged"
    assert f.replicas[0].engine.weights.entry_id == 2


def test_objstore_outage_pins_epoch_with_backoff_no_torn_params(tmp_path):
    f = _Fleet(tmp_path, n=2)
    f.publish(1, f.params)
    assert f.actions(4) == ["started", "swapped", "swapped", "converged"]
    old = [_leaf0(r.engine.params).copy() for r in f.replicas]

    # outage while watching: the fleet keeps serving, watch backs off
    f.store.outage = True
    st = f.dep.poll()
    assert st["action"] == "watching" and "outage" in st["error"]
    assert f.dep.poll()["action"] == "watching"   # still in backoff
    f.store.outage = False
    f.t += 1.5
    assert f.dep.poll()["action"] == "idle"

    # outage mid-rollout: the pulling replica pins, backoff grows
    f.publish(2, jax.tree.map(lambda x: x - 0.125, f.params))
    assert f.dep.poll()["action"] == "started"
    f.store.outage = True
    t_fail1 = f.t
    st = f.dep.poll()
    assert st["action"] == "pinned" and st["replica"] == "r0"
    interval1 = st["retry_at"] - t_fail1
    f.t = st["retry_at"]
    st = f.dep.poll()
    assert st["action"] == "pinned"
    assert st["retry_at"] - f.t > interval1       # exponential backoff
    # nothing moved: both replicas bit-exact on entry 1
    assert f.dep.fleet_epochs() == {"r0": 1, "r1": 1}
    for r, leaf in zip(f.replicas, old):
        np.testing.assert_array_equal(_leaf0(r.engine.params), leaf)
    # outage clears → rollout resumes from r0 and converges
    f.store.outage = False
    f.t = st["retry_at"] + 0.1
    assert f.actions(3) == ["swapped", "swapped", "converged"]
    assert f.dep.fleet_epochs() == {"r0": 2, "r1": 2}


def test_generate_is_consistent_through_a_fleet_swap(tmp_path):
    """The serving-path acceptance check: a replica that swaps between
    batches produces exactly what an engine born with the new weights
    would produce from the same state — and a replica that has NOT yet
    swapped still matches the old weights."""
    f = _Fleet(tmp_path, n=1)
    cfg = f.cfg
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                 cfg.vocab_size, jnp.int32)
    r = f.replicas[0]
    r.engine.prefill(prompts)
    f.publish(1, f.params)
    assert f.actions(3) == ["started", "swapped", "converged"]
    tuned = jax.tree.map(lambda x: x + 0.02, f.params)
    f.publish(2, tuned)
    st_before = r.engine.get_state()
    assert f.actions(2) == ["started", "swapped"]
    got = r.engine.generate(4)
    ref = ServingEngine(f.model, tuned, batch=2, max_len=32)
    ref.set_state(st_before)
    want = ref.generate(4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
