"""Pallas TPU kernels: dirty-block compaction (gather) and restore (scatter).

After the dirty-map is computed on device (blockhash.py), the dirty blocks
are packed into a contiguous buffer so a *single* dense DMA ships them to
the host — instead of n_dirty strided host reads. The block indices arrive
via scalar prefetch (``PrefetchScalarGridSpec``), the canonical TPU pattern
for data-dependent addressing: the index vector lands in SMEM before the
grid runs, and each grid step's BlockSpec index_map reads it to choose the
HBM tile to bring into VMEM.

``diffunpack`` is the inverse (restore path): scatter packed blocks back
into the base buffer (aliased in-place via input_output_aliases).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def diffpack_pallas(blocks: jnp.ndarray, dirty_idx: jnp.ndarray,
                    interpret: bool = False) -> jnp.ndarray:
    """Gather: (n_blocks, e) × (n_dirty,) int32 → (n_dirty, e)."""
    n_dirty = dirty_idx.shape[0]
    e = blocks.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_dirty,),
        in_specs=[pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, e), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dirty, e), blocks.dtype),
        interpret=interpret,
    )(dirty_idx, blocks)


def _scatter_kernel(idx_ref, packed_ref, base_ref, out_ref):
    # base is aliased to out; each step overwrites one block row
    out_ref[...] = packed_ref[...]


def diffunpack_pallas(base: jnp.ndarray, packed: jnp.ndarray,
                      dirty_idx: jnp.ndarray, interpret: bool = False
                      ) -> jnp.ndarray:
    """Scatter: write packed rows back at dirty_idx. Returns updated base."""
    n_dirty, e = packed.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_dirty,),
        in_specs=[
            pl.BlockSpec((1, e), lambda i, idx_ref: (i, 0)),            # packed
            pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0)),   # base
        ],
        out_specs=pl.BlockSpec((1, e), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={2: 0},    # alias base → out (in-place)
        interpret=interpret,
    )(dirty_idx, packed, base)
