"""Telemetry walkthrough: trace a store end to end, read the metrics,
probe a live health endpoint, and summarize the trace with chktrace.

The telemetry plane is three stdlib-only pieces (``repro.telemetry``):

- ``trace``   — process-wide span recorder exporting Chrome trace-event
  JSON (load the file at https://ui.perfetto.dev to see Plan → Pack →
  Place → Commit nested per thread, chunk uploads on the transfer pool);
- ``metrics`` — always-on counter/gauge/histogram registry with JSON
  snapshot and Prometheus text exposition;
- ``health``  — a real HTTP endpoint (/healthz /readyz /metrics) whose
  readiness follows the serving swap protocol.

Run:  PYTHONPATH=src python examples/telemetry_trace.py
"""
import json
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro.core.context import CheckpointConfig, CheckpointContext
from repro.telemetry import metrics, trace
from repro.telemetry.health import HealthServer, HealthState
from repro.tools.chktrace import store_critical_paths, build_spans

TRACE_PATH = "/tmp/openchk-telemetry/trace.json"

# --- 1. trace a real store ----------------------------------------------- #
# enable() here; production turns it on from outside via OPENCHK_TRACE=
# <file> or OPENCHK_TRACE_DIR=<dir> (launch/train.py --trace-dir does the
# latter so supervisor + restarted workers merge onto one timeline)
trace.enable(TRACE_PATH)

ctx = CheckpointContext(CheckpointConfig(
    dir="/tmp/openchk-telemetry/ckpt", backend="fti",
    dedicated_thread=False))
state = {"params": {"w": jnp.asarray(
    np.arange(1 << 20, dtype=np.float32))}}
report = ctx.store(state, id=1, level=4)         # L4 → chunk uploads too
ctx.shutdown()
trace.flush()
print(f"trace written: {TRACE_PATH}  (open in ui.perfetto.dev)")
print(f"the report knows its span: StoreReport.span_id={report.span_id}")

# --- 2. ask questions about the trace (what chktrace automates) ---------- #
events = json.load(open(TRACE_PATH))["traceEvents"]
for row in store_critical_paths(build_spans(events)):
    print(f"store ckpt={row['ckpt_id']}: {row['dur_us'] / 1e3:.1f} ms, "
          f"dominant stage = {row['dominant_stage']}")
print("same, from the CLI:  PYTHONPATH=src python -m repro.tools.chktrace "
      + TRACE_PATH)

# --- 3. the metrics the store fed ---------------------------------------- #
snap = metrics.snapshot()
stores = snap["openchk_store_total"]["series"][0]
print(f"openchk_store_total{stores['labels']} = {stores['value']}")
print("prometheus text has",
      len(metrics.to_prometheus().splitlines()), "lines")

# --- 4. a live health endpoint ------------------------------------------- #
# serving replicas get this wired automatically (attach_engine / the
# --health-port flags on launch/serve.py and launch/train.py --supervise)
health = HealthState(name="demo")
srv = HealthServer(health).start()
for ready in (False, True):
    health.set_ready(ready, epoch=1)
    try:
        with urllib.request.urlopen(srv.url + "/readyz", timeout=5) as r:
            code, body = r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        code, body = e.code, e.read().decode()
    print(f"/readyz while ready={ready}: HTTP {code} {body.strip()}")
srv.stop()
