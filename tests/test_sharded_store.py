"""Shard-local stores: the multi-file ``.tmp``-until-commit invariant
(crash after k of n shard files staged → checkpoint not restorable,
recovery falls back), manifest coverage of shard sets, the sharded CHK5
layout + ElasticLoader region reads, and — in a forced-16-device
subprocess — the no-gather Plan guarantee, a store → crash → restore
cycle, and the ``chkls --json`` shard inventory."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.core.resharding as rs
from repro.core import manifest as mf
from repro.core.comm import LocalComm
from repro.core.formats import CHK5Writer
from repro.core.resharding import (
    ElasticLoader,
    ShardChunk,
    ShardSnapshot,
    write_shard_files,
)
from repro.core.storage import StorageConfig, StorageEngine, StoreRequest


def _engine(tmp_path):
    cfg = StorageConfig(root=str(tmp_path / "shared"), block_bytes=256)
    return StorageEngine(cfg, LocalComm(str(tmp_path / "nl")))


def _sharded_plan(eng, ckpt_id, n_chunks=4, rows=16, cols=8):
    """A Plan carrying a hand-built shard snapshot (host chunks — the
    snapshot machinery accepts np data, so the multi-file commit protocol
    is testable without a multi-device mesh)."""
    plan = eng.pipeline.plan(StoreRequest(
        named={"step": np.int32(ckpt_id)}, ckpt_id=ckpt_id, level=1))
    full = np.arange(rows * cols, dtype=np.float32).reshape(rows, cols)
    per = rows // n_chunks
    chunks = [ShardChunk(offset=(k * per, 0), shape=(per, cols),
                         data=full[k * per:(k + 1) * per])
              for k in range(n_chunks)]
    plan.sharded = {"w": ShardSnapshot(
        dtype="<f4", global_shape=(rows, cols), chunks=chunks)}
    return plan, full


def test_crash_mid_shard_write_stays_tmp_and_falls_back(tmp_path,
                                                        monkeypatch):
    """Kill the store after k of n shard files are written: the whole set
    stays in ``.tmp``, the checkpoint is not listed as restorable, and
    recovery falls back to the previous id."""
    eng = _engine(tmp_path)
    eng.store({"w": np.ones(64, np.float32)}, ckpt_id=1, level=1)

    real_writer = rs.CHK5Writer
    made = []

    class ExplodingWriter(real_writer):
        def __init__(self, path, **kw):
            if ".shard" in os.path.basename(path):
                made.append(path)
                if len(made) > 2:       # k=2 of n staged, then crash
                    raise RuntimeError("simulated crash mid-shard-write")
            super().__init__(path, **kw)

    monkeypatch.setattr(rs, "CHK5Writer", ExplodingWriter)
    plan, _ = _sharded_plan(eng, 2, n_chunks=8)
    with pytest.raises(RuntimeError, match="mid-shard-write"):
        eng.pipeline.finish(plan)
    monkeypatch.setattr(rs, "CHK5Writer", real_writer)

    root = eng.pipeline.tier_root(1)
    assert os.path.isdir(mf.ckpt_dir(root, 2, tmp=True))   # staged, not
    assert not os.path.isdir(mf.ckpt_dir(root, 2))         # committed
    assert mf.list_committed(root) == [1]
    named, meta = eng.load_latest()
    assert meta["id"] == 1 and named["w"][0] == 1.0


def test_committed_shard_set_with_lost_file_not_restorable(tmp_path):
    """Post-commit loss of one shard file: the manifest detects the
    incomplete set and the restore walk falls back to the previous id
    instead of assembling a partial leaf."""
    eng = _engine(tmp_path)
    eng.store({"w": np.ones(64, np.float32), "step": np.int32(1)},
              ckpt_id=1, level=1)
    plan, full = _sharded_plan(eng, 2)
    eng.pipeline.finish(plan)

    root = eng.pipeline.tier_root(1)
    man = mf.read_manifest(root, 2)
    files = mf.manifest_files(man)
    shard_files = [f for f in files if ".shard" in f]
    assert "rank0.chk5" in files and len(shard_files) == 4
    assert mf.missing_files(root, 2) == []

    # intact: the sharded leaf restores (materialized) bit-exact
    named, meta = eng.load_latest()
    assert meta["id"] == 2
    np.testing.assert_array_equal(named["w"], full)

    os.remove(os.path.join(mf.ckpt_dir(root, 2), shard_files[1]))
    assert mf.missing_files(root, 2) == [shard_files[1]]
    named, meta = eng.load_latest()       # falls back — never partial data
    assert meta["id"] == 1
    assert int(named["step"]) == 1


def test_partner_tier_replicates_shard_set_across_node_loss(tmp_path):
    """L2: the whole multi-file shard set is replicated to the ring
    partner, so a lost node's sharded checkpoint restores from partner
    copies (rank<k>.partner<j>.shard<s>.chk5)."""
    from repro.core.comm import SimulatedCluster
    cluster = SimulatedCluster(str(tmp_path / "cluster"), 4)
    cfg = StorageConfig(root=str(tmp_path / "shared"), group_size=4,
                        block_bytes=256)
    engines = [StorageEngine(cfg, c) for c in cluster.comms]
    fulls = {}
    for r, eng in enumerate(engines):
        plan, full = _sharded_plan(eng, 1)
        plan.level = 2
        plan.tiers = eng.pipeline.tier_stack(2)
        plan.root = plan.tiers[0].root
        eng.pipeline.finish(plan)
        fulls[r] = full

    victim = 1
    cluster.kill_node(victim)
    got = engines[victim].load_latest()
    assert got is not None, "L2 sharded recovery failed after node loss"
    named, meta = got
    assert meta["recovered_via"] == "partner"
    np.testing.assert_array_equal(named["w"], fulls[victim])


def test_shard_layout_roundtrip_and_elastic_regions(tmp_path):
    """write_shard_files → ElasticLoader: multi-dim chunks reassemble any
    region; the legacy axis-0 layout reads through the same loader."""
    d = str(tmp_path)
    full = np.arange(12 * 10, dtype=np.float32).reshape(12, 10)
    chunks = [ShardChunk(offset=(r * 6, c * 5), shape=(6, 5),
                         data=full[r * 6:(r + 1) * 6, c * 5:(c + 1) * 5])
              for r in range(2) for c in range(2)]
    with CHK5Writer(os.path.join(d, "rank0.chk5")) as w:
        files = write_shard_files(
            d, "rank0", w,
            {"w": ShardSnapshot("<f4", (12, 10), chunks)}, max_writers=3)
    assert len(files) == 3 and all(os.path.exists(p) for p in files)

    loader = ElasticLoader(sorted(files))
    assert loader.names() == ["w"]
    assert loader.global_shape("w") == [12, 10]
    np.testing.assert_array_equal(loader.read_region("w", None), full)
    np.testing.assert_array_equal(
        loader.read_region("w", (slice(3, 9), slice(2, 8))),
        full[3:9, 2:8])
    np.testing.assert_array_equal(loader.read_rows("w", 5, 7), full[5:7])
    with pytest.raises(ValueError, match="not fully covered"):
        ElasticLoader(sorted(files)[:1]).read_region("w", None)
    loader.close()

    # legacy axis-0 chunk files read through the same loader
    legacy = os.path.join(d, "legacy.chk5")
    rs.save_sharded(legacy, {"v": full[4:]}, {"v": 4},
                    {"v": [12, 10]})
    lo = ElasticLoader([legacy])
    np.testing.assert_array_equal(lo.read_rows("v", 6, 10), full[6:10])
    lo.close()

    # OVERLAPPING chunk files (replicated shards merged from several rank
    # files) must assemble, not double-count coverage — regression: the
    # volume-sum check rejected fully-covered overlapping sets
    a = os.path.join(d, "ov-a.chk5")
    b = os.path.join(d, "ov-b.chk5")
    rs.save_sharded(a, {"v": full[0:8]}, {"v": 0}, {"v": [12, 10]})
    rs.save_sharded(b, {"v": full[5:12]}, {"v": 5}, {"v": [12, 10]})
    lo = ElasticLoader([a, b])
    np.testing.assert_array_equal(lo.read_region("v", None), full)
    np.testing.assert_array_equal(lo.read_rows("v", 3, 11), full[3:11])
    lo.close()
    # a genuine hole still raises, overlap or not
    c = os.path.join(d, "ov-c.chk5")
    rs.save_sharded(c, {"v": full[9:12]}, {"v": 9}, {"v": [12, 10]})
    lo = ElasticLoader([a, c])
    with pytest.raises(ValueError, match="not fully covered"):
        lo.read_region("v", None)
    lo.close()


def test_shard_chunk_int8_codec_roundtrip_regions_and_verify(tmp_path):
    """``compress="int8"`` reaches shard chunks: each chunk quantizes
    independently (scales in the same shard file), region reads decode
    only the touched blocks, a full-chunk read verifies the recorded
    dequantized crc32, and corruption is caught — closing the ROADMAP
    "chunks ship raw" gap."""
    from repro.core.formats import CHK5CorruptionError, CHK5Reader
    from repro.core.protect import Protect
    from repro.core.resharding import resolve_shard_refs
    from repro.dist.compression import dequantize_int8_np, quantize_int8_np

    d = str(tmp_path)
    rng = np.random.default_rng(1)
    full = rng.normal(size=(16, 10)).astype(np.float32)
    chunks = [ShardChunk(offset=(r * 8, c * 5), shape=(8, 5),
                         data=full[r * 8:(r + 1) * 8, c * 5:(c + 1) * 5])
              for r in range(2) for c in range(2)]
    with CHK5Writer(os.path.join(d, "rank0.chk5")) as w:
        files = write_shard_files(
            d, "rank0", w, {"w": ShardSnapshot("<f4", (16, 10), chunks)},
            specs={"w": Protect("w", compress="int8")}, max_writers=2)

    exp = np.empty_like(full)
    for c in chunks:
        q, s = quantize_int8_np(np.ascontiguousarray(c.data))
        back = dequantize_int8_np(q, s, c.data.shape).astype(np.float32)
        exp[c.offset[0]:c.offset[0] + 8, c.offset[1]:c.offset[1] + 5] = back

    loader = ElasticLoader(sorted(files))
    np.testing.assert_array_equal(loader.read_region("w", None), exp)
    np.testing.assert_array_equal(                   # partial-block decode
        loader.read_region("w", (slice(3, 13), slice(2, 9))),
        exp[3:13, 2:9])
    loader.close()
    assert np.abs(exp - full).max() <= np.abs(full).max() / 127 + 1e-6

    # lazy-ref restore path (what the pipeline hands TCL) decodes too
    rd = CHK5Reader(os.path.join(d, "rank0.chk5"))
    assert rd.info("shardidx/w")["attrs"].get("codec") == "int8"
    refs = resolve_shard_refs(rd, [d], 0)
    np.testing.assert_array_equal(refs["w"].materialize(), exp)
    rd.close()

    # per-chunk attrs: codec + scales dataset + dequantized crc32
    frd = CHK5Reader(sorted(files)[0])
    ds = [x for x in frd.datasets() if x.startswith("shard/")][0]
    attrs = frd.info(ds)["attrs"]
    assert attrs["codec"] == "int8" and "roundtrip_crc32" in attrs
    assert f"codecaux/{ds}/scale" in frd.datasets()
    off = frd.info(ds)["offset"]
    frd.close()

    # flip one payload byte: the full-chunk dequantized-crc verify trips
    with open(sorted(files)[0], "r+b") as fh:
        fh.seek(off)
        b = fh.read(1)
        fh.seek(off)
        fh.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CHK5CorruptionError, match="roundtrip"):
        ElasticLoader(sorted(files)).read_region("w", None)


def test_shard_chunk_int8_fallbacks(tmp_path):
    """Non-float leaves and chunks whose roundtrip error exceeds
    ``max_error`` ship raw, with the reason recorded per dataset."""
    from repro.core.formats import CHK5Reader
    from repro.core.protect import Protect

    d = str(tmp_path)
    ints = np.arange(40, dtype=np.int32).reshape(8, 5)
    # random normals roundtrip with ~1e-3 relative L2 — any bound tighter
    # than that trips the per-chunk fallback
    wild = np.random.default_rng(2).normal(size=(4, 2)).astype(np.float32)
    snaps = {
        "i": ShardSnapshot("<i4", (8, 5),
                           [ShardChunk((0, 0), (8, 5), ints)]),
        "f": ShardSnapshot("<f4", (4, 2),
                           [ShardChunk((0, 0), (4, 2), wild)]),
    }
    specs = {"i": Protect("i", compress="int8"),
             "f": Protect("f", compress="int8", max_error=1e-9)}
    with CHK5Writer(os.path.join(d, "rank0.chk5")) as w:
        files = write_shard_files(d, "rank0", w, snaps, specs=specs,
                                  max_writers=1)
    rd = CHK5Reader(os.path.join(d, "rank0.chk5"))
    assert "codec_fallback" in rd.info("shardidx/i")["attrs"]
    rd.close()
    frd = CHK5Reader(files[0])
    fa = frd.info("shard/f/shard-0")["attrs"]
    assert "codec" not in fa and "max_error" in fa["codec_fallback"]
    ia = frd.info("shard/i/shard-0")["attrs"]
    assert "codec" not in ia
    frd.close()
    # raw fallbacks restore bit-exact
    loader = ElasticLoader(files)
    np.testing.assert_array_equal(loader.read_region("i", None), ints)
    np.testing.assert_array_equal(loader.read_region("f", None), wild)
    loader.close()


SUBPROC_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.core.resharding import reshard_tree

    def make_state(mesh):
        state = {"params": {
            "w": jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64),
            "b": jnp.arange(32.0)}, "step": jnp.int32(7)}
        sh = {"params": {"w": NamedSharding(mesh, P("data", "model")),
                         "b": NamedSharding(mesh, P())},
              "step": NamedSharding(mesh, P())}
        return reshard_tree(state, sh)
""")

STORE_CRASH_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    ckpt_dir = sys.argv[1]
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    state = make_state(mesh)

    # --- the no-gather Plan guarantee -------------------------------- #
    import repro.core.protect as protect_mod
    import repro.core.pipeline as pipeline_mod
    gathered = []
    real_to_host = protect_mod.to_host
    def spy_to_host(named):
        gathered.extend(named)
        return real_to_host(named)
    protect_mod.to_host = spy_to_host
    pipeline_mod.to_host = spy_to_host

    ctx = CheckpointContext(CheckpointConfig(
        dir=ckpt_dir, backend="fti", dedicated_thread=False))
    ctx.store(state, id=1, level=1)
    # the sharded leaf never went through the host gather, and its Plan
    # snapshot holds per-shard device references, each 1/16 of the leaf
    assert "params/w" not in gathered, gathered
    from repro.core.storage import StoreRequest
    from repro.core.protect import flatten_named
    named, _ = flatten_named(state)
    plan = ctx.tcl.backend.pipeline.plan(StoreRequest(
        named=named, ckpt_id=99, level=1))
    snap = plan.sharded["params/w"]
    assert len(snap.chunks) == 16
    assert all(c.shape == (16, 16) for c in snap.chunks)
    assert all(not isinstance(c.data, np.ndarray) for c in snap.chunks)
    assert "params/w" not in (plan.named_host or {})
    ctx.tcl.backend.pipeline.abort_plan(plan)

    # --- crash after k of n shard files staged ----------------------- #
    import repro.core.resharding as rs
    real_writer = rs.CHK5Writer
    made = []
    class DyingWriter(real_writer):
        def close(self):
            super().close()
            if ".shard" in os.path.basename(self.path):
                made.append(self.path)
                if len(made) == 2:     # k=2 of n staged, then hard kill
                    os._exit(7)
    rs.CHK5Writer = DyingWriter
    state2 = dict(state, step=jnp.int32(8))
    ctx.store(state2, id=2, level=1)   # never returns
    raise SystemExit("store survived the injected crash")
""")

RESTORE_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    import glob, io, json, contextlib
    from repro.core.protect import flatten_named
    from repro.tools.chkls import main as chkls_main

    ckpt_dir = sys.argv[1]
    local = os.path.join(ckpt_dir, "node-local", "ckpts")
    # the crashed store left its partial multi-file set staged, uncommitted
    assert os.path.isdir(os.path.join(local, "ckpt-2.tmp"))
    assert not os.path.isdir(os.path.join(local, "ckpt-2"))
    staged = glob.glob(os.path.join(local, "ckpt-2.tmp", "*.shard*.chk5"))
    assert len(staged) >= 2, staged

    # shard inventory of the committed checkpoint via chkls --json
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert chkls_main([os.path.join(local, "ckpt-1", "rank0.chk5"),
                           "--json"]) == 0
    inv = json.loads(buf.getvalue())
    by_name = {d["name"]: d for d in inv["datasets"]}
    idx = by_name["shardidx/params/w"]
    assert idx["attrs"]["n_chunks"] == 16
    assert idx["attrs"]["global_shape"] == [64, 64]
    assert sorted(set(idx["attrs"]["files"])) == [
        f"rank0.shard{j}.chk5" for j in range(4)]
    assert inv["attrs"]["sharded"] is True
    for j in range(4):
        assert os.path.exists(os.path.join(local, "ckpt-1",
                                           f"rank0.shard{j}.chk5"))

    # restore on a different mesh shape — falls back to id 1
    mesh_b = jax.make_mesh((2, 8), ("data", "model"))
    template = make_state(mesh_b)
    template = jax.tree.map(jnp.zeros_like, template)
    ctx = CheckpointContext(CheckpointConfig(
        dir=ckpt_dir, backend="fti", dedicated_thread=False))
    got = ctx.load(template)
    assert ctx.restarted
    ctx.shutdown()
    named = flatten_named(got)[0]
    assert int(named["step"]) == 7          # id 1, not the crashed id 2
    want = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    np.testing.assert_array_equal(np.asarray(named["params/w"]), want)
    print("SHARDED-CRASH-RESTORE-OK")
""")


def test_sharded_store_crash_restore_subprocess(tmp_path):
    """Forced-16-device lane: shard-local store (no gather in Plan), a
    hard kill after 2 of 4 shard files staged, then a fresh process
    restores the previous id on a different mesh and the shard inventory
    checks out via ``chkls --json``."""
    d = str(tmp_path / "ck")
    r = subprocess.run([sys.executable, "-c", STORE_CRASH_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert r.returncode == 7, r.stdout[-2000:] + r.stderr[-3000:]
    r = subprocess.run([sys.executable, "-c", RESTORE_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "SHARDED-CRASH-RESTORE-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
