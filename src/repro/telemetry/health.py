"""Live health endpoints: ``/healthz``, ``/readyz``, ``/metrics``.

A :class:`HealthServer` is a real stdlib HTTP server (ThreadingHTTPServer
on a daemon thread, loopback only, ephemeral port by default) fronting a
thread-safe :class:`HealthState`:

- ``/healthz`` — 200 while the process is up; liveness never flips.
- ``/readyz``  — 200/503 from ``HealthState.ready`` plus its info dict
  (epoch, entry id, reason).  For a serving replica readiness follows the
  ``WeightsHandle`` swap protocol: :func:`attach_engine` chains onto
  ``ServingEngine.swap_hook`` so every atomic weight flip re-asserts
  readiness with the new epoch — and a deployer can drop readiness for the
  pull window so a rolling swap is observable from outside the process.
- ``/metrics`` — Prometheus text from the process-wide registry.

One server per serving replica and one per supervisor; everything is
stdlib so the endpoint works in the most degraded environments (which is
when you need it).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

from repro.telemetry import metrics


class HealthState:
    """Thread-safe readiness flag + info payload for ``/readyz``."""

    def __init__(self, name: str = "", ready: bool = False) -> None:
        self._lock = threading.Lock()
        self.name = name
        self._ready = ready
        self._info: Dict[str, Any] = {}

    def set_ready(self, ready: bool, **info: Any) -> None:
        with self._lock:
            self._ready = bool(ready)
            self._info.update(info)
        if self.name:
            metrics.gauge("openchk_serve_ready",
                          replica=self.name).set(1.0 if ready else 0.0)

    @property
    def ready(self) -> bool:
        with self._lock:
            return self._ready

    def describe(self) -> Dict[str, Any]:
        with self._lock:
            d = dict(self._info)
        d["ready"] = self.ready
        if self.name:
            d["name"] = self.name
        return d


class _Handler(BaseHTTPRequestHandler):
    # set per-server via type(); BaseHTTPRequestHandler has no ctor hook
    state: HealthState

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/healthz":
            self._send(200, b'{"status": "ok"}\n', "application/json")
        elif path == "/readyz":
            d = self.state.describe()
            body = (json.dumps(d) + "\n").encode()
            self._send(200 if d["ready"] else 503, body,
                       "application/json")
        elif path == "/metrics":
            self._send(200, metrics.to_prometheus().encode(),
                       "text/plain; version=0.0.4")
        else:
            self._send(404, b'{"error": "not found"}\n', "application/json")

    def log_message(self, fmt: str, *args: Any) -> None:
        return  # health probes must not spam stderr


class HealthServer:
    """HTTP endpoint for one HealthState.  ``port=0`` → ephemeral."""

    def __init__(self, state: HealthState, port: int = 0,
                 host: str = "127.0.0.1") -> None:
        self.state = state
        handler = type("BoundHandler", (_Handler,), {"state": state})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "HealthServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"health:{self.state.name or self.port}", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"


def attach_engine(engine: Any, name: str = "serve",
                  port: Optional[int] = None) -> HealthState:
    """Bind a HealthState to a ServingEngine's swap protocol.

    Readiness starts True (the engine is constructed with weights) and is
    re-asserted — with the fresh epoch/entry id — on every ``set_weights``
    by chaining onto ``swap_hook``.  The deployer flips it False for the
    pull window; the swap hook flips it back.  With *port*, also starts a
    HealthServer and stores it at ``state.server``."""
    state = HealthState(name=name, ready=True)
    handle = engine.weights
    state.set_ready(True, epoch=int(handle.epoch),
                    entry_id=handle.entry_id)

    prev_hook = engine.swap_hook

    def _hook(old: Any, new: Any) -> None:
        state.set_ready(True, epoch=int(new.epoch), entry_id=new.entry_id,
                        reason="swapped")
        if prev_hook is not None:
            prev_hook(old, new)

    engine.swap_hook = _hook
    if port is not None:
        state.server = HealthServer(state, port=port).start()  # type: ignore[attr-defined]
    return state
