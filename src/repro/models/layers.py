"""Shared model building blocks (pure-functional, pytree params).

All layers are plain functions ``f(params, x, ...) -> y``; parameters are
created by ``init_*`` functions returning pytrees of jnp arrays. Layer stacks
are stored *stacked along axis 0* so the transformer can ``lax.scan`` over
depth (one compiled layer body regardless of n_layers — essential for the
40-cell × 2-mesh dry-run on a single CPU core).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def groupnorm_heads(x: jnp.ndarray, n_heads: int, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm with one group per head over the last dim (RWKV wkv output)."""
    dt = x.dtype
    *lead, d = x.shape
    x32 = x.astype(jnp.float32).reshape(*lead, n_heads, d // n_heads)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.reshape(*lead, d).astype(dt)


# --------------------------------------------------------------------------- #
# activations / MLP
# --------------------------------------------------------------------------- #

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def init_mlp(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def mlp(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    a = _ACTS[act]
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------------- #
# rotary embedding
# --------------------------------------------------------------------------- #


def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    dim = x.shape[-1]
    freqs = rope_frequencies(dim, theta)                       # (dim/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dim/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., S, 1, dim/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------------- #


def cast_floating(tree, dtype):
    """Cast floating leaves to ``dtype`` (params stored fp32, computed bf16)."""
    import jax

    def one(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(one, tree)


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Stable CE; logits (..., V) fp32-promoted, labels int (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - label_logit
