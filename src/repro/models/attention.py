"""Attention variants: GQA (+qkv bias), sliding-window, MLA, cross-attention.

Design notes (see DESIGN.md §4):
- Training/prefill attention scans over query blocks (`lax.scan`) so the
  materialized score tensor is O(q_block × S) instead of O(S²) — required to
  fit 32k-token prefill in HBM. Softmax is fp32.
- Sliding-window attention slices the K/V sequence with a dynamic (but
  statically-sized) window per query block, so SWA flops are O(S·W) not
  O(S²) in the compiled HLO.
- Decode uses in-place cache update (`dynamic_update_slice`); sliding-window
  decode uses a rolling O(W) cache. MLA decode runs in the *absorbed* form
  (cache = latents, W_uk folded into the query) — the compressed-KV-cache
  trick that makes MLA worth its name.
- Tensors are (batch, seq, heads, head_dim) internally; GQA scores are
  computed without materializing repeated KV heads.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.context import DATA, MODEL, shard_decode_kv, shard_hint
from repro.models.layers import apply_rope, dense_init

Params = Dict[str, Any]

_NEG_INF = -1e30


def _attn_impl() -> str:
    """"blockwise" (default) or "flash" (fused Pallas kernel — the §Perf
    memory-term fix; set REPRO_ATTN_IMPL=flash)."""
    import os
    return os.environ.get("REPRO_ATTN_IMPL", "blockwise")


def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B,S,KV,dh) → (B,S,KV·n_rep,dh). Materializing the repeat lets the
    head dim shard cleanly on the model axis (MaxText-style GQA TP)."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (b, s, kv, n_rep, dh)).reshape(
        b, s, kv * n_rep, dh)


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #


def init_gqa(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, dtype),
        "wk": dense_init(ks[1], d, kv * dh, dtype),
        "wv": dense_init(ks[2], d, kv * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, h * qk, dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "w_kr": dense_init(ks[3], d, m.qk_rope_dim, dtype),
        "w_uk": dense_init(ks[4], m.kv_lora_rank, h * m.qk_nope_dim, dtype),
        "w_uv": dense_init(ks[5], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def init_cross_attn(key, cfg: ArchConfig, dtype) -> Params:
    return init_gqa(key, cfg, dtype)


def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    if cfg.attn_kind == "mla":
        return init_mla(key, cfg, dtype)
    return init_gqa(key, cfg, dtype)


# --------------------------------------------------------------------------- #
# core blockwise attention
# --------------------------------------------------------------------------- #


def _scores_softmax_v(q, k, v, mask, scale):
    """q:(B,Qb,H,dh) k/v:(B,Sk,H,dh) mask:(Qb,Sk) bool → (B,Qb,H,dh)."""
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def blockwise_attention(
    q: jnp.ndarray,              # (B, S, H, dh)
    k: jnp.ndarray,              # (B, S, H, dh)  (KV pre-repeated to H)
    v: jnp.ndarray,              # (B, S, H, dh)
    *,
    causal: bool,
    window: Optional[int] = None,
    q_block: int = 512,
) -> jnp.ndarray:
    """Query-block-scanned attention. O(q_block·Sk) memory per step.

    With ``window`` set, each query block attends only to a dynamically
    sliced K/V span of length ``window + q_block`` — sub-quadratic SWA.
    """
    b, s, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qb = min(q_block, s)
    n_blocks = s // qb
    assert s % qb == 0, f"seq {s} not divisible by q_block {qb}"

    use_window = window is not None and causal and (window + qb) < s
    span = (window + qb) if use_window else s

    q_blocks = q.reshape(b, n_blocks, qb, h, dh)

    def body(_, i):
        qi = q_blocks[:, i]                     # (B, qb, H, dh)
        q_start = i * qb
        if use_window:
            k_start = jnp.clip(q_start + qb - span, 0, s - span)
            ki = jax.lax.dynamic_slice_in_dim(k, k_start, span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, k_start, span, axis=1)
            k_pos = k_start + jnp.arange(span)
        else:
            ki, vi = k, v
            k_pos = jnp.arange(s)
        q_pos = q_start + jnp.arange(qb)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
        else:
            mask = jnp.ones((qb, k_pos.shape[0]), dtype=bool)
        return None, _scores_softmax_v(qi, ki, vi, mask, scale)

    _, out = jax.lax.scan(body, None, jnp.arange(n_blocks))
    # out: (n_blocks, B, qb, H, dv) → (B, S, H, dv); dv may differ from dh (MLA)
    dv = v.shape[-1]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)


# --------------------------------------------------------------------------- #
# GQA full layer (train/prefill)
# --------------------------------------------------------------------------- #


def gqa_attention(
    p: Params,
    x: jnp.ndarray,              # (B, S, d)
    cfg: ArchConfig,
    *,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    use_rope: Optional[bool] = None,             # default: cfg.use_rope
    kv_override: Optional[jnp.ndarray] = None,   # cross-attn: encoder states
) -> jnp.ndarray:
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = kv_override if kv_override is not None else x
    sk = src.shape[1]

    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, sk, kv, dh)
    v = v.reshape(b, sk, kv, dh)

    if use_rope is None:
        use_rope = cfg.use_rope
    if use_rope and kv_override is None:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    k = repeat_kv(k, h // kv)
    v = repeat_kv(v, h // kv)
    q = shard_hint(q, DATA, None, MODEL, None)
    k = shard_hint(k, DATA, None, MODEL, None)
    v = shard_hint(v, DATA, None, MODEL, None)

    is_causal = causal and kv_override is None
    if _attn_impl() == "flash" and cfg.sliding_window is None:
        from repro.kernels.flashattn import flash_attention_bshd
        out = flash_attention_bshd(
            q, k, v, causal=is_causal,
            interpret=jax.default_backend() != "tpu")
    else:
        out = blockwise_attention(
            q, k, v, causal=is_causal, window=cfg.sliding_window)
    return out.reshape(b, s, h * dh) @ p["wo"]


# --------------------------------------------------------------------------- #
# GQA decode (one step, KV cache)
# --------------------------------------------------------------------------- #


class KVCache(NamedTuple):
    k: jnp.ndarray               # (B, C, KV, dh)  C = max_len or window
    v: jnp.ndarray

    def is_windowed(self, cfg: ArchConfig) -> bool:
        """Rolling cache iff allocated at exactly the sliding window size."""
        return cfg.sliding_window is not None and self.k.shape[1] == cfg.sliding_window


def init_kv_cache(batch: int, cfg: ArchConfig, max_len: int, dtype) -> KVCache:
    w = cfg.sliding_window
    c = w if (w is not None and w < max_len) else max_len
    shape = (batch, c, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def gqa_decode(
    p: Params,
    x: jnp.ndarray,              # (B, 1, d)
    cache: KVCache,
    pos,                         # scalar int32 — current position
    cfg: ArchConfig,
    *,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, KVCache]:
    b = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, 1, h, dh)
    pos_b = jnp.full((b, 1), pos)
    if cfg.use_rope:
        q = apply_rope(q, pos_b, cfg.rope_theta)

    if kv_override is not None:                   # cross-attn: static cache
        ck, cv = kv_override
        mask = jnp.ones((ck.shape[1],), dtype=bool)
    else:
        knew = x @ p["wk"]
        vnew = x @ p["wv"]
        if cfg.qkv_bias:
            knew = knew + p["bk"]
            vnew = vnew + p["bv"]
        knew = knew.reshape(b, 1, kvh, dh)
        if cfg.use_rope:
            knew = apply_rope(knew, pos_b, cfg.rope_theta)
        vnew = vnew.reshape(b, 1, kvh, dh)
        c = cache.k.shape[1]
        windowed = cache.is_windowed(cfg)
        slot = jax.lax.rem(pos, c) if windowed else pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, knew, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vnew, slot, axis=1)
        cache = KVCache(ck, cv)
        idx = jnp.arange(c)
        if windowed:
            mask = (idx <= pos) | (pos >= c)      # all slots valid once wrapped
        else:
            mask = idx <= pos

    kr = shard_decode_kv(repeat_kv(ck, h // ck.shape[2]))
    vr = shard_decode_kv(repeat_kv(cv, h // cv.shape[2]))
    scores = jnp.einsum("bhd,bshd->bhs", q[:, 0], kr).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vr.dtype)
    out = jnp.einsum("bhs,bshd->bhd", probs, vr).reshape(b, 1, h * dh)
    return out @ p["wo"], cache


# --------------------------------------------------------------------------- #
# MLA (Multi-head Latent Attention)
# --------------------------------------------------------------------------- #


def mla_attention(
    p: Params,
    x: jnp.ndarray,              # (B, S, d)
    cfg: ArchConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Training/prefill MLA in the naive (materialized K/V) form."""
    m = cfg.mla
    assert m is not None
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    pos = positions if positions is not None else jnp.arange(s)[None, :]

    q = (x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    c_kv = x @ p["w_dkv"]                                   # (B,S,r)
    k_rope = apply_rope(
        (x @ p["w_kr"]).reshape(b, s, 1, m.qk_rope_dim), pos, cfg.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, m.qk_nope_dim)
    vv = (c_kv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))], axis=-1)
    out = blockwise_attention(q_full, k_full, vv, causal=True)
    return out.reshape(b, s, h * m.v_head_dim) @ p["wo"]


class MLACache(NamedTuple):
    c_kv: jnp.ndarray            # (B, C, r)       latent cache
    k_rope: jnp.ndarray          # (B, C, rope_dim)


def init_mla_cache(batch: int, cfg: ArchConfig, max_len: int, dtype) -> MLACache:
    m = cfg.mla
    assert m is not None
    return MLACache(
        jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    )


def mla_decode(
    p: Params,
    x: jnp.ndarray,              # (B, 1, d)
    cache: MLACache,
    pos,
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-form MLA decode: attend over latents; W_uk folded into q."""
    m = cfg.mla
    assert m is not None
    b = x.shape[0]
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    scale = 1.0 / jnp.sqrt(qk).astype(jnp.float32)
    pos_b = jnp.full((b, 1), pos)

    q = ((x @ p["w_dq"]) @ p["w_uq"]).reshape(b, 1, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos_b, cfg.rope_theta)
    # fold W_uk: q_abs[h, r] = q_nope[h, n] · W_uk[r, h, n]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)   # (B,H,r)

    c_new = (x @ p["w_dkv"])                                 # (B,1,r)
    kr_new = apply_rope(
        (x @ p["w_kr"]).reshape(b, 1, 1, m.qk_rope_dim), pos_b, cfg.rope_theta
    ).reshape(b, 1, m.qk_rope_dim)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, pos, axis=1)
    cache = MLACache(c_kv, k_rope)

    c_kv_s = shard_decode_kv(c_kv, model_dim=None)
    k_rope_s = shard_decode_kv(k_rope, model_dim=None)
    sc = jnp.einsum("bhr,bsr->bhs", q_abs, c_kv_s)
    sc = sc + jnp.einsum("bhe,bse->bhs", q_rope[:, 0], k_rope_s)
    sc = sc.astype(jnp.float32) * scale
    mask = jnp.arange(c_kv.shape[1]) <= pos
    sc = jnp.where(mask[None, None], sc, _NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1).astype(c_kv.dtype)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, c_kv_s)        # (B,H,r)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv).reshape(b, 1, h * m.v_head_dim)
    return o @ p["wo"], cache


# --------------------------------------------------------------------------- #
# dispatch helpers
# --------------------------------------------------------------------------- #


def attention(p, x, cfg: ArchConfig, **kw):
    if cfg.attn_kind == "mla":
        kw.pop("causal", None)
        kw.pop("use_rope", None)
        return mla_attention(p, x, cfg, **kw)
    return gqa_attention(p, x, cfg, **kw)


def init_decode_cache(batch: int, cfg: ArchConfig, max_len: int, dtype):
    if cfg.attn_kind == "mla":
        return init_mla_cache(batch, cfg, max_len, dtype)
    return init_kv_cache(batch, cfg, max_len, dtype)


def attention_decode(p, x, cache, pos, cfg: ArchConfig):
    if cfg.attn_kind == "mla":
        return mla_decode(p, x, cache, pos, cfg)
    return gqa_decode(p, x, cache, pos, cfg)
