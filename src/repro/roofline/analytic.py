"""Analytic per-device cost model (flops / HBM bytes / collective wire bytes).

Why analytic: XLA's HloCostAnalysis counts a ``while`` body ONCE regardless
of trip count (verified in tests/test_roofline.py), so any scanned model —
layers, query blocks, SSM chunks, microbatches — undercounts by the trip
count. Buffer assignment (memory_analysis) is loop-correct, cost_analysis
is not. We therefore compute the roofline terms from the model's own
structure — every matmul in repro/models is enumerated here with its exact
sharded dimensions — and *calibrate* against compiled cost_analysis on
fully-unrolled small cells (§Roofline in EXPERIMENTS.md reports agreement).

Conventions:
- flops are implementation-faithful: blockwise attention computes the full
  S×S_k score matrix (no causal block skipping), SWA restricts S_k to
  window+q_block; the MODEL_FLOPS/HLO ratio then *shows* the causal 2×.
- bytes count major HBM traffic: weight reads, activation reads+writes of
  (T,d)-scale tensors, attention score round-trips, optimizer state.
- collective wire bytes use the same ring model as analyze.py.
- backward = 2× forward matmul flops; full remat adds one forward
  recompute (train multiplier 4× vs 3×).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeSpec
from repro.roofline import hw


@dataclass
class Costs:
    flops: float = 0.0            # per device
    bytes: float = 0.0            # per device HBM traffic
    wire: float = 0.0             # per device collective bytes on the wire
    grad_wire: float = 0.0        # gradient-sync wire (overlappable)
    notes: Dict[str, float] = field(default_factory=dict)
    byte_notes: Dict[str, float] = field(default_factory=dict)

    def add(self, tag: str, flops: float = 0.0, bytes_: float = 0.0,
            wire: float = 0.0, grad_wire: float = 0.0):
        self.flops += flops
        self.bytes += bytes_
        self.wire += wire
        self.grad_wire += grad_wire
        if flops:
            self.notes[tag] = self.notes.get(tag, 0.0) + flops
        if bytes_:
            self.byte_notes[tag] = self.byte_notes.get(tag, 0.0) + bytes_


@dataclass
class MeshModel:
    dp: int
    tp: int

    @property
    def chips(self) -> int:
        return self.dp * self.tp


def _div(n: int, k: int) -> bool:
    return k > 1 and n % k == 0


def _ring(nbytes: float, n: int, op: str) -> float:
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if op == "ar":
        return 2 * nbytes * frac
    if op == "ag" or op == "a2a":     # nbytes = gathered/global size
        return nbytes * frac
    if op == "rs":
        return nbytes * frac          # nbytes = input (pre-scatter) size
    if op == "cp":
        return nbytes
    raise ValueError(op)


class CellModel:
    """Per-(arch × shape × mesh × knobs) analytic cost builder."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, mesh: MeshModel,
                 *, remat: bool = True, zero1: bool = False,
                 fsdp: bool = False, q_block: int = 512,
                 causal_skip: bool = False, softmax_bytes: int = 4,
                 attn_impl: str = "blockwise",
                 grad_compress: Optional[str] = None,
                 overlap_gradsync: bool = False):
        self.cfg = cfg
        self.shape = shape
        self.m = mesh
        self.remat = remat
        self.zero1 = zero1
        self.fsdp = fsdp
        self.q_block = q_block
        self.causal_skip = causal_skip     # beyond-paper: block-skip attention
        self.softmax_bytes = softmax_bytes  # fp32 (4) or bf16 (2) score traffic
        self.attn_impl = attn_impl          # "blockwise" | "flash" (Pallas)
        self.grad_compress = grad_compress  # None | "int8"
        self.overlap_gradsync = overlap_gradsync
        self.wdt = 2 if cfg.param_dtype == "bfloat16" else 4
        self.adt = 2                        # bf16 activations
        self.train = shape.kind == "train"
        # tokens per device (per step; decode: 1 token × local batch)
        dp = mesh.dp
        if shape.kind == "decode":
            self.b_loc = max(1, shape.global_batch // dp)
            self.t_loc = self.b_loc
        else:
            self.b_loc = max(1, shape.global_batch // dp)
            self.t_loc = self.b_loc * shape.seq_len
        self.c = Costs()

    # ------------------------------------------------------------------ #
    # primitives
    # ------------------------------------------------------------------ #

    def _fwd_mult(self) -> float:
        """Train: fwd + bwd (2×) + remat refwd (1×) = 4 (3 without remat)."""
        if not self.train:
            return 1.0
        return 4.0 if self.remat else 3.0

    def matmul(self, tag: str, t: float, d_in: int, d_out: int,
               shardable: bool = True, weight: bool = True,
               mult: Optional[float] = None):
        """t local rows through a (d_in, d_out) weight, col/row TP-sharded."""
        tp = self.m.tp if shardable else 1
        mult = self._fwd_mult() if mult is None else mult
        f = 2.0 * t * d_in * d_out / tp * mult
        # weight read per pass + activation in/out
        wreads = (2 if self.train else 1)
        b = (d_in * d_out / tp) * self.wdt * wreads * (1 if weight else 0)
        b += t * d_in * self.adt * mult
        b += t * (d_out / tp) * self.adt * mult
        if self.fsdp and weight and self.train:
            # params also sharded over dp → all-gather fwd + bwd refwd
            self.c.add(tag + "/fsdp-ag", wire=_ring(
                d_in * d_out / tp * self.wdt, self.m.dp, "ag") * 2)
        self.c.add(tag, f, b)

    def tp_allreduce(self, tag: str, t: float, d: int, per_pass: int = 1):
        """Megatron row-parallel output psum: activations (t, d)."""
        if self.m.tp <= 1:
            return
        passes = (3 if self.train else 1)   # fwd + bwd(dx) + remat refwd
        if self.train and not self.remat:
            passes = 2
        self.c.add(tag, wire=_ring(t * d * self.adt, self.m.tp, "ar")
                   * per_pass * passes)

    def act_traffic(self, tag: str, t: float, d: int, n_tensors: float):
        self.c.add(tag, bytes_=t * d * self.adt * n_tensors * self._fwd_mult())

    # ------------------------------------------------------------------ #
    # components
    # ------------------------------------------------------------------ #

    def attention_layer(self, s_q: float, s_kv: float, causal: bool):
        cfg = self.cfg
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        t_q = self.b_loc * s_q
        # projections
        if cfg.attn_kind == "mla":
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            self.matmul("attn/q_a", t_q, cfg.d_model, m.q_lora_rank)
            self.matmul("attn/q_b", t_q, m.q_lora_rank, h * qk)
            self.matmul("attn/kv_a", t_q, cfg.d_model,
                        m.kv_lora_rank + m.qk_rope_dim)
            self.matmul("attn/k_b", t_q, m.kv_lora_rank, h * m.qk_nope_dim)
            self.matmul("attn/v_b", t_q, m.kv_lora_rank, h * m.v_head_dim)
            self.matmul("attn/o", t_q, h * m.v_head_dim, cfg.d_model)
            dh_eff = qk
            dv_eff = m.v_head_dim
        else:
            self.matmul("attn/q", t_q, cfg.d_model, h * dh)
            self.matmul("attn/k", t_q, cfg.d_model, kv * dh)
            self.matmul("attn/v", t_q, cfg.d_model, kv * dh)
            self.matmul("attn/o", t_q, h * dh, cfg.d_model)
            dh_eff = dv_eff = dh
        self.tp_allreduce("attn/psum", t_q, cfg.d_model)

        # scores: S_kv restricted by window; causal halving only when the
        # implementation actually skips blocks (causal_skip knob)
        window = cfg.sliding_window
        if window is not None and causal and (window + self.q_block) < s_kv:
            s_eff = window + self.q_block
        else:
            s_eff = s_kv
            if causal and self.causal_skip:
                s_eff = s_kv / 2 + self.q_block / 2
        h_loc = h / self.m.tp if _div(h, self.m.tp) else h
        f = 2.0 * self.b_loc * h_loc * s_q * s_eff * (dh_eff + dv_eff)
        f *= self._fwd_mult()
        if self.attn_impl == "flash":
            # fused Pallas flash kernel (kernels/flashattn.py): score matrix
            # lives in VMEM only — no HBM round-trip
            score_b = 0.0
        else:
            # score traffic: one write + one read of (s_q, s_eff) per head
            score_b = self.b_loc * h_loc * s_q * s_eff * self.softmax_bytes * 2
            score_b *= self._fwd_mult()
        # k/v read per q block round (streaming reads)
        kv_b = (s_q / self.q_block) * self.b_loc * h_loc * s_eff * \
            2 * dh_eff * self.adt
        self.c.add("attn/scores", f, score_b + kv_b)

    def attention_decode_layer(self, s_cache: float):
        cfg = self.cfg
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        b = self.b_loc
        window = cfg.sliding_window
        s_eff = min(s_cache, window) if window else s_cache
        # cache sharding: batch over dp when divisible, else seq over dp
        if not _div(self.shape.global_batch, self.m.dp):
            s_eff = s_eff / self.m.dp
        if cfg.attn_kind == "mla":
            m = cfg.mla
            r = m.kv_lora_rank
            self.matmul("attn/q_a", b, cfg.d_model, m.q_lora_rank)
            self.matmul("attn/q_b", b, m.q_lora_rank,
                        h * (m.qk_nope_dim + m.qk_rope_dim))
            self.matmul("attn/kv_a", b, cfg.d_model, r + m.qk_rope_dim)
            self.matmul("attn/absorb", b, h * m.qk_nope_dim, r,
                        shardable=_div(h, self.m.tp), weight=False)
            h_loc = h / self.m.tp if _div(h, self.m.tp) else h
            f = 2.0 * b * h_loc * s_eff * 2 * r        # scores + out over latents
            cache_b = b * s_eff * (r + m.qk_rope_dim) * self.adt
            self.c.add("attn/latent", f, cache_b)
            self.matmul("attn/uv", b, r, h * m.v_head_dim, weight=True)
            self.matmul("attn/o", b, h * m.v_head_dim, cfg.d_model)
        else:
            self.matmul("attn/q", b, cfg.d_model, h * dh)
            self.matmul("attn/k", b, cfg.d_model, kv * dh)
            self.matmul("attn/v", b, cfg.d_model, kv * dh)
            h_loc = h / self.m.tp if _div(h, self.m.tp) else h
            f = 2.0 * b * h_loc * s_eff * 2 * dh
            cache_b = b * s_eff * (h_loc * 2) * dh * self.adt  # repeated KV read
            self.c.add("attn/cache", f, cache_b)
            self.matmul("attn/o", b, h * dh, cfg.d_model)
        self.tp_allreduce("attn/psum", b, cfg.d_model)
        if not _div(self.shape.global_batch, self.m.dp):
            # flash-decoding LSE combine over dp: (b, h) partials ×3
            self.c.add("attn/lse", wire=_ring(
                3 * b * self.cfg.n_heads * 4, self.m.dp, "ar"))

    def mlp_layer(self, t: float):
        cfg = self.cfg
        self.matmul("mlp/gate", t, cfg.d_model, cfg.d_ff)
        self.matmul("mlp/up", t, cfg.d_model, cfg.d_ff)
        self.matmul("mlp/down", t, cfg.d_ff, cfg.d_model)
        self.tp_allreduce("mlp/psum", t, cfg.d_model)

    def moe_layer(self, t: float):
        cfg = self.cfg
        m = cfg.moe
        e, k, cf, gs = m.n_experts, m.top_k, m.capacity_factor, m.group_size
        ep = _div(e, self.m.tp)
        self.matmul("moe/router", t, cfg.d_model, e, shardable=False)
        t_exp = t * k * cf
        disp_div = self.m.tp if ep else 1   # dispatch output sharded on E
        if m.dispatch == "einsum":
            cap = gs * k * cf / e
            # dispatch + combine one-hot einsums (fwd+bwd)
            f = 2.0 * t * e * cap * cfg.d_model * 2 * self._fwd_mult() / disp_div
            self.c.add("moe/dispatch", f, t * cfg.d_model * self.adt * 4)
        else:
            self.c.add("moe/dispatch",
                       bytes_=t_exp * cfg.d_model * self.adt * 4)
        if ep:
            # expert-parallel: tokens a2a to expert shards and back; each
            # EP rank runs its experts' share of the token work (÷tp)
            self.c.add("moe/a2a", wire=_ring(
                t_exp * cfg.d_model * self.adt, self.m.tp, "a2a")
                * 2 * (3 if self.train else 1))
        mult = self._fwd_mult()
        f = 2.0 * t_exp * cfg.d_model * cfg.d_ff / self.m.tp * 3 * mult
        wb = 3 * e * cfg.d_model * cfg.d_ff / (self.m.tp) * self.wdt \
            * (2 if self.train else 1)
        self.c.add("moe/experts", f, wb + t_exp * cfg.d_ff / self.m.tp
                   * self.adt * 2 * mult)
        if not ep:
            self.tp_allreduce("moe/psum", t, cfg.d_model)

    def rwkv_layer(self, t: float, decode: bool = False):
        cfg = self.cfg
        d = cfg.d_model
        hd = cfg.ssm.head_dim
        hds = d // hd
        ck = 1 if decode else cfg.ssm.chunk
        for tag in ("r", "k", "v", "g", "o"):
            self.matmul(f"rwkv/{tag}", t, d, d)
        self.matmul("rwkv/lora", t, d, 5 * 32 + 64, shardable=False)
        # wkv: intra-chunk (t × C × K) + state (K × V) terms per head
        h_loc = hds  # heads 40 not divisible by 16 → replicated (honest)
        if _div(hds, self.m.tp):
            h_loc = hds / self.m.tp
        f = (3.0 * t * ck * hd + 4.0 * t * hd * hd / max(1, hd // hd)) * h_loc
        f = f * self._fwd_mult()
        sb = t * h_loc * (ck * 4 + hd * 4) * 4      # ratio tensors fp32
        self.c.add("rwkv/wkv", f, sb)
        # channel mix
        self.matmul("rwkv/cm_k", t, d, cfg.d_ff)
        self.matmul("rwkv/cm_v", t, cfg.d_ff, d)
        self.matmul("rwkv/cm_r", t, d, d)
        self.tp_allreduce("rwkv/psum", t, d, per_pass=2)

    def mamba_layer(self, t: float, decode: bool = False):
        cfg = self.cfg
        s = cfg.ssm
        d = cfg.d_model
        di = s.expand * d
        hds = di // s.head_dim
        ck = 1 if decode else s.chunk
        self.matmul("mamba/z", t, d, di)
        self.matmul("mamba/x", t, d, di)
        self.matmul("mamba/bcdt", t, d, 2 * s.d_state + hds, shardable=False)
        self.c.add("mamba/conv", 2.0 * t * di / self.m.tp * s.conv_width
                   * self._fwd_mult(), t * di / self.m.tp * self.adt * 2)
        h_loc = hds / self.m.tp if _div(hds, self.m.tp) else hds
        # ssd: intra (C·N + C·P) + state update/out (P·N) per token per head
        f = 2.0 * t * h_loc * (ck * s.d_state + ck * s.head_dim
                               + 2 * s.head_dim * s.d_state)
        f *= self._fwd_mult()
        self.c.add("mamba/ssd", f, t * h_loc * s.head_dim * 4 * 2)
        self.matmul("mamba/out", t, di, d)
        self.tp_allreduce("mamba/psum", t, d)

    def embed_logits(self, t: float, tied: bool):
        cfg = self.cfg
        v, d = cfg.vocab_size, cfg.d_model
        # input embedding gather (+ one psum when vocab-sharded)
        self.c.add("embed/gather", bytes_=t * d * self.adt)
        if _div(v, self.m.tp):
            self.c.add("embed/psum", wire=_ring(t * d * self.adt, self.m.tp, "ar"))
        self.matmul("logits/head", t, d, v)
        # CE over vocab-sharded logits: lse partials (t,) — negligible wire
        self.c.add("logits/ce", bytes_=t * v / self.m.tp * 4 * 2
                   * (2 if self.train else 1))

    def optimizer_and_grads(self):
        if not self.train:
            return
        cfg = self.cfg
        p_total = cfg.param_count(active_only=False)
        p_loc = p_total / self.m.tp        # weights TP-sharded (approx.)
        # AdamW: read p,m,v, write p,m,v (fp32 moments) + grad read
        self.c.add("opt/adamw", flops=12.0 * p_loc,
                   bytes_=p_loc * (4 * 6 + self.wdt * 2))
        # gradient sync over dp (int8 compression: dist/compression.py —
        # per-block scales + error feedback; payload 1 byte/grad)
        gbytes = 1 if self.grad_compress == "int8" else 4
        if self.zero1:
            wire = _ring(p_loc * gbytes, self.m.dp, "rs") + \
                _ring(p_loc * self.wdt, self.m.dp, "ag")
        else:
            wire = _ring(p_loc * gbytes, self.m.dp, "ar")
        self.c.add("opt/gradsync", grad_wire=wire)

    # ------------------------------------------------------------------ #

    def build(self) -> Costs:
        cfg = self.cfg
        sh = self.shape
        decode = sh.kind == "decode"
        t = self.t_loc

        if cfg.encdec:
            if decode:
                # decoder only: self-attn over the cache + static cross-attn;
                # the encoder does NOT run per decode step
                for _ in range(cfg.n_layers):
                    self.attention_decode_layer(sh.seq_len)   # self
                    self.attention_decode_layer(sh.seq_len)   # cross (static)
                    self.mlp_layer(t)
                self.embed_logits(t, True)
            else:
                # encoder over S frames + teacher-forced decoder over S tokens
                for _ in range(cfg.n_layers):
                    self.attention_layer(sh.seq_len, sh.seq_len, causal=False)
                    self.mlp_layer(t)
                for _ in range(cfg.n_layers):
                    self.attention_layer(sh.seq_len, sh.seq_len, causal=True)
                    self.attention_layer(sh.seq_len, sh.seq_len, causal=False)
                    self.mlp_layer(t)
                self.embed_logits(t, True)
            self.optimizer_and_grads()
            return self.c

        for i in range(cfg.n_layers):
            kind = cfg.layer_kind(i)
            moe_here = cfg.moe is not None and (
                i % cfg.moe.every_k_layers == (cfg.moe.every_k_layers - 1)
                if cfg.moe.every_k_layers > 1 else True)
            if kind == "attn":
                if decode:
                    self.attention_decode_layer(sh.seq_len)
                else:
                    self.attention_layer(sh.seq_len, sh.seq_len, causal=True)
            elif kind == "mamba":
                self.mamba_layer(t, decode)
            elif kind in ("ssm", "rwkv6"):
                self.rwkv_layer(t, decode)
            if kind in ("attn", "mamba"):   # rwkv embeds its own channel-mix
                if moe_here:
                    self.moe_layer(t)
                else:
                    self.mlp_layer(t)
        self.embed_logits(t, cfg.tie_embeddings)
        self.optimizer_and_grads()
        return self.c


def analytic_report(cfg: ArchConfig, shape: ShapeSpec, dp: int, tp: int,
                    **knobs) -> Dict[str, float]:
    mesh = MeshModel(dp=dp, tp=tp)
    cm = CellModel(cfg, shape, mesh, **knobs)
    c = cm.build()
    t_comp = c.flops / hw.PEAK_FLOPS_BF16
    t_mem = c.bytes / hw.HBM_BW
    t_grad = c.grad_wire / hw.ICI_LINK_BW
    if cm.overlap_gradsync:
        # grad all-reduce overlapped with backward compute (bucketed async);
        # only the portion exceeding compute time is exposed
        t_coll = c.wire / hw.ICI_LINK_BW + max(0.0, t_grad - t_comp)
    else:
        t_coll = (c.wire + c.grad_wire) / hw.ICI_LINK_BW
    mf = cfg.model_flops(shape)
    t_bound = max(t_comp, t_mem, t_coll)
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "wire_bytes_per_device": c.wire + c.grad_wire,
        "grad_wire_bytes_per_device": c.grad_wire,
        "t_compute": t_comp,
        "t_memory": t_mem,
        "t_collective": t_coll,
        "bottleneck": max(
            {"compute": t_comp, "memory": t_mem, "collective": t_coll},
            key=lambda k: {"compute": t_comp, "memory": t_mem,
                           "collective": t_coll}[k]),
        "model_flops_total": mf,
        "useful_flops_ratio": mf / (c.flops * mesh.chips) if c.flops else 0.0,
        "roofline_fraction": (mf / (mesh.chips * hw.PEAK_FLOPS_BF16)) / t_bound
        if t_bound else 0.0,
        "top_flop_sites": dict(sorted(c.notes.items(),
                                      key=lambda kv: -kv[1])[:8]),
        "top_byte_sites": dict(sorted(c.byte_notes.items(),
                                      key=lambda kv: -kv[1])[:8]),
    }
