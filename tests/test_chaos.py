"""repro.chaos: injection plane, Daly cadence, backoff, quorum/elastic ties."""
import json
import os

import numpy as np
import pytest

from repro.chaos import inject as chaos
from repro.chaos.cadence import (
    REFERENCE,
    CadenceConfig,
    CadenceController,
    MTBFEstimator,
    checkpoint_efficiency,
    daly_interval,
    progress_rate,
)
from repro.chaos.inject import ChaosRegistry, FaultSpec, InjectedFault
from repro.ft.backoff import ExponentialBackoff, backoff_delay


@pytest.fixture(autouse=True)
def _clean_registry():
    chaos.reset()
    yield
    chaos.reset()


# -- FaultSpec triggers ------------------------------------------------------
def test_spec_at_fires_on_nth_hit():
    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="s", at=3))
    reg.fire("s")
    reg.fire("s")
    with pytest.raises(InjectedFault):
        reg.fire("s")
    reg.fire("s")                              # times=1: exhausted
    assert reg.fired_count("s") == 1


def test_spec_every_repeats_up_to_times():
    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="s", every=2, times=2, mode="skip"))
    hits = [reg.fire("s").skipped for _ in range(8)]
    assert hits == [False, True, False, True, False, False, False, False]


def test_spec_prob_is_seeded_deterministic():
    def pattern(seed):
        reg = ChaosRegistry(env={})
        reg.arm(FaultSpec(site="s", prob=0.5, times=None, seed=seed,
                          mode="skip"))
        return [reg.fire("s").skipped for _ in range(64)]

    assert pattern(7) == pattern(7)
    assert pattern(7) != pattern(8)
    assert 10 < sum(pattern(7)) < 54          # actually probabilistic


def test_spec_match_filters_site_glob_and_ctx():
    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="objstore.*", match={"rank": 3}, times=None))
    reg.fire("objstore.put", rank=1)           # ctx mismatch
    reg.fire("tier.place", rank=3)             # site mismatch
    with pytest.raises(InjectedFault):
        reg.fire("objstore.get", rank=3)


def test_error_mode_raises_the_sites_natural_exception():
    class SiteError(Exception):
        pass

    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="s", message="boom"))
    with pytest.raises(SiteError, match="boom"):
        reg.fire("s", exc=SiteError)


def test_corrupt_mode_flips_payload_bytes():
    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="s", mode="corrupt"))
    blob = bytes(range(32))
    out = reg.fire("s", data=blob)
    assert out.data != blob and len(out.data) == len(blob)
    assert reg.fire("s", data=blob).data == blob   # exhausted → pass-through


def test_delay_mode_sleeps():
    import time
    reg = ChaosRegistry(env={})
    reg.arm(FaultSpec(site="s", mode="delay", delay_s=0.05))
    t0 = time.monotonic()
    reg.fire("s")
    assert time.monotonic() - t0 >= 0.05


def test_unknown_mode_and_unknown_keys_rejected():
    with pytest.raises(ValueError):
        FaultSpec(site="s", mode="explode")
    with pytest.raises(ValueError):
        FaultSpec.from_dict({"site": "s", "wat": 1})


# -- env activation protocol -------------------------------------------------
def test_env_round_trip_arms_specs_in_child_registry():
    specs = [FaultSpec(site="tier.place", at=2, match={"rank": 1}),
             FaultSpec(site="objstore.*", mode="corrupt", times=None)]
    env = chaos.env_for_specs(specs)
    reg = ChaosRegistry(env=env)
    assert reg.load_env() == 2
    armed = reg.specs()
    assert [s.to_dict() for s in armed] == [s.to_dict() for s in specs]


def test_env_file_indirection(tmp_path):
    p = tmp_path / "spec.json"
    p.write_text(json.dumps([{"site": "s", "mode": "skip"}]))
    reg = ChaosRegistry(env={chaos.CHAOS_ENV: f"@{p}"})
    assert reg.load_env() == 1
    assert reg.fire("s").skipped


def test_malformed_chaos_env_warns_and_arms_nothing():
    for bad in ("not json", '{"site": "s", "mode": "wat"}', '[{"nope": 1}]',
                "@/does/not/exist.json"):
        reg = ChaosRegistry(env={chaos.CHAOS_ENV: bad})
        with pytest.warns(RuntimeWarning):
            assert reg.load_env() == 0
        assert reg.fire("s").fired == 0        # inert, no raise


def test_fire_lazily_loads_env_once():
    reg = ChaosRegistry(env=chaos.env_for_specs([FaultSpec(site="s")]))
    with pytest.raises(InjectedFault):
        reg.fire("s")                          # no explicit load_env


def test_legacy_inject_at_warns_not_raises():
    from repro.ft.failures import should_inject_from_env
    assert chaos.legacy_inject_at({}) is None
    assert chaos.legacy_inject_at({chaos.LEGACY_INJECT_ENV: "0.9"}) == 0.9
    with pytest.warns(RuntimeWarning):
        assert chaos.legacy_inject_at({chaos.LEGACY_INJECT_ENV: "90%"}) is None
    os.environ[chaos.LEGACY_INJECT_ENV] = "oops"
    try:
        with pytest.warns(RuntimeWarning):
            assert should_inject_from_env() is None
    finally:
        del os.environ[chaos.LEGACY_INJECT_ENV]


# -- instrumented seams ------------------------------------------------------
def test_heartbeat_fsyncs_before_replace(tmp_path, monkeypatch):
    from repro.ft import detector
    synced = []
    real = os.fsync
    monkeypatch.setattr(detector.os, "fsync",
                        lambda fd: (synced.append(fd), real(fd))[1])
    hb = detector.Heartbeat(str(tmp_path / "hb"))
    hb.beat(1)
    assert synced and hb.last_step() == 1


def test_heartbeat_skip_site_suppresses_write(tmp_path):
    from repro.ft.detector import Heartbeat
    chaos.arm(chaos.SITES.HEARTBEAT, mode="skip", times=None)
    hb = Heartbeat(str(tmp_path / "hb"))
    hb.beat(1)
    assert hb.last() is None                   # write never landed


# -- closed-form Daly equations (goldens from comd-ft's reference model) -----
GOLDEN = {  # num_nodes -> (delta_s, mtbf_s, tau_opt_s, efficiency)
    10: (0.4864, 3155760.0, 1751.795, 0.999445),
    100: (4.864, 315576.0, 1748.879, 0.994443),
    1000: (48.64, 31557.6, 1719.843, 0.944045),
    10000: (486.4, 3155.76, 1442.856, 0.464944),
}


@pytest.mark.parametrize("n", sorted(GOLDEN))
def test_daly_optimum_matches_reference_platform(n):
    delta, mtbf, tau, eff = GOLDEN[n]
    p = REFERENCE.platform(n)
    assert p.delta_s == pytest.approx(delta, rel=1e-9)
    assert p.mtbf_s == pytest.approx(mtbf, rel=1e-9)
    assert p.recovery_s == p.delta_s           # recovery reads what we wrote
    assert daly_interval(p.delta_s, p.mtbf_s) == pytest.approx(tau, rel=1e-4)
    assert checkpoint_efficiency(
        p.delta_s, p.recovery_s, p.mtbf_s) == pytest.approx(eff, rel=1e-4)


def test_daly_interval_edges():
    assert daly_interval(10.0, 4.0) == 4.0     # delta >= 2M → tau = M
    with pytest.raises(ValueError):
        daly_interval(0.0, 100.0)
    with pytest.raises(ValueError):
        daly_interval(1.0, 0.0)
    # optimum actually optimal: nudging tau either way loses progress
    p = REFERENCE.platform(1000)
    tau = daly_interval(p.delta_s, p.mtbf_s)
    best = progress_rate(tau, p.delta_s, p.recovery_s, p.mtbf_s)
    assert best > progress_rate(tau * 1.5, p.delta_s, p.recovery_s, p.mtbf_s)
    assert best > progress_rate(tau * 0.5, p.delta_s, p.recovery_s, p.mtbf_s)


def test_progress_rate_overflow_guard():
    assert progress_rate(1e9, 1.0, 1.0, 1.0) == 0.0


# -- online MTBF estimation --------------------------------------------------
def test_mtbf_estimator_converges_to_failure_spacing():
    est = MTBFEstimator(prior_mtbf_s=3600.0)
    est.note_progress(0.0)
    for i in range(1, 101):
        est.note_failure(i * 500.0)
    assert est.estimate() == pytest.approx(500.0, rel=0.15)
    assert est.failures == 100


def test_mtbf_estimator_counts_heartbeat_gaps_as_failures():
    est = MTBFEstimator(prior_mtbf_s=100.0, gap_failure_s=10.0)
    est.note_progress(0.0)
    est.note_progress(5.0)
    est.note_progress(50.0)                    # 45 s silence → failure
    assert est.failures == 1


def test_ingest_chaos_history_is_cursor_based():
    ctl = CadenceController()
    chaos.arm("s", mode="skip", every=1, times=None)
    chaos.fire("s")
    chaos.fire("s")
    assert ctl.ingest_chaos_history() == 2
    assert ctl.ingest_chaos_history() == 0     # nothing new
    chaos.fire("s")
    assert ctl.ingest_chaos_history() == 1
    assert ctl.mtbf.failures == 3


# -- cadence controller vs the closed form -----------------------------------
@pytest.mark.parametrize("n", [10, 100, 1000, 10000])
def test_controller_tracks_daly_optimum_within_10pct(n):
    """Synthetic MTBF sweep: store costs + failures at exact MTBF spacing →
    the controller's L4 interval lands within 10% of the closed form."""
    p = REFERENCE.platform(n)
    ctl = CadenceController(CadenceConfig(max_interval_s=1e9))
    for _ in range(8):
        ctl.note_store(4, p.delta_s)           # measured store cost
    ctl.note_step(0.0)
    for i in range(1, 201):                    # failures at exact spacing
        ctl.note_failure(i * p.mtbf_s)
    tau = ctl.interval_for(4)
    ref = daly_interval(p.delta_s, p.mtbf_s)
    assert abs(tau - ref) / ref < 0.10
    dp = ctl.datapoints(4)
    assert dp["checkpoint_efficiency"] == pytest.approx(
        checkpoint_efficiency(p.delta_s, p.recovery_s, p.mtbf_s), rel=0.05)
    assert 0.0 < dp["progress_rate"] <= 1.0


def test_due_levels_keeps_l1_frequent_l4_rare():
    ctl = CadenceController(CadenceConfig(prior_mtbf_s=10_000.0))
    ctl.note_store(1, 0.001)                   # cheap local tier
    ctl.note_store(4, 25.0)                    # expensive PFS tier
    assert ctl.interval_for(1) < ctl.interval_for(4) / 10
    assert ctl.due_levels(now=0.0) == [4, 3, 2, 1]   # nothing stored yet
    ctl.mark_stored(4, now=0.0)                # L4 refreshes nested tiers
    assert ctl.due_levels(now=0.0) == []
    t1 = ctl.interval_for(1) * 1.01
    assert ctl.due_levels(now=t1) == [1]       # only L1 due again
    assert ctl.due_levels(now=ctl.interval_for(4) * 1.01) == [4, 3, 2, 1]


def test_recovery_cost_falls_back_to_store_cost():
    ctl = CadenceController()
    ctl.note_store(4, 7.0)
    assert ctl.recovery_cost(4) == 7.0
    ctl.note_recovery(4, 3.0)
    assert ctl.recovery_cost(4) == 3.0


# -- shared restart backoff --------------------------------------------------
def test_backoff_delay_doubles_and_caps():
    assert backoff_delay(0) == 0.0
    assert [backoff_delay(k, 1.0, 30.0) for k in (1, 2, 3, 4, 5, 6)] == \
        [1.0, 2.0, 4.0, 8.0, 16.0, 30.0]


def test_exponential_backoff_state_machine():
    b = ExponentialBackoff(base_s=0.5, max_s=4.0)
    assert b.delay() == 0.0
    assert [b.failed() for _ in range(5)] == [0.5, 1.0, 2.0, 4.0, 4.0]
    b.reset()
    assert b.failed() == 0.5
    slept = []
    b.sleep_after_failure(sleep_fn=slept.append)
    assert slept == [1.0]


# -- quorum over multi-file shard sets ---------------------------------------
def _touch(d, name, payload=b"x"):
    with open(os.path.join(d, name), "wb") as f:
        f.write(payload)


def test_quorum_partner_covers_lost_shard_file(tmp_path):
    from repro.core import manifest as mf
    from repro.ft.straggler import commit_if_quorum, validate_quorum
    from repro.redundancy.groups import Topology
    topo = Topology(world=4)
    d = mf.begin(str(tmp_path), 1)
    for r in range(4):
        _touch(d, f"rank{r}.chk5")
        for j in (0, 1):
            if (r, j) != (2, 1):               # rank 2 lost shard 1
                _touch(d, f"rank{r}.shard{j}.chk5")
    h = topo.partner_of(2)
    _touch(d, f"rank{h}.partner2.chk5")
    _touch(d, f"rank{h}.partner2.shard1.chk5")  # ...but its partner holds it
    rep = validate_quorum(d, topo)
    assert rep.restorable
    assert rep.covered_by_partner == [2] and (2, 1) in rep.shards_covered
    assert sorted(rep.present) == [0, 1, 3]
    assert commit_if_quorum(str(tmp_path), 1, topo)


def test_quorum_shard_hole_nobody_holds_is_lost(tmp_path):
    from repro.core import manifest as mf
    from repro.ft.straggler import validate_quorum
    from repro.redundancy.groups import Topology
    topo = Topology(world=2)
    d = mf.begin(str(tmp_path), 1)
    for r in range(2):
        _touch(d, f"rank{r}.chk5")
        _touch(d, f"rank{r}.shard0.chk5")
    _touch(d, "rank0.shard2.chk5")             # shard 1 is a hole for rank 0
    rep = validate_quorum(d, topo)
    assert not rep.restorable and rep.lost == [0]


# -- elastic discovery through objstore catalog roots ------------------------
def _one_rank_backend(tmp_path, name="fti"):
    from repro.backends.registry import make_backend
    from repro.core.comm import LocalComm
    from repro.core.storage import StorageConfig
    cfg = StorageConfig(root=str(tmp_path / "shared"), group_size=1)
    comm = LocalComm(str(tmp_path / "node-local"))
    kw = {"dedicated_thread": False} if name == "fti" else {}
    return cfg, comm, make_backend(cfg, comm, name, **kw)


def test_find_latest_sharded_discovers_catalog_ids(tmp_path):
    import shutil
    from repro.core.storage import CHK_FULL
    from repro.ft.elastic import find_latest_sharded
    cfg, comm, b = _one_rank_backend(tmp_path)
    b.tcl_store({"w": np.arange(64, dtype=np.float32)}, 5, 4, CHK_FULL)
    b.tcl_wait()
    tier = b.engine.objstore_tier()
    shutil.rmtree(comm.node_local_dir)
    shutil.rmtree(cfg.global_root)
    got = find_latest_sharded([cfg.global_root], tiers=[tier])
    assert got is not None
    d, ckpt_id = got
    assert ckpt_id == 5 and d.startswith(tier.root)
    assert os.path.exists(os.path.join(d, "rank0.chk5"))  # materialized


def test_find_latest_sharded_falls_back_past_dead_catalog(tmp_path):
    import shutil
    from repro.core import manifest as mf
    from repro.core.storage import CHK_FULL
    from repro.ft.elastic import find_latest_sharded
    cfg, comm, b = _one_rank_backend(tmp_path)
    b.tcl_store({"w": np.zeros(8, np.float32)}, 9, 4, CHK_FULL)
    b.tcl_wait()
    tier = b.engine.objstore_tier()
    shutil.rmtree(cfg.global_root)             # id 9 lives only in the bucket
    # a directory-backed id 3 plus the catalog id 9 behind an outage
    d3 = mf.begin(cfg.global_root, 3)
    _touch(d3, "rank0.chk5")
    mf.write_manifest(cfg.global_root, 3, {"kind": "FULL", "level": 4})
    mf.commit(cfg.global_root, 3, keep_last=10)
    chaos.arm("objstore.*", mode="error", every=1, times=None)
    got = find_latest_sharded([cfg.global_root], tiers=[tier])
    assert got is not None and got[1] == 3     # catalog dark → dir id wins


# -- restart-durable chaos state ---------------------------------------------
def _state_env(specs, state_path):
    return chaos.env_for_specs(specs, state_path=str(state_path))


def test_state_file_persists_counters_across_registries(tmp_path):
    spec = FaultSpec(site="s", mode="error", at=3, times=1)
    env = _state_env([spec], tmp_path / "state.json")
    reg1 = ChaosRegistry(env=env)
    assert reg1.fire("s").fired == 0           # hit 1
    assert reg1.fire("s").fired == 0           # hit 2 — persisted
    reg2 = ChaosRegistry(env=env)              # the restarted process
    with pytest.raises(InjectedFault):
        reg2.fire("s")                         # hit 3, not hit 1
    reg3 = ChaosRegistry(env=env)              # spec now exhausted
    assert reg3.fire("s").fired == 0
    blob = json.loads((tmp_path / "state.json").read_text())
    (st,) = blob.values()
    assert st["hits"] == 3 and st["fired"] == 1


def test_prob_spec_rng_state_round_trips(tmp_path):
    mk = lambda: FaultSpec(site="s", mode="skip", prob=0.5, seed=7,
                           times=None)
    ref = mk()                                 # uninterrupted reference
    want = [ref.should_fire() for _ in range(20)]
    env = _state_env([mk()], tmp_path / "state.json")
    reg1 = ChaosRegistry(env=env)
    got = [reg1.fire("s").skipped for _ in range(10)]
    reg2 = ChaosRegistry(env=env)              # resumes the RNG stream
    got += [reg2.fire("s").skipped for _ in range(10)]
    assert got == want


def test_malformed_state_file_warns_never_raises(tmp_path):
    p = tmp_path / "state.json"
    spec = FaultSpec(site="s", at=1)
    bad_counters = json.dumps({spec.state_key(): {"hits": "wat"}})
    for bad in ("not json", "[1, 2]", bad_counters):
        p.write_text(bad)
        env = _state_env([FaultSpec(site="s", at=1)], p)
        reg = ChaosRegistry(env=env)
        with pytest.warns(RuntimeWarning):
            assert reg.load_env() == 1         # spec armed, counters zeroed
        with pytest.raises(InjectedFault):
            reg.fire("s")                      # still fires on hit 1
    # a state key that matches no armed spec is simply ignored (it may
    # belong to a sibling process's spec set)
    p.write_text('{"deadbeef": {"hits": 5}}')
    reg = ChaosRegistry(env=_state_env([FaultSpec(site="s", at=1)], p))
    assert reg.load_env() == 1
    with pytest.raises(InjectedFault):
        reg.fire("s")


def test_rearm_flag_serialization_round_trips():
    assert "rearm" not in FaultSpec(site="s").to_dict()   # default stays
    d = FaultSpec(site="s", rearm=False).to_dict()
    assert d["rearm"] is False
    assert FaultSpec.from_dict(d).rearm is False
    assert FaultSpec.from_dict({"site": "s"}).rearm is True


def test_restart_env_applies_rearm_semantics(tmp_path):
    keep = FaultSpec(site="train.step", mode="exit", every=8)
    drop = FaultSpec(site="objstore.*", mode="error", rearm=False)
    env = _state_env([keep, drop], tmp_path / "st.json")
    env[chaos.LEGACY_INJECT_ENV] = "0.9"
    out = chaos.restart_env(env)
    assert chaos.LEGACY_INJECT_ENV not in out  # one-shot legacy fault
    assert json.loads(out[chaos.CHAOS_ENV]) == [keep.to_dict()]
    assert out[chaos.CHAOS_STATE_ENV] == str(tmp_path / "st.json")
    # all rearm=False → both chaos vars drop
    out2 = chaos.restart_env(_state_env([drop], tmp_path / "st.json"))
    assert chaos.CHAOS_ENV not in out2 and chaos.CHAOS_STATE_ENV not in out2
    # malformed spec JSON → warn, drop, never raise
    with pytest.warns(RuntimeWarning):
        out3 = chaos.restart_env({chaos.CHAOS_ENV: "not json"})
    assert chaos.CHAOS_ENV not in out3
    assert chaos.restart_env({}) == {}


def test_exit_spec_kills_child_n_but_not_child_n_plus_1(tmp_path):
    """The tentpole contract end to end: a repeating exit spec kills the
    child whose hit count reaches the trigger, and the durable state file
    keeps it from re-killing the next child at the same count."""
    import subprocess
    import sys

    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.update(_state_env(
        [FaultSpec(site="train.step", mode="exit", every=2, times=1)],
        tmp_path / "state.json"))
    script = ("from repro.chaos import inject as chaos\n"
              "for i in range(2):\n"
              "    chaos.fire('train.step', step=i)\n"
              "print('CLEAN')\n")
    p1 = subprocess.run([sys.executable, "-c", script], env=env,
                        capture_output=True, text=True, timeout=60)
    assert p1.returncode == chaos.EXIT_CODE and "CLEAN" not in p1.stdout
    p2 = subprocess.run([sys.executable, "-c", script], env=env,
                        capture_output=True, text=True, timeout=60)
    assert p2.returncode == 0 and "CLEAN" in p2.stdout
    blob = json.loads((tmp_path / "state.json").read_text())
    assert sum(v["fired"] for v in blob.values()) == 1


# -- cadence-aware DIFF scheduling -------------------------------------------
def test_kind_strings_mirror_core_protect():
    from repro.chaos.cadence import CHK_DIFF_KIND, CHK_FULL_KIND
    from repro.core.protect import CHK_DIFF, CHK_FULL
    assert CHK_FULL_KIND == CHK_FULL and CHK_DIFF_KIND == CHK_DIFF


def test_diff_interval_golden_vs_closed_form():
    from repro.chaos.cadence import CHK_DIFF_KIND
    ctl = CadenceController(CadenceConfig(max_interval_s=1e9))
    for _ in range(8):
        ctl.note_store(4, 20.0)
        ctl.note_diff_store(4, 2.0, 0.10)
    m = ctl.mtbf.estimate()
    assert ctl.interval_for(4) == pytest.approx(daly_interval(20.0, m))
    assert ctl.interval_for(4, kind=CHK_DIFF_KIND) == pytest.approx(
        daly_interval(2.0, m))
    assert ctl.interval_for(4, kind=CHK_DIFF_KIND) < ctl.interval_for(4)
    sched = ctl.schedule(kind=CHK_DIFF_KIND)
    assert sched[4] == ctl.interval_for(4, kind=CHK_DIFF_KIND)


def test_diff_interval_collapses_to_full_past_promote_threshold():
    from repro.chaos.cadence import CHK_DIFF_KIND
    ctl = CadenceController(CadenceConfig(max_interval_s=1e9))
    ctl.note_store(4, 20.0)
    ctl.note_diff_store(4, 2.0, 0.99)          # dirty past break-even
    assert ctl.diff_store_cost(4) == ctl.store_cost(4)
    assert ctl.interval_for(4, kind=CHK_DIFF_KIND) == ctl.interval_for(4)


def test_diff_dirty_ratio_scales_full_cost_when_unmeasured():
    from repro.chaos.cadence import CHK_DIFF_KIND
    ctl = CadenceController(CadenceConfig(max_interval_s=1e9))
    ctl.note_store(4, 20.0)
    ctl.note_diff_store(4, None, 0.25)         # ratio known, cost not
    assert ctl.diff_store_cost(4) == pytest.approx(0.25 * 20.0)
    assert ctl.interval_for(4, kind=CHK_DIFF_KIND) == pytest.approx(
        daly_interval(5.0, ctl.mtbf.estimate()))
    # nothing observed at all → never schedule cheaper than evidence
    assert ctl.diff_store_cost(3) == ctl.store_cost(3)


def test_note_report_routes_diff_vs_promoted_full():
    from types import SimpleNamespace as NS
    ctl = CadenceController()
    ctl.note_report(NS(level=4, seconds=2.0, kind="DIFF",
                       promoted_full=False, dirty_ratio=0.2))
    assert ctl._costs[4].diff_store_s == 2.0 and ctl._costs[4].store_s is None
    ctl.note_report(NS(level=4, seconds=21.0, kind="DIFF",
                       promoted_full=True, dirty_ratio=0.98))
    assert ctl._costs[4].store_s == 21.0       # promoted = FULL pricing
    assert ctl._costs[4].diff_store_s == 2.0   # DIFF EWMA untouched
    assert ctl._costs[4].dirty_ratio > 0.2     # but the evidence lands
    ctl.note_report(NS(level=4, seconds=20.0, kind="FULL",
                       promoted_full=False, dirty_ratio=None))
    assert ctl._costs[4].store_s < 21.0


# -- MTBF merge + durable feed -----------------------------------------------
def test_mtbf_merge_and_feed_round_trip(tmp_path):
    from repro.chaos.cadence import MTBFFeed
    est = MTBFEstimator(prior_mtbf_s=3600.0)
    est.note_progress(0.0)
    est.note_failure(10.0)
    feed = MTBFFeed(str(tmp_path / "feed.json"))
    assert feed.read() is None                 # missing file: no warning
    feed.write(est, deaths=1, mttr_s=[2.5])
    fresh = MTBFEstimator(prior_mtbf_s=3600.0)
    assert feed.seed(fresh) is True
    assert fresh.failures == 1 and fresh.span_s == pytest.approx(10.0)
    assert fresh.estimate() == pytest.approx(est.estimate())
    assert fresh.estimate() < 3600.0           # the estimate actually moved
    blob = feed.read()
    assert blob["deaths"] == 1 and blob["mttr_s"] == [2.5]


def test_mtbf_feed_malformed_warns_and_seeds_nothing(tmp_path):
    from repro.chaos.cadence import MTBFFeed
    p = tmp_path / "feed.json"
    for bad in ("not json", "[1]", '{"failures": "wat", "span_s": "x"}'):
        p.write_text(bad)
        est = MTBFEstimator()
        with pytest.warns(RuntimeWarning):
            assert MTBFFeed(str(p)).seed(est) is False
        assert est.failures == 0


# -- backoff reset after sustained health ------------------------------------
def test_backoff_resets_after_sustained_healthy_span():
    b = ExponentialBackoff(base_s=1.0, max_s=30.0)
    b.failed()
    b.failed()
    assert b.note_healthy_span(5.0, 10.0) is False
    assert b.failures == 2                     # not healthy long enough
    assert b.note_healthy_span(10.0, 10.0) is True
    assert b.failures == 0
    assert b.note_healthy_span(20.0, 10.0) is False   # nothing to forget
    assert b.failed() == 1.0                   # back to base, not 4.0
