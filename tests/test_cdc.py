"""Content-defined chunking + the fused streaming upload path.

Covers the CDC layer's contracts in isolation (determinism vs push
granularity, size bounds, boundary re-synchronization after an insert),
the ``FileEntry`` offset/mode migration, and the ``ChunkStream`` region
hooks (digest-keyed layout replay; correctness never depending on the
layout cache).
"""
import hashlib

import numpy as np
import pytest

from repro.objstore.cdc import CDCParams, Chunker, split
from repro.objstore.chunks import (
    ChunkUploader,
    FileEntry,
    chunk_key,
    fetch_file,
)
from repro.objstore.client import MemoryObjectStore, ObjectStoreError

#: small bounds so a few hundred KiB exercises many chunks
P = CDCParams(min_bytes=2 << 10, avg_bytes=8 << 10, max_bytes=32 << 10)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


# ------------------------------------------------------------------ #
# the chunker itself
# ------------------------------------------------------------------ #


def test_params_validation():
    with pytest.raises(ValueError):
        CDCParams(min_bytes=2, avg_bytes=8, max_bytes=16)   # below window
    with pytest.raises(ValueError):
        CDCParams(min_bytes=1 << 20, avg_bytes=1 << 10, max_bytes=1 << 22)
    with pytest.raises(ValueError):
        CDCParams(min_bytes=1 << 10, avg_bytes=1 << 22, max_bytes=1 << 20)
    # a 2^20 average needs 20 low mask bits (one candidate per 2^20 bytes)
    assert CDCParams(avg_bytes=1 << 20).mask == (1 << 20) - 1


def test_cuts_independent_of_push_granularity():
    data = _rand(300 << 10)
    want = split(data, P)
    assert b"".join(want) == data
    for step in (1 << 10, 7_919, 64 << 10, len(data)):
        c = Chunker(P)
        got = []
        for off in range(0, len(data), step):
            got += c.push(data[off:off + step])
        got += c.finish()
        assert got == want, f"push step {step} changed the cut sequence"


def test_chunk_size_bounds_and_reassembly():
    data = _rand(500 << 10, seed=1)
    chunks = split(data, P)
    assert b"".join(chunks) == data
    assert len(chunks) > 5                     # bounds actually exercised
    for ch in chunks[:-1]:
        assert P.min_bytes <= len(ch) <= P.max_bytes
    assert len(chunks[-1]) <= P.max_bytes


def test_degenerate_data_cuts_at_min_and_dedups():
    # all-zero bytes hash identically everywhere: every position past min
    # is a boundary, so the splitter must fall out at min_bytes uniformly
    # (and never materialize a per-byte candidate index set)
    data = bytes(256 << 10)
    chunks = split(data, P)
    assert all(len(c) == P.min_bytes for c in chunks[:-1])
    assert len(set(chunks[:-1])) == 1          # one stored object after dedup


def test_boundaries_resync_after_insert():
    v1 = _rand(256 << 10, seed=2)
    at = len(v1) // 3
    v2 = v1[:at] + b"wedge" + v1[at:]
    c1 = {hashlib.sha256(c).hexdigest() for c in split(v1, P)}
    chunks2 = split(v2, P)
    new = [c for c in chunks2
           if hashlib.sha256(c).hexdigest() not in c1]
    # only the neighborhood of the insertion re-chunks; everything past
    # the re-sync point dedups against v1's chunks
    assert sum(len(c) for c in new) < len(v2) // 4
    assert b"".join(chunks2) == v2


def test_max_bound_forces_cut():
    # avg == max: boundary candidates land only every ~max bytes, so most
    # cuts are forced at the max bound — and none may ever exceed it
    p = CDCParams(min_bytes=1 << 10, avg_bytes=16 << 10, max_bytes=16 << 10)
    chunks = split(_rand(256 << 10, seed=3), p)
    assert all(len(c) <= p.max_bytes for c in chunks)
    assert max(len(c) for c in chunks) == p.max_bytes


# ------------------------------------------------------------------ #
# FileEntry: offsets, modes, legacy rows
# ------------------------------------------------------------------ #


def test_file_entry_legacy_rows_get_cumulative_offsets():
    fe = FileEntry("f", 30, [("a", 10), ("b", 12), ("c", 8)])
    assert fe.chunks == [("a", 0, 10), ("b", 10, 12), ("c", 22, 8)]
    assert fe.mode == "fixed"
    rt = FileEntry.from_json("f", fe.to_json())
    assert rt.chunks == fe.chunks and rt.mode == "fixed"


def test_file_entry_from_json_defaults_mode_for_precdc_catalogs():
    # the exact shape a pre-CDC catalog stored: [digest, nbytes] rows, no
    # mode key
    legacy = {"size": 7, "chunks": [["aa", 4], ["bb", 3]]}
    fe = FileEntry.from_json("old.chk5", legacy)
    assert fe.mode == "fixed"
    assert fe.chunks == [("aa", 0, 4), ("bb", 4, 3)]


def test_fetch_file_restores_legacy_entry_bit_exact(tmp_path):
    # a catalog entry written by the pre-CDC fixed-size uploader (2-tuple
    # rows, no offsets recorded) must keep restoring byte-identically
    store = MemoryObjectStore()
    data = _rand(10_000, seed=4)
    rows = []
    for off in range(0, len(data), 4096):
        piece = data[off:off + 4096]
        h = hashlib.sha256(piece).hexdigest()
        store.put(chunk_key(h), piece)
        rows.append([h, len(piece)])
    entry = FileEntry.from_json(
        "old.chk5", {"size": len(data), "chunks": rows})
    dest = str(tmp_path / "restored.chk5")
    fetch_file(store, entry, dest)
    with open(dest, "rb") as f:
        assert f.read() == data


def test_fetch_file_rejects_non_tiling_offsets(tmp_path):
    store = MemoryObjectStore()
    piece = b"x" * 64
    h = hashlib.sha256(piece).hexdigest()
    store.put(chunk_key(h), piece)
    entry = FileEntry("gap.chk5", 128, [(h, 0, 64), (h, 70, 64)])
    with pytest.raises(ObjectStoreError, match="does not tile"):
        fetch_file(store, entry, str(tmp_path / "gap"))


# ------------------------------------------------------------------ #
# the streaming sink
# ------------------------------------------------------------------ #


def test_stream_matches_file_based_cuts(tmp_path):
    # the fused Pack path and the submit_file fallback must produce the
    # same chunk layout for the same bytes (dedup across entry modes)
    data = _rand(200 << 10, seed=5)
    path = tmp_path / "payload.bin"
    path.write_bytes(data)
    up = ChunkUploader(MemoryObjectStore(), cdc=P)
    s = up.open_stream("streamed")
    for off in range(0, len(data), 10_000):
        s.write(data[off:off + 10_000])
    s.finish()
    streamed = s.pending().result()
    filed = up.upload_file(str(path), "filed")
    up.close()
    assert [h for h, _, _ in streamed.chunks] == \
        [h for h, _, _ in filed.chunks]
    assert streamed.mode == filed.mode == "cdc"
    assert up.stats["bytes_deduped"] >= len(data)   # second pass all-dedup


def test_stream_roundtrips_through_fetch(tmp_path):
    store = MemoryObjectStore()
    up = ChunkUploader(store, cdc=P)
    data = _rand(100 << 10, seed=6)
    s = up.open_stream("rt.chk5")
    s.write(data)
    entry = s.finish().result()
    up.close()
    dest = str(tmp_path / "rt.chk5")
    fetch_file(store, entry, dest)
    with open(dest, "rb") as f:
        assert f.read() == data


def test_stream_guards_lifecycle():
    up = ChunkUploader(MemoryObjectStore(), cdc=P)
    s = up.open_stream("x")
    with pytest.raises(ObjectStoreError, match="not finished"):
        s.pending()                    # writer crashed before close
    s.write(b"abc")
    s.finish()
    with pytest.raises(ObjectStoreError, match="write after finish"):
        s.write(b"more")
    assert s.finish() is s.pending()   # idempotent
    up.close()


def test_region_replay_skips_scan_and_keeps_layout():
    up = ChunkUploader(MemoryObjectStore(), cdc=P)
    region = _rand(120 << 10, seed=7)

    def store_once(tag):
        s = up.open_stream(tag)
        s.write(b"HEADER--")
        s.begin_region("leaf-key")
        for off in range(0, len(region), 9_000):
            s.write(region[off:off + 9_000])
        s.end_region()
        s.write(b"--TAIL")
        return s.finish().result()

    e1 = store_once("a.chk5")
    assert up.stats["regions_reused"] == 0
    e2 = store_once("b.chk5")
    up.close()
    # second store replayed the recorded layout without scanning...
    assert up.stats["regions_reused"] == 1
    assert up.stats["bytes_scan_skipped"] >= len(region)
    # ...and produced the identical chunk sequence, so everything deduped
    assert [h for h, _, _ in e1.chunks] == [h for h, _, _ in e2.chunks]


def test_stale_region_layout_still_stores_correct_bytes(tmp_path):
    # the cache key says "unchanged" but the bytes differ (the defensive
    # case): layout replay must never mis-address content — digests come
    # from the actual bytes, so the store stays correct, just with
    # cache-shaped cuts
    store = MemoryObjectStore()
    up = ChunkUploader(store, cdc=P)
    v1 = _rand(64 << 10, seed=8)
    v2 = _rand(64 << 10, seed=9)            # different bytes, same length

    def store_region(tag, payload):
        s = up.open_stream(tag)
        s.begin_region("same-key")
        s.write(payload)
        s.end_region()
        return s.finish().result()

    store_region("a.chk5", v1)
    e2 = store_region("b.chk5", v2)
    up.close()
    dest = str(tmp_path / "b.chk5")
    fetch_file(store, e2, dest)
    with open(dest, "rb") as f:
        assert f.read() == v2


def test_fixed_mode_stream_matches_legacy_splitter(tmp_path):
    # cdc=None: the stream emits the legacy fixed-size layout, so entries
    # written through either path stay dedup-compatible with old catalogs
    data = _rand(10 << 10, seed=10)
    path = tmp_path / "f.bin"
    path.write_bytes(data)
    up = ChunkUploader(MemoryObjectStore(), chunk_bytes=4096)
    s = up.open_stream("s")
    s.write(data)
    streamed = s.finish().result()
    filed = up.upload_file(str(path))
    up.close()
    assert streamed.mode == "fixed"
    assert [h for h, _, _ in streamed.chunks] == \
        [h for h, _, _ in filed.chunks]
    assert [n for _, _, n in streamed.chunks] == [4096, 4096, 2048]
