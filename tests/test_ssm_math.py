"""Chunked SSM algebra vs sequential recurrences (hypothesis sweeps)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.models.ssm import _ssd_chunked, _wkv6_chunked


def wkv6_seq(r, k, v, logw, u):
    b, t, h, K = r.shape
    V = v.shape[-1]
    S = np.zeros((b, h, K, V))
    out = np.zeros((b, t, h, V))
    for i in range(t):
        kv = np.einsum("bhk,bhv->bhkv", k[:, i], v[:, i])
        out[:, i] = np.einsum("bhk,bhkv->bhv", r[:, i],
                              S + u[None, :, :, None] * kv)
        S = np.exp(logw[:, i])[..., None] * S + kv
    return out


def ssd_seq(x, B, C, loga):
    b, t, h, P = x.shape
    S = np.zeros((b, h, P, B.shape[-1]))
    out = np.zeros((b, t, h, P))
    for i in range(t):
        a = np.exp(loga[:, i])
        S = a[..., None, None] * S + np.einsum("bhp,bn->bhpn", x[:, i], B[:, i])
        out[:, i] = np.einsum("bhpn,bn->bhp", S, C[:, i])
    return out


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), t=st.sampled_from([8, 16, 32, 48]),
       chunk=st.sampled_from([4, 8, 16]))
def test_wkv6_chunked_equals_sequential(seed, t, chunk):
    if t % chunk:
        t = (t // chunk) * chunk or chunk
    rng = np.random.RandomState(seed)
    b, h, K, V = 2, 3, 8, 8
    r = rng.randn(b, t, h, K).astype(np.float32)
    k = rng.randn(b, t, h, K).astype(np.float32)
    v = rng.randn(b, t, h, V).astype(np.float32)
    logw = -np.exp(rng.randn(b, t, h, K).astype(np.float32))
    u = rng.randn(h, K).astype(np.float32)
    got = np.asarray(_wkv6_chunked(*map(jnp.asarray, (r, k, v, logw)),
                                   jnp.asarray(u), chunk))
    want = wkv6_seq(r, k, v, logw, u)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), t=st.sampled_from([8, 16, 32]),
       chunk=st.sampled_from([4, 8]))
def test_ssd_chunked_equals_sequential(seed, t, chunk):
    rng = np.random.RandomState(seed)
    b, h, P, N = 2, 3, 4, 5
    x = rng.randn(b, t, h, P).astype(np.float32)
    B = rng.randn(b, t, N).astype(np.float32)
    C = rng.randn(b, t, N).astype(np.float32)
    loga = -np.abs(rng.randn(b, t, h).astype(np.float32))
    got = np.asarray(_ssd_chunked(*map(jnp.asarray, (x, B, C, loga)), chunk))
    want = ssd_seq(x, B, C, loga)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_wkv6_extreme_decay_stable():
    """No overflow with near-zero decay (exp(-exp(x)) can be tiny)."""
    b, t, h, K, V = 1, 32, 1, 4, 4
    rng = np.random.RandomState(0)
    r = rng.randn(b, t, h, K).astype(np.float32)
    k = rng.randn(b, t, h, K).astype(np.float32)
    v = rng.randn(b, t, h, V).astype(np.float32)
    logw = np.full((b, t, h, K), -80.0, np.float32)    # decay ≈ 0
    u = np.zeros((h, K), np.float32)
    got = np.asarray(_wkv6_chunked(*map(jnp.asarray, (r, k, v, logw)),
                                   jnp.asarray(u), 8))
    assert np.all(np.isfinite(got))
