"""Serving engine: batched prefill + incremental decode with KV caches.

``make_serve_step`` builds the single-token decode step that the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells. The engine's state
(caches + positions + generated tokens) is a pytree, so OpenCHK can
checkpoint a *serving* process too — a failed server resumes decoding
without re-running prefill (examples/serve_resilient.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.zoo import Model


class ServeState(NamedTuple):
    caches: Any
    pos: jnp.ndarray             # scalar int32 — next write position
    last_token: jnp.ndarray      # (B, 1) int32


def make_serve_step(model: Model) -> Callable[..., Tuple[jnp.ndarray, Any]]:
    """serve_step(params, token (B,1), caches, pos) → (next_token, caches).

    Greedy argmax sampling (deterministic — serving benchmarks measure the
    system, not the sampler).
    """

    def serve_step(params, token, caches, pos):
        logits, caches = model.decode_step(params, token, caches, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, caches

    return serve_step


class ServingEngine:
    """Minimal batched serving loop over a fixed request batch."""

    def __init__(self, model: Model, params: Any, batch: int, max_len: int):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self._step = jax.jit(make_serve_step(model))
        self._decode_warm = jax.jit(model.decode_step)
        self.state: Optional[ServeState] = None

    def prefill(self, prompts: jnp.ndarray) -> None:
        """Sequential prefill through the decode path (cache-exact; fine for
        the small CPU examples — large-scale prefill uses model.forward)."""
        b, s = prompts.shape
        caches = self.model.init_caches(b, self.max_len)
        tok = prompts[:, :1]
        for i in range(s):
            logits, caches = self._decode_warm(
                self.params, prompts[:, i: i + 1], caches, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.state = ServeState(caches, jnp.int32(s), nxt)

    def generate(self, n_tokens: int) -> jnp.ndarray:
        assert self.state is not None, "prefill first (or restore a checkpoint)"
        toks = []
        st = self.state
        for _ in range(n_tokens):
            nxt, caches = self._step(self.params, st.last_token, st.caches, st.pos)
            st = ServeState(caches, st.pos + 1, nxt)
            toks.append(nxt)
        self.state = st
        return jnp.concatenate(toks, axis=1)

    # --- checkpointable serving state (OpenCHK integration) -------------- #
    def get_state(self) -> ServeState:
        return self.state

    def set_state(self, st: ServeState) -> None:
        self.state = st
