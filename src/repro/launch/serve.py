"""Serving driver: batched decode with checkpointable engine state.

Demonstrates OpenCHK for inference: the engine's (caches, pos, last_token)
pytree is stored/loaded through the same directives, so a failed server
resumes generation without re-running prefill.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/openchk-serve")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--kill-after", type=int, default=None,
                    help="simulate failure after N generated tokens")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--follow-catalog", default=None, metavar="URL",
                    help="object-store url (file:<dir> / mem:) to follow: "
                    "newly published FULL checkpoints hot-swap into the "
                    "engine between batches (checkpoint-as-deployment)")
    ap.add_argument("--deploy-cache", default=None,
                    help="node-local chunk/file cache for --follow-catalog "
                    "pulls (default <ckpt-dir>/deploy-cache)")
    ap.add_argument("--health-port", type=int, default=None,
                    help="serve /healthz /readyz /metrics for this replica "
                    "(0 = ephemeral); readiness follows weight swaps")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.models.zoo import build_model
    from repro.serve.engine import ServingEngine, WeightsHandle

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, args.batch, args.max_len,
                        name="serve0")
    eng.swap_hook = lambda old, new: print(
        f"[serve] weights swapped: epoch {old.epoch} -> {new.epoch} "
        f"(catalog entry {new.entry_id})")

    health = None
    if args.health_port is not None:
        from repro.telemetry.health import attach_engine
        health = attach_engine(eng, name="serve0", port=args.health_port)
        print(f"[serve] health endpoint on {health.server.url}")

    deployer = None
    if args.follow_catalog:
        from repro.objstore.client import make_object_store
        from repro.serve.deploy import FleetDeployer, Replica
        cache = args.deploy_cache or f"{args.ckpt_dir}/deploy-cache"
        deployer = FleetDeployer(
            make_object_store(args.follow_catalog),
            [Replica(name="serve0", engine=eng, cache_root=cache,
                     prefix="params", health=health)])

    ckpt = CheckpointContext(CheckpointConfig(dir=args.ckpt_dir,
                                              backend=args.backend))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)

    # transparent restart: if a serving checkpoint exists, skip prefill
    t0 = time.time()
    eng.prefill(prompts)
    restored = ckpt.load(eng.get_state())
    if ckpt.restarted:
        eng.set_state(restored)
        print(f"[serve] resumed at pos {int(restored.pos)} "
              f"(prefill skipped on restore path)")

    done = int(eng.get_state().pos) - args.prompt_len
    out = []
    for i in range(done, args.gen):
        if deployer is not None:
            st = deployer.poll()
            if st["action"] == "started":
                d = st["delta"]
                print(f"[serve] deploying catalog entry {st['entry']} "
                      f"(delta {d.n_chunks_delta}/{d.n_chunks_total} chunks, "
                      f"{d.bytes_delta}/{d.bytes_total} bytes)")
            elif st["action"] == "pinned":
                print(f"[serve] deploy pinned: {st['error']} "
                      f"(retrying with backoff)")
        out.append(eng.generate(1))
        ckpt.store(eng.get_state(), id=int(eng.get_state().pos), level=1,
                   if_=(i + 1) % 8 == 0)
        if args.kill_after is not None and (i + 1) >= args.kill_after:
            ckpt.wait()
            print(f"[serve] simulated failure after {i + 1} tokens")
            ckpt.shutdown()
            return 39
    ckpt.wait()
    toks = jnp.concatenate(out, axis=1) if out else jnp.zeros((args.batch, 0))
    print(f"[serve] generated {toks.shape[1]} tokens/req in "
          f"{time.time() - t0:.1f}s; sample: {toks[0][:16].tolist()}")
    ckpt.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
