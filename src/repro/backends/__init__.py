"""Checkpoint backends: FTI-like, SCR-like, VeloC-like (behind TCL)."""
