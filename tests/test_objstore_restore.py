"""The objstore acceptance path on a forced-16-device mesh: a sharded
(4×4) level-4 store — one leaf int8-compressed at the chunk level — then
every checkpoint directory (node-local L1–L3 *and* the L4 global dir) is
wiped, and a fresh process restores bit-exact onto a 2×8 mesh from the
object store alone: catalog discovery → chunked file reassembly into the
node-local cache → ``ElasticLoader``/``ShardedLeafRef`` region reads.
``chkls --json`` asserts the remote catalog inventory along the way."""

import subprocess
import sys
import textwrap

SUBPROC_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import glob, shutil
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.context import CheckpointConfig, CheckpointContext, Protect
    from repro.core.resharding import reshard_tree

    def orig_arrays():
        rng = np.random.default_rng(0)
        return (np.arange(64 * 64, dtype=np.float32).reshape(64, 64),
                rng.normal(size=(64, 32)).astype(np.float32))

    def make_state(mesh):
        w, c = orig_arrays()
        state = {"params": {"w": jnp.asarray(w), "c": jnp.asarray(c)},
                 "step": jnp.int32(7)}
        sh = {"params": {"w": NamedSharding(mesh, P("data", "model")),
                         "c": NamedSharding(mesh, P("data", "model"))},
              "step": NamedSharding(mesh, P())}
        return reshard_tree(state, sh)

    def make_ctx(ckpt_dir):
        ctx = CheckpointContext(CheckpointConfig(
            dir=ckpt_dir, backend="fti", dedicated_thread=False,
            objstore_chunk_bytes=4096))
        ctx.protect(Protect("params/c", compress="int8"), Protect("**"))
        return ctx

    def expected_dequant_c(mesh_shape=(4, 4)):
        # bit-exact expectation: the store quantized each owned shard
        # chunk independently (per-chunk scales)
        from repro.dist.compression import quantize_int8_np, dequantize_int8_np
        _w, c = orig_arrays()
        out = np.empty_like(c)
        rr, cc = c.shape[0] // mesh_shape[0], c.shape[1] // mesh_shape[1]
        for i in range(mesh_shape[0]):
            for j in range(mesh_shape[1]):
                blk = np.ascontiguousarray(
                    c[i*rr:(i+1)*rr, j*cc:(j+1)*cc])
                q, s = quantize_int8_np(blk)
                out[i*rr:(i+1)*rr, j*cc:(j+1)*cc] = \\
                    dequantize_int8_np(q, s, blk.shape)
        return out
""")

STORE_WIPE_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    ckpt_dir = sys.argv[1]
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    state = make_state(mesh)
    ctx = make_ctx(ckpt_dir)
    ctx.store(state, id=1, level=4)
    ctx.shutdown()

    # the catalog already covers the multi-file shard set
    from repro.objstore.catalog import Catalog
    from repro.objstore.client import make_object_store
    cat = Catalog(make_object_store(
        "file:" + os.path.join(ckpt_dir, "objstore")))
    entry = cat.entry(1)
    assert entry is not None
    names = sorted(entry["files"])
    assert "rank0.chk5" in names, names
    assert [n for n in names if ".shard" in n], names

    # wipe L1-L3 (node-local, incl. the objstore cache) AND the L4
    # global directory: only the bucket survives
    shutil.rmtree(os.path.join(ckpt_dir, "node-local"))
    for d in glob.glob(os.path.join(ckpt_dir, "global", "ckpt-*")):
        shutil.rmtree(d)
    os.remove(os.path.join(ckpt_dir, "global", "latest"))
    leftovers = [p for p in glob.glob(os.path.join(ckpt_dir, "*"))
                 if os.path.basename(p) != "objstore"]
    assert all(os.path.basename(p) == "global" for p in leftovers), leftovers
    print("STORE-WIPE-OK")
""")

RESTORE_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    import io, json, contextlib
    from repro.core.protect import flatten_named
    from repro.tools.chkls import main as chkls_main

    ckpt_dir = sys.argv[1]

    # chkls --json lists the remote catalog (CI-assertable inventory)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert chkls_main([os.path.join(ckpt_dir, "objstore"),
                           "--json"]) == 0
    inv = json.loads(buf.getvalue())["catalog"]
    assert [e["id"] for e in inv["entries"]] == [1]
    e = inv["entries"][0]
    assert e["kind"] == "FULL" and e["level"] == 4
    assert [n for n in e["files"] if ".shard" in n], e["files"]
    assert inv["stored_chunks"] >= e["n_chunks"] > 0

    # the CLI output IS the typed inspect API's inventory: chkls --json
    # must agree field-for-field with a CatalogView over the same bucket
    from repro.objstore.inspect import CatalogView
    root = os.path.join(ckpt_dir, "objstore")
    view = CatalogView.from_root(root, count_chunks=True)
    assert view.to_inventory(root) == inv, "chkls --json drifted from inspect"
    ti = view.entry(1)
    assert ti is not None and ti.kind == e["kind"] and ti.level == e["level"]
    assert ti.n_chunks == e["n_chunks"] and ti.total_bytes == e["total_bytes"]
    assert sorted(f.name for f in ti.files) == sorted(e["files"])
    assert view.latest(kind="FULL").id == 1
    assert len(ti.chunk_digests) <= view.stored_chunks

    # the recovery really is the catalog rung (nothing else exists)
    probe = make_ctx(ckpt_dir)
    got = probe.tcl.backend.engine.load_latest(lazy_sharded=True)
    assert got is not None and got[1]["recovered_via"] == "objstore", got
    probe.shutdown()

    # restore onto a DIFFERENT mesh (2x8) through ElasticLoader regions
    mesh_b = jax.make_mesh((2, 8), ("data", "model"))
    template = jax.tree.map(jnp.zeros_like, make_state(mesh_b))
    ctx = make_ctx(ckpt_dir)
    restored = ctx.load(template)
    assert ctx.restarted
    ctx.shutdown()
    named = flatten_named(restored)[0]
    w, c = orig_arrays()
    assert int(named["step"]) == 7
    np.testing.assert_array_equal(np.asarray(named["params/w"]), w)
    # the compressed leaf restores bit-exact to its per-chunk dequantized
    # values (and within the int8 error envelope of the original)
    got_c = np.asarray(named["params/c"])
    np.testing.assert_array_equal(got_c, expected_dequant_c())
    assert np.abs(got_c - c).max() <= np.abs(c).max() / 127 + 1e-6
    # the cached container records the codec on the shard index
    from repro.core.formats import CHK5Reader
    cache = os.path.join(ckpt_dir, "node-local", "objstore-cache",
                         "ckpt-1", "rank0.chk5")
    rd = CHK5Reader(cache)
    assert rd.info("shardidx/params/c")["attrs"].get("codec") == "int8"
    assert "codec" not in rd.info("shardidx/params/w")["attrs"]
    rd.close()
    print("OBJSTORE-ELASTIC-RESTORE-OK")
""")


def test_objstore_sharded_store_wipe_elastic_restore(tmp_path):
    """Forced-16-device lane: 4×4 sharded L4 store (int8 chunk codec on
    one leaf) → wipe every directory → fresh process restores bit-exact
    on 2×8 from the object store alone."""
    d = str(tmp_path / "ck")
    r = subprocess.run([sys.executable, "-c", STORE_WIPE_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "STORE-WIPE-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
    r = subprocess.run([sys.executable, "-c", RESTORE_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "OBJSTORE-ELASTIC-RESTORE-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
