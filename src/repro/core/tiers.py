"""Storage tiers — the *Place* stage's pluggable backends (FTI's L1–L4
ladder as first-class objects).

A :class:`Tier` owns one rung of the checkpoint ladder, for **both**
directions:

    write side   ``place()``    — apply the tier's redundancy/copy scheme to
                                  a packed payload sitting in a staging dir
    read side    ``recover()``  — produce a rank's payload bytes from
                                  whatever this tier persisted

The four built-ins mirror the paper (§4.2.1) / FTI semantics:

    ``LocalTier``     L1  node-local write (RAM-disk / NVMe analogue)
    ``PartnerTier``   L2  partner copy on a different node
    ``ErasureTier``   L3  Reed–Solomon (or XOR) parity across the node group
    ``GlobalTier``    L4  parallel-file-system write (global directory)

plus the object-store rung (``repro.objstore.tier.ObjectStoreTier``,
composed into the L4 stack when ``StorageConfig.objstore`` is on):
content-addressed chunk uploads at Place, an atomically-published
checkpoint catalog at Commit, and a catalog-driven restore path that
survives every checkpoint directory being wiped.

Write stacks compose tiers (L2 = local + partner, L3 = local + erasure,
L4 = global + objstore); the recovery ladder tries every tier in level
order L1 → L2 → L3 → L4 → objstore.  Tiers participate in two more
pipeline moments besides ``place``/``recover``: ``commit`` (after the
local atomic rename — where the objstore tier publishes its catalog
entry) and ``list_ids`` (checkpoint discovery beyond directory scans —
how a wiped run finds what the catalog still holds).
Backends select/compose stacks via ``Backend.compose_tiers`` — adding a new
tier (compression, object store, multi-node batching) means subclassing
``Tier`` and composing it into a stack; nothing in the pipeline changes.

A :class:`PackTier` is the Pack-stage analogue: it encodes one planned
leaf into container datasets according to the leaf's ``Protect`` clauses
(core/protect.py) and decodes it back on restore.  Two built-ins consume
the clause system:

    ``Int8CompressTier``   ``compress="int8"`` — per-block max-abs int8
                           quantization (dist/compression.py), roundtrip
                           computed at pack time and crc-verified on load
    ``CHK5FormatTier``     the always-on format tier: plain CHK5 dataset
                           write, clause attrs recorded as dataset
                           attributes, ``precision`` casts applied

Pack tiers are *per-leaf* and self-describing: decode dispatches on the
``codec`` dataset attribute, so a reader needs no Protect specs — any
CHK5 container (and ``chkls --json``) shows exactly how each dataset was
encoded.  Backends compose them via ``Backend.compose_pack_tiers``.
"""
from __future__ import annotations

import abc
import json
import os
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import manifest as mf
from repro.core.comm import Communicator
from repro.core.formats import (
    CHK5CorruptionError,
    CHK5Reader,
    CHK5Writer,
    dtype_to_str,
    resolve_precision,
    str_to_dtype,
)
from repro.core.protect import CHK_FULL, Protect
from repro.redundancy import erasure
from repro.redundancy.groups import Topology
from repro.redundancy.partner import (
    find_partner_copy,
    replicate,
    store_partner_copy,
)


class TierContext:
    """Shared services a tier needs: config, communicator, topology, and
    directory resolution across the local/global roots and reachable peers."""

    def __init__(self, cfg, comm: Communicator, topo: Topology):
        self.cfg = cfg
        self.comm = comm
        self.topo = topo
        # roots owned by catalog-backed tiers (the objstore restore
        # cache): listed in recovery_dirs so shard files resolve there,
        # but the owning tier is the only one that answers payload reads
        # from them — it digest-verifies the cache against its catalog,
        # which the byte-oblivious directory tiers cannot
        self.catalog_roots: set = set()

    @property
    def local_root(self) -> str:
        return os.path.join(self.comm.node_local_dir, "ckpts")

    @property
    def global_root(self) -> str:
        return self.cfg.global_root

    def peer_ckpt_dirs(self, ckpt_id: int) -> List[str]:
        """The local-tier checkpoint dir on every reachable node (recovery
        pulls partner replicas / parity from surviving nodes' storage)."""
        dirs = []
        for r in range(self.comm.world):
            if r == self.comm.rank:
                base = self.local_root
            else:
                peer = self.comm.peer_local_dir(r)
                if peer is None:
                    continue
                base = os.path.join(peer, "ckpts")
            d = mf.ckpt_dir(base, ckpt_id)
            if os.path.isdir(d):
                dirs.append(d)
        return dirs

    def peer_ckpt_dir_for_write(self, rank: int, ckpt_id: int
                                ) -> Optional[str]:
        """Resolve where a shard for ``rank`` should land (its local tier
        dir, committed or in-flight)."""
        if rank == self.comm.rank:
            base = self.local_root
        else:
            peer = self.comm.peer_local_dir(rank)
            if peer is None:
                return None
            base = os.path.join(peer, "ckpts")
        final = mf.ckpt_dir(base, ckpt_id)
        tmp = mf.ckpt_dir(base, ckpt_id, tmp=True)
        return final if os.path.isdir(final) else (
            tmp if os.path.isdir(tmp) else None)

    def recovery_dirs(self, root: str, ckpt_id: int) -> List[str]:
        """Candidate dirs holding pieces of ``ckpt_id`` under ``root``:
        the root's own dir, plus (for node-local roots) reachable peers'."""
        search = [mf.ckpt_dir(root, ckpt_id)]
        if root != self.global_root:
            search += [d for d in self.peer_ckpt_dirs(ckpt_id)
                       if d not in search]
        return search


def _valid_payload(path: str) -> Optional[bytes]:
    """Read a CHK5 payload, rejecting corrupt containers."""
    if not os.path.exists(path):
        return None
    try:
        CHK5Reader(path).close()
    except CHK5CorruptionError:
        return None
    return open(path, "rb").read()


class Tier(abc.ABC):
    """One rung of the checkpoint ladder (write + recovery)."""

    name: str = "?"
    level: int = 0                     # ladder rung this tier implements

    def __init__(self, ctx: TierContext):
        self.ctx = ctx

    @property
    def root(self) -> str:
        """Where payloads (and the manifest) for this tier land."""
        return self.ctx.local_root

    def pack_sink(self, ckpt_id: int, basename: str):
        """Pack-stage streaming hook: return a byte sink (an object with
        ``write``/``cut``/``begin_region``/``end_region``/``finish``, see
        ``repro.objstore.chunks.ChunkStream``) for the staged file
        ``basename`` of checkpoint ``ckpt_id``, or None when this tier
        consumes whole staged files.  A CHK5 writer tees every written
        byte into the sink, so a sink tier overlaps its transfers with
        packing instead of re-reading the file at Place."""
        return None

    def place(self, ckpt_id: int, stage_dir: str, payload_path: str,
              extra_files: Sequence[str] = ()) -> None:
        """Write-side: apply this tier's scheme to the packed payload.
        ``stage_dir`` is the uncommitted ``.tmp`` checkpoint dir;
        ``extra_files`` are the payload's sibling shard files (sharded
        stores stage a multi-file set)."""

    def commit(self, ckpt_id: int, manifest: Dict) -> None:
        """Post-commit hook: runs after the checkpoint's atomic ``.tmp`` →
        final rename, with the committed manifest.  The objstore tier
        publishes its catalog entry here — so the catalog only ever
        advertises checkpoints whose local commit succeeded."""

    def list_ids(self) -> List[Tuple[int, str]]:
        """Checkpoint ids this tier can produce beyond the pipeline's
        directory scans → ``[(ckpt_id, root)]`` (the catalog-discovery
        hook; default none)."""
        return []

    @abc.abstractmethod
    def recover(self, ckpt_id: int, rank: int, root: str,
                manifest: Dict, dirs: List[str]) -> Optional[bytes]:
        """Read-side: return ``rank``'s payload bytes, or None if this tier
        cannot produce it.  ``dirs`` is the candidate dir list for this
        (root, ckpt_id) — computed once per ladder walk by the pipeline
        (``TierContext.recovery_dirs``), not per tier."""


class LocalTier(Tier):
    """L1 — the payload itself on node-local storage (written by Pack;
    place is a no-op)."""

    name = "local"
    level = 1

    def recover(self, ckpt_id, rank, root, manifest, dirs):
        for d in dirs:
            if d.startswith(self.ctx.global_root):
                continue               # global payloads are GlobalTier's rung
            if any(d.startswith(r) for r in self.ctx.catalog_roots):
                continue               # objstore cache: its tier verifies
            blob = _valid_payload(os.path.join(d, f"rank{rank}.chk5"))
            if blob is not None:
                return blob
        return None


class PartnerTier(Tier):
    """L2 — replicate the payload to the ring partner on another node."""

    name = "partner"
    level = 2

    def place(self, ckpt_id, stage_dir, payload_path, extra_files=()):
        payload = open(payload_path, "rb").read()
        extra = {os.path.basename(p): open(p, "rb").read()
                 for p in extra_files}
        replicate(self.ctx.comm, self.ctx.topo, ckpt_id, payload,
                  extra=extra or None)
        self.ctx.comm.barrier()
        store_partner_copy(self.ctx.comm, self.ctx.topo, ckpt_id, stage_dir)

    def recover(self, ckpt_id, rank, root, manifest, dirs):
        for d in dirs:
            pc = find_partner_copy(self.ctx.topo, d, rank)
            if pc:
                return open(pc, "rb").read()
        return None


class ErasureTier(Tier):
    """L3 — RS/XOR parity across the node group, shards scattered so one
    node loss never takes a payload and its covering parity together."""

    name = "erasure"
    level = 3

    def place(self, ckpt_id, stage_dir, payload_path, extra_files=()):
        # parity covers the rank container only: shard files of a sharded
        # L3 store are not erasure-encoded, so losing a node that held
        # them makes that checkpoint non-restorable — the restore walk
        # detects the incomplete shard set and falls back safely (older
        # id / global tier) rather than reconstructing a partial payload
        ctx = self.ctx
        group = ctx.topo.erasure_group(ctx.comm.rank)
        g = ctx.topo.group_index(ctx.comm.rank)
        payload = open(payload_path, "rb").read()
        for r in group:
            if r != ctx.comm.rank:
                ctx.comm.post(f"er:{ckpt_id}", r, payload)
        ctx.comm.barrier()
        blobs = [
            payload if r == ctx.comm.rank
            else ctx.comm.collect(f"er:{ckpt_id}", r)
            for r in group
        ]
        if any(b is None for b in blobs):
            return                  # not complete yet (an earlier member)
        lengths = [len(b) for b in blobs]
        if ctx.cfg.erasure_scheme == "xor":
            parities = [erasure.encode_xor(blobs)]
        else:
            parities = erasure.encode_rs(
                blobs, min(ctx.cfg.rs_parity, len(group)))
        meta = json.dumps({"lengths": lengths, "group": group})
        for j, par in enumerate(parities):
            # parity placement: on the NEXT group's nodes (ring) so a single
            # node loss never takes a payload and its covering parity
            # together; single-group worlds fall back to in-group rotation
            # (then XOR needs rs/m ≥ 2 to survive a parity-holder loss)
            if ctx.comm.world > len(group):
                holder = (group[-1] + 1 + j) % ctx.comm.world
            else:
                holder = group[(j + 1) % len(group)]
            hd = stage_dir if holder == ctx.comm.rank else \
                ctx.peer_ckpt_dir_for_write(holder, ckpt_id)
            if hd is None:
                hd = stage_dir      # fall back: keep shard locally
            with open(os.path.join(hd, f"parity.g{g}.p{j}.bin"), "wb") as f:
                f.write(par)
            with open(os.path.join(hd, f"parity.g{g}.meta"), "w") as f:
                f.write(meta)
        with open(os.path.join(stage_dir, f"parity.g{g}.meta"), "w") as f:
            f.write(meta)

    def recover(self, ckpt_id, rank, root, manifest, dirs):
        if manifest.get("level") != 3:
            return None
        ctx = self.ctx
        group = ctx.topo.erasure_group(rank)
        g = ctx.topo.group_index(rank)

        def find(name: str) -> Optional[str]:
            for d in dirs:
                p = os.path.join(d, name)
                if os.path.exists(p):
                    return p
            return None

        meta_p = find(f"parity.g{g}.meta")
        if meta_p is None:
            return None
        meta = json.loads(open(meta_p).read())
        lengths = meta["lengths"]
        survivors: Dict[int, bytes] = {}
        for j, r in enumerate(group):
            p = find(f"rank{r}.chk5")
            if p:
                survivors[j] = open(p, "rb").read()
        parities: Dict[int, bytes] = {}
        for j in range(len(group)):        # collect every surviving shard
            p = find(f"parity.g{g}.p{j}.bin")
            if p is not None:
                parities[j] = open(p, "rb").read()
        try:
            if ctx.cfg.erasure_scheme == "xor":
                blobs = erasure.decode_xor(survivors, parities[0], len(group),
                                           lengths)
            else:
                blobs = erasure.decode_rs(survivors, parities, len(group),
                                          lengths)
        except Exception:
            return None
        return blobs[group.index(rank)]


class GlobalTier(Tier):
    """L4 — the payload on the parallel file system (shared directory)."""

    name = "global"
    level = 4

    @property
    def root(self) -> str:
        return self.ctx.global_root

    def recover(self, ckpt_id, rank, root, manifest, dirs):
        if root != self.ctx.global_root:
            return None
        p = os.path.join(mf.ckpt_dir(root, ckpt_id), f"rank{rank}.chk5")
        return _valid_payload(p)


def default_tier_stacks(ctx: TierContext) -> Dict[int, List[Tier]]:
    """The FTI ladder: L2/L3 stack a redundancy tier on the local write;
    L4 stacks the content-addressed object store on the global-directory
    write (``StorageConfig.objstore`` gates it — the survivable rung the
    recovery ladder falls back to when every directory is gone)."""
    local = LocalTier(ctx)
    l4: List[Tier] = [GlobalTier(ctx)]
    if getattr(ctx.cfg, "objstore", True):
        # lazy import: objstore.tier subclasses Tier from this module
        from repro.objstore.tier import ObjectStoreTier
        l4.append(ObjectStoreTier(ctx))
    return {
        1: [local],
        2: [local, PartnerTier(ctx)],
        3: [local, ErasureTier(ctx)],
        4: l4,
    }


def recovery_ladder(stacks: Dict[int, List[Tier]]) -> List[Tier]:
    """Deduplicated tiers of every stack, in ladder order L1 → L4."""
    seen: Dict[str, Tier] = {}
    for lvl in sorted(stacks):
        for t in stacks[lvl]:
            seen.setdefault(t.name, t)
    return sorted(seen.values(), key=lambda t: t.level)


# -------------------------------------------------------------------------- #
# Pack-side tiers — per-leaf encoders driven by Protect clauses
# -------------------------------------------------------------------------- #

_AUX_GROUP = "codecaux"          # side-channel datasets (e.g. int8 scales)


def clause_attrs(spec: Optional[Protect], eff_kind: str) -> Dict[str, Any]:
    """The dataset attributes the CHK5 format tier records for one leaf:
    the *effective* kind plus every clause the governing spec carried.
    ``compress`` is recorded as ``codec`` only by the codec tier itself
    (on success), so the attr always reflects what is actually on disk."""
    attrs: Dict[str, Any] = {"kind": eff_kind}
    if spec is not None:
        attrs["selector"] = spec.selector
        for k, v in spec.clauses().items():
            if k in ("kind", "compress"):
                continue
            attrs[k] = v
    return attrs


class PackTier(abc.ABC):
    """One Pack-stage encoder: leaf + Protect spec → container datasets."""

    name: str = "?"

    @abc.abstractmethod
    def wants(self, spec: Optional[Protect]) -> bool:
        """Does this tier handle a leaf governed by ``spec``?"""

    @abc.abstractmethod
    def encode(self, w: CHK5Writer, name: str, arr: np.ndarray,
               spec: Optional[Protect], attrs: Dict[str, Any]) -> None:
        """Write ``data/<name>`` (plus any aux datasets) into ``w``."""


class CHK5FormatTier(PackTier):
    """The always-on format tier (paper §4.2.4: checkpoints double as
    analyzable datasets).  Records the leaf's clause attrs as dataset
    attributes and applies the ``precision`` cast (restore casts back to
    the recorded original dtype)."""

    name = "chk5"

    def wants(self, spec: Optional[Protect]) -> bool:
        return True

    def encode(self, w, name, arr, spec, attrs):
        arr = np.asarray(arr)
        attrs = dict(attrs, dtype=dtype_to_str(arr.dtype))
        if spec is not None and spec.precision is not None:
            target = resolve_precision(spec.precision)
            if not np.issubdtype(arr.dtype, np.floating):
                # ints/bools keep their bits; record why the cast was skipped
                attrs.pop("precision", None)
                attrs["precision_fallback"] = (
                    f"{spec.precision}: non-float leaf "
                    f"({dtype_to_str(arr.dtype)})")
            elif arr.dtype != target:
                arr = arr.astype(target)
            # already at target precision: the clause is honored as-is —
            # keep the attr, nothing to cast
        w.write_dataset(f"data/{name}", arr, attrs)


def int8_encode_array(arr: np.ndarray, orig: np.ndarray,
                      max_error: Optional[float]):
    """The one int8 payload encoder behind both the gathered-leaf
    ``Int8CompressTier`` and the shard-chunk codec
    (core/resharding.write_shard_files): quantize ``arr`` (the
    precision-limited values), measure the roundtrip against ``orig``
    (the original values, whose dtype the restore must reproduce).

    → ``(q, scale, attrs)`` on success — ``attrs`` carries
    ``codec``/``codec_block``/``codec_error``/``roundtrip_crc32`` so the
    read side can dispatch and verify — or ``(None, None, attrs)`` with a
    ``codec_fallback`` reason when ``max_error`` is exceeded."""
    from repro.dist.compression import (
        BLOCK, dequantize_int8_np, quantize_int8_np)
    q, scale = quantize_int8_np(arr)
    back = dequantize_int8_np(q, scale, arr.shape).astype(orig.dtype)
    # relative-L2 roundtrip error in f32 (the f64 casts dominated the
    # compressed-store overhead); an overflow degrades to inf, which
    # simply trips the max_error fallback — never a silent accept
    d = (back.astype(np.float32, copy=False)
         - orig.astype(np.float32, copy=False)).reshape(-1)
    a32 = orig.astype(np.float32, copy=False).reshape(-1)
    err = float(np.sqrt(np.dot(d, d))
                / max(float(np.sqrt(np.dot(a32, a32))), 1e-12))
    if max_error is not None and err > max_error:
        return None, None, {"codec_fallback": (
            f"int8: roundtrip error {err:.3e} > max_error {max_error:.3e}")}
    attrs = {"codec": Int8CompressTier.codec, "codec_block": BLOCK,
             "codec_error": err,
             "roundtrip_crc32": zlib.crc32(back.tobytes()) & 0xFFFFFFFF}
    return q, scale, attrs


class Int8CompressTier(PackTier):
    """``compress="int8"`` — per-block max-abs int8 quantization of the
    packed payload (dist/compression.py), the ROADMAP's compressed-payload
    tier.  Lossy by construction (elementwise error ≤ max|block|/127), so:

    - the *dequantized* payload is computed at pack time and its crc32
      recorded — load dequantizes and verifies against it, making the
      restore path roundtrip-verified end to end;
    - a spec ``max_error`` bound makes the tier fall back to an
      uncompressed write when the observed relative-L2 roundtrip error
      exceeds it (recorded in ``codec_fallback``);
    - non-float leaves always fall back (quantizing step counters or bit
      payloads is meaningless).
    """

    name = "int8"
    codec = "int8"

    def wants(self, spec: Optional[Protect]) -> bool:
        return spec is not None and spec.compress == self.codec

    def encode(self, w, name, arr, spec, attrs):
        arr = np.asarray(arr)
        if not np.issubdtype(arr.dtype, np.floating):
            CHK5FormatTier().encode(w, name, arr, spec, dict(
                attrs, codec_fallback=(
                    f"int8: non-float leaf ({dtype_to_str(arr.dtype)})")))
            return
        orig = arr
        if spec.precision is not None:
            # the precision clause composes with the codec: quantize the
            # precision-limited values (same store-side cast the format
            # tier applies), restore still casts back to the original
            target = resolve_precision(spec.precision)
            if arr.dtype != target:
                arr = arr.astype(target)
        q, scale, codec_attrs = int8_encode_array(arr, orig, spec.max_error)
        if q is None:
            CHK5FormatTier().encode(w, name, orig, spec,
                                    dict(attrs, **codec_attrs))
            return
        attrs = dict(attrs, **codec_attrs, dtype=dtype_to_str(orig.dtype),
                     shape=[int(x) for x in orig.shape])
        w.write_dataset(f"data/{name}", q, attrs)
        w.write_dataset(f"{_AUX_GROUP}/{name}/scale", scale)


def default_pack_tiers() -> List[PackTier]:
    """Clause-priority order: codecs first, the format tier as fallback."""
    return [Int8CompressTier(), CHK5FormatTier()]


def pack_named(w: CHK5Writer, named_host: Dict[str, np.ndarray],
               specs: Optional[Dict[str, Optional[Protect]]],
               pack_tiers: Optional[List[PackTier]] = None,
               default_kind: str = CHK_FULL) -> None:
    """Run the Pack-tier chain over every leaf (first tier that ``wants``
    the governing spec encodes it)."""
    tiers = pack_tiers if pack_tiers is not None else default_pack_tiers()
    specs = specs or {}
    for name, arr in named_host.items():
        spec = specs.get(name)
        attrs = clause_attrs(spec, default_kind)
        for tier in tiers:
            if tier.wants(spec):
                tier.encode(w, name, np.asarray(arr), spec, attrs)
                break
        else:
            # a silently dropped leaf would only surface at restore time;
            # fail the store where the misconfiguration is
            raise RuntimeError(
                f"no pack tier accepted leaf {name!r} (spec {spec!r}) — "
                f"compose_pack_tiers chains must end with a catch-all "
                f"(CHK5FormatTier)")


def decode_leaf(rd: CHK5Reader, ds_name: str) -> np.ndarray:
    """Decode one ``data/...`` dataset, dispatching on its ``codec`` attr
    (self-describing — no Protect specs needed at restore)."""
    meta = rd.info(ds_name)
    attrs = meta.get("attrs", {})
    codec = attrs.get("codec")
    if codec == Int8CompressTier.codec:
        from repro.dist.compression import dequantize_int8_np
        name = ds_name[len("data/"):]
        q = rd.read_dataset(ds_name)
        scale = rd.read_dataset(f"{_AUX_GROUP}/{name}/scale")
        arr = dequantize_int8_np(q, scale, attrs["shape"]).astype(
            str_to_dtype(attrs["dtype"]))
        got = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
        if got != attrs["roundtrip_crc32"]:
            raise CHK5CorruptionError(
                f"{rd.path}:{ds_name}: int8 codec roundtrip mismatch "
                f"(crc {got:#x} != recorded {attrs['roundtrip_crc32']:#x})")
        return arr
    if codec is not None:
        raise CHK5CorruptionError(
            f"{rd.path}:{ds_name}: unknown payload codec {codec!r}")
    arr = rd.read_dataset(ds_name)
    if "precision" in attrs and "dtype" in attrs:
        arr = arr.astype(str_to_dtype(attrs["dtype"]))
    return arr


def unpack_named(rd: CHK5Reader) -> Dict[str, np.ndarray]:
    """Decode every ``data/...`` dataset of a container → {path: array}."""
    out: Dict[str, np.ndarray] = {}
    for ds in rd.datasets():
        if ds.startswith("data/"):
            out[ds[len("data/"):]] = decode_leaf(rd, ds)
    return out
