"""Mixture-of-Experts MLP — GShard-style grouped capacity dispatch.

Two dispatch implementations (ablated in EXPERIMENTS.md §Perf):

- ``einsum``: one-hot dispatch/combine einsums (classic TPU MoE — GShard
  [arXiv:2006.16668] / Switch [arXiv:2101.03961]). Dispatch FLOP overhead is
  ~``group_size / (3·d_ff)`` of expert compute, MXU-friendly, SPMD-clean.
- ``scatter``: sort-based token permutation (MegaBlocks-flavored) — moves
  dispatch cost from FLOPs to bytes (gather/scatter), at the price of less
  regular collectives under SPMD.

Tokens are processed in groups of ``group_size`` with per-group expert
capacity ``C = ceil(group_size · top_k · capacity_factor / E)`` (overflow
tokens are dropped by the router — their residual path passes through).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    assert m is not None
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "moe_w_gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "moe_w_up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
        "moe_w_down": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / jnp.sqrt(f))).astype(dtype),
    }


def _capacity(m: MoEConfig) -> int:
    c = int(m.group_size * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, (c + 3) // 4 * 4)


def _router(x_groups: jnp.ndarray, p: Params, m: MoEConfig):
    """x_groups (G, gs, d) → gates (G,gs,k), idx (G,gs,k), probs (G,gs,E), aux."""
    logits = (x_groups.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss on the top-1 assignment
    top1 = jax.nn.one_hot(idx[..., 0], m.n_experts)
    frac_tokens = jnp.mean(top1, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs)
    return gates, idx, aux


def _expert_ffn(p: Params, h: jnp.ndarray, act) -> jnp.ndarray:
    """h (E, C', d) → (E, C', d) batched gated MLP."""
    up = act(jnp.einsum("ecd,edf->ecf", h, p["moe_w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", h, p["moe_w_up"])
    return jnp.einsum("ecf,efd->ecd", up, p["moe_w_down"])


def _moe_einsum(p: Params, xg: jnp.ndarray, m: MoEConfig, act) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g, gs, d = xg.shape
    e, cap = m.n_experts, _capacity(m)
    gates, idx, aux = _router(xg, p, m)

    dispatch = jnp.zeros((g, gs, e, cap), dtype=xg.dtype)
    combine = jnp.zeros((g, gs, e, cap), dtype=jnp.float32)
    count = jnp.zeros((g, 1, e), dtype=jnp.int32)
    for k in range(m.top_k):
        mask = jax.nn.one_hot(idx[..., k], e, dtype=jnp.int32)      # (G,gs,E)
        pos = jnp.cumsum(mask, axis=1) - mask + count                # (G,gs,E)
        keep = (pos < cap) & (mask > 0)
        count = count + jnp.sum(mask, axis=1, keepdims=True)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=xg.dtype) * keep[..., None].astype(xg.dtype)
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh.astype(jnp.float32) * gates[..., k][..., None, None]

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)           # (E,G,C,d)
    expert_out = _expert_ffn(p, expert_in.reshape(e, g * cap, d), act)
    expert_out = expert_out.reshape(e, g, cap, d)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(xg.dtype), expert_out)
    return out, aux


def _moe_scatter(p: Params, xg: jnp.ndarray, m: MoEConfig, act) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g, gs, d = xg.shape
    e, cap = m.n_experts, _capacity(m)
    gates, idx, aux = _router(xg, p, m)
    x = xg.reshape(g * gs, d)
    n = g * gs

    flat_e = idx.reshape(n, m.top_k).reshape(-1)                     # (n·k,)
    flat_t = jnp.repeat(jnp.arange(n), m.top_k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted, t_sorted, g_sorted = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert = index - first index of that expert
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    ranks = jnp.arange(n * m.top_k) - starts[e_sorted]
    cap_total = max(4, int(n * m.top_k * m.capacity_factor / e))
    keep = ranks < cap_total
    slot = jnp.where(keep, e_sorted * cap_total + ranks, e * cap_total)  # drop row

    buf = jnp.zeros((e * cap_total + 1, d), dtype=x.dtype)
    buf = buf.at[slot].set(x[t_sorted], mode="drop")
    hidden = _expert_ffn(p, buf[:-1].reshape(e, cap_total, d), act)
    hidden = hidden.reshape(e * cap_total, d)
    picked = jnp.where(keep[:, None], hidden[jnp.clip(slot, 0, e * cap_total - 1)], 0.0)
    out = jnp.zeros((n, d), dtype=jnp.float32)
    out = out.at[t_sorted].add(picked.astype(jnp.float32) * g_sorted[:, None])
    return out.astype(x.dtype).reshape(g, gs, d), aux


def moe_mlp(p: Params, x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) → (out (B, S, d), aux_loss scalar)."""
    from repro.models.layers import _ACTS
    m = cfg.moe
    assert m is not None
    act = _ACTS[cfg.act]
    b, s, d = x.shape
    n = b * s
    gs = min(m.group_size, n)
    assert n % gs == 0, f"tokens {n} not divisible by group {gs}"
    xg = x.reshape(n // gs, gs, d)
    if m.dispatch == "scatter":
        out, aux = _moe_scatter(p, xg, m, act)
    else:
        out, aux = _moe_einsum(p, xg, m, act)
    return out.reshape(b, s, d), aux
