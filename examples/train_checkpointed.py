"""End-to-end driver: train an LM with checkpoint/restart, fault included.

Default: reduced tinyllama (CPU-friendly, ~1 min). The full-scale flow —
supervision, heartbeats, restart loop — is the same code path used by
``python -m repro.launch.train --supervise`` (see that module); pass
``--hundred-m`` for a ~100M-parameter llama-family config if you have the
compute budget (same code, bigger dims).

Run:  PYTHONPATH=src python examples/train_checkpointed.py
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get_arch
from repro.core.context import CheckpointConfig, CheckpointContext
from repro.data.synthetic import init_data_state
from repro.ft.failures import FaultInjector, SimulatedFault
from repro.models.zoo import build_model
from repro.train.loop import LevelSchedule, LoopConfig, run_training
from repro.train.optimizer import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--differential", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/openchk-train-example")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = get_arch("tinyllama-1.1b").reduced()
    if args.hundred_m:    # ~100M params, same family
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab_size=32_000)
    model = build_model(cfg)
    print(f"params ≈ {cfg.param_count() / 1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params, jax.random.PRNGKey(1), init_data_state())
    step = make_train_step(model, AdamWConfig(total_steps=args.steps,
                                              warmup_steps=5))
    loop = LoopConfig(total_steps=args.steps, ckpt_every=10,
                      kind="DIFF" if args.differential else "FULL",
                      levels=LevelSchedule())

    # attempt 1: fault at 90 % progress (paper §6.1 methodology)
    ctx = CheckpointContext(CheckpointConfig(dir=args.ckpt_dir))
    inj = FaultInjector(args.steps, at_progress=0.9)
    try:
        run_training(model, step, state, ctx, loop, 8, 64, injector=inj)
    except SimulatedFault as e:
        print(f"!! {e}")
    finally:
        ctx.shutdown()

    # attempt 2: transparent restart → completion
    ctx2 = CheckpointContext(CheckpointConfig(dir=args.ckpt_dir))
    out = run_training(model, step, state, ctx2, loop, 8, 64)
    ctx2.shutdown()
    print(f"finished: step={out['final_step']} loss={out['loss']:.4f} "
          f"restarted={out['restarted']} backend_stats={out['stats']}")


if __name__ == "__main__":
    main()
