"""Train-step factory: loss, grads, microbatch accumulation, optimizer.

Data-parallel gradient averaging is *implicit*: parameters are replicated on
the (pod×)data axes so GSPMD inserts the grad all-reduce (or reduce-scatter +
all-gather under ZeRO-1 — selected purely by the optimizer-state sharding;
see ``launch/dryrun.py``). An explicit int8-compressed gradient-sync variant
lives in ``repro/dist/compression.py``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.models.layers import softmax_cross_entropy
from repro.train.optimizer import AdamWConfig, adamw_update
from repro.train.state import TrainState


def compute_loss(model: Model, params: Any, batch: Dict[str, jnp.ndarray],
                 remat: bool = False) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = model.forward(params, batch, remat=remat)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    ce = softmax_cross_entropy(logits, safe)
    ce = jnp.sum(ce * valid) / jnp.maximum(1.0, jnp.sum(valid))
    w = model.cfg.moe.router_aux_weight if model.cfg.moe is not None else 0.0
    loss = ce + w * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    remat: bool = True,
    num_microbatches: int = 1,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    """Build ``train_step(state, batch) → (state, metrics)`` for jit/pjit."""

    grad_fn = jax.value_and_grad(
        lambda p, b: compute_loss(model, p, b, remat=remat), has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def microbatched(params, batch):
        mb = num_microbatches

        def split(x):
            return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])

        batches = jax.tree.map(split, batch)
        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mbatch):
            acc, _ = carry
            (loss, metrics), grads = grad_fn(params, mbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / mb, acc, grads)
            return (acc, metrics), None

        (grads, metrics), _ = jax.lax.scan(
            body, (zero_g, {"loss": jnp.zeros(()), "ce": jnp.zeros(()),
                            "aux": jnp.zeros(())}), batches)
        return grads, metrics

    accumulate = single if num_microbatches == 1 else microbatched

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict]:
        grads, metrics = accumulate(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, **opt_metrics)
        new_state = TrainState(
            step=state.step + 1,
            params=new_params,
            opt=new_opt,
            rng=jax.random.fold_in(state.rng, 0),
            data_state=state.data_state,
        )
        return new_state, metrics

    return train_step


def make_fused_data_train_step(model: Model, opt_cfg: AdamWConfig,
                               global_batch: int, seq_len: int,
                               remat: bool = True, num_microbatches: int = 1):
    """Variant that draws its batch from the in-state data cursor — the form
    lowered by the dry-run (batch generation fused into the step) and used by
    the training loop for exactly-once data semantics."""
    from repro.data.synthetic import next_batch

    step_fn = make_train_step(model, opt_cfg, remat=remat,
                              num_microbatches=num_microbatches)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        new_state, metrics = step_fn(state, batch)
        _, new_data = next_batch(state.data_state, model.cfg, global_batch, seq_len)
        return new_state._replace(data_state=new_data), metrics

    return train_step
