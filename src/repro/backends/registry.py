"""Backend selection — the portability mechanism of the paper.

The backend is chosen at *runtime* from config or the ``OPENCHK_BACKEND``
environment variable; application code is identical for all three
(``examples/multibackend_portability.py`` runs the same training script
under each backend with zero source changes).
"""
from __future__ import annotations

import os
from typing import Optional

from repro.backends.base import Backend
from repro.backends.fti import FTIBackend
from repro.backends.scr import SCRBackend
from repro.backends.veloc import VeloCBackend
from repro.core.comm import Communicator
from repro.core.storage import StorageConfig

BACKENDS = {
    "fti": FTIBackend,
    "scr": SCRBackend,
    "veloc": VeloCBackend,
}

ENV_VAR = "OPENCHK_BACKEND"


def make_backend(cfg: StorageConfig, comm: Communicator,
                 name: Optional[str] = None, **kw) -> Backend:
    name = name or os.environ.get(ENV_VAR, "fti")
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}; have {sorted(BACKENDS)}")
    return BACKENDS[name](cfg, comm, **kw)
