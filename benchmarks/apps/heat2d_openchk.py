"""Heat-2D with OpenCHK directives. CR lines are tagged ``# [CR]`` —
bench_sloc.py counts them (5, matching the paper's 'five lines' claim)."""
from __future__ import annotations

import numpy as np

from benchmarks.apps.heat2d_common import checksum, heat_step, init_grid
from repro.core.context import CheckpointConfig, CheckpointContext  # [CR]


def run(n=128, steps=200, ckpt_every=20, ckpt_dir="/tmp/heat-openchk",
        injector=None, backend=None):
    # the step counter stays a host scalar (np.int32, like the native
    # variants' plain int) — a per-step jnp.int32() would charge a
    # device dispatch to the CR-instrumented loop that the physics
    # doesn't need, biasing the overhead ratio
    state = {"grid": init_grid(n), "t": np.int32(0)}
    ctx = CheckpointContext(CheckpointConfig(dir=ckpt_dir, backend=backend))  # [CR]
    state = ctx.load(state)                                                   # [CR]
    for t in range(int(state["t"]), steps):
        state = {"grid": heat_step(state["grid"]), "t": np.int32(t + 1)}
        if injector is not None:
            injector.maybe_fail(t + 1)
        ctx.store(state, id=t + 1, level=1, if_=(t + 1) % ckpt_every == 0)    # [CR]
    ctx.shutdown()                                                            # [CR]
    return {"checksum": checksum(state["grid"]), "restarted": ctx.restarted}
