"""Heat-2D against the native SCR-style file-mode API: the user writes and
reads the checkpoint file *themselves* through route_file, drives the
start/complete phase protocol, and modifies program flow for restarts —
the most verbose variant (paper Figs. 16-19, Table 5)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.apps.heat2d_common import checksum, heat_step, init_grid
from repro.backends.scr import SCRBackend                                  # [CR]
from repro.core.comm import LocalComm                                      # [CR]
from repro.core.formats import CHK5Reader, CHK5Writer                      # [CR]
from repro.core.storage import StorageConfig                               # [CR]


def run(n=128, steps=200, ckpt_every=20, ckpt_dir="/tmp/heat-scr",
        injector=None, backend=None):
    grid = init_grid(n)
    t = 0
    scr = SCRBackend(StorageConfig(root=ckpt_dir),                         # [CR]
                     LocalComm(ckpt_dir + "/node-local"),                  # [CR]
                     checkpoint_interval=ckpt_every)                       # [CR]
    restarted = False                                                      # [CR]
    if scr.have_restart() is not None:              # modified program flow  [CR]
        cid = scr.start_restart()                                          # [CR]
        path = scr.route_file("heat.ckpt")                                 # [CR]
        ok = False                                                         # [CR]
        try:                                                               # [CR]
            rd = CHK5Reader(path)                   # manual file I/O        [CR]
            t = int(rd.read_dataset("data/t"))      # manual deserialize     [CR]
            grid = jnp.asarray(rd.read_dataset("data/grid"))               # [CR]
            rd.close()                                                     # [CR]
            ok = True                                                      # [CR]
        except Exception:                                                  # [CR]
            t = 0                                                          # [CR]
        scr.complete_restart(ok)                                           # [CR]
        restarted = ok and t > 0                                           # [CR]
    for step in range(t, steps):
        grid = heat_step(grid)
        if injector is not None:
            injector.maybe_fail(step + 1)
        if (step + 1) % ckpt_every == 0:                                   # [CR]
            scr.start_checkpoint(step + 1, level=1)                        # [CR]
            path = scr.route_file("heat.ckpt")                             # [CR]
            valid = False                                                  # [CR]
            try:                                                           # [CR]
                with CHK5Writer(path) as w:         # manual file I/O        [CR]
                    w.write_dataset("data/t", np.int32(step + 1))          # [CR]
                    w.write_dataset("data/grid", np.asarray(grid))         # [CR]
                valid = True                                               # [CR]
            finally:                                                       # [CR]
                scr.complete_checkpoint(valid)                             # [CR]
    return {"checksum": checksum(grid), "restarted": restarted}
