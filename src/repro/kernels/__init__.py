"""Pallas TPU kernels for checkpoint hot-spots (blockhash, diffpack)."""
