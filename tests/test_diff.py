"""Differential checkpointing: dirty detection, replay, break-even promote."""
import numpy as np
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.core.diff import (
    DiffEngine,
    apply_delta,
    leaf_to_u32_flat,
    u32_flat_to_leaf,
)
from repro.kernels import ops

BB = 256          # small blocks for tests


def test_first_diff_is_all_dirty():
    eng = DiffEngine(block_bytes=BB)
    a = jnp.arange(1000, dtype=jnp.float32)
    deltas, stats = eng.compute_deltas({"a": a})
    # no base digests → every block dirty → promoted to full
    assert stats.dirty_ratio == 1.0
    assert deltas is None and stats.promoted_full


def test_clean_store_no_dirty():
    eng = DiffEngine(block_bytes=BB)
    a = jnp.arange(1000, dtype=jnp.float32)
    eng.update_digests_full({"a": a})
    deltas, stats = eng.compute_deltas({"a": a})
    assert stats.dirty_blocks == 0
    assert deltas is not None and deltas[0].dirty_idx.size == 0


def test_single_element_change_one_block():
    eng = DiffEngine(block_bytes=BB)
    a = jnp.arange(1000, dtype=jnp.float32)
    eng.update_digests_full({"a": a})
    b = a.at[500].set(-1.0)
    deltas, stats = eng.compute_deltas({"a": b})
    assert stats.dirty_blocks == 1
    assert deltas[0].dirty_idx.tolist() == [500 * 4 // BB]


def test_promote_threshold():
    eng = DiffEngine(block_bytes=BB, promote_threshold=0.5)
    a = jnp.arange(1024, dtype=jnp.float32)
    eng.update_digests_full({"a": a})
    deltas, stats = eng.compute_deltas({"a": a + 1.0})   # everything dirty
    assert deltas is None and stats.promoted_full


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 3000),
       n_edits=st.integers(0, 20),
       dtype=st.sampled_from(["float32", "int32", "float16", "uint8"]))
def test_replay_reconstructs_exactly(seed, n, n_edits, dtype):
    """full base + chain of diffs replays to the exact final array."""
    rng = np.random.RandomState(seed)
    base = np.abs(rng.randn(n) * 10).astype(dtype)
    eng = DiffEngine(block_bytes=BB)
    eng.update_digests_full({"x": jnp.asarray(base)})

    buf = leaf_to_u32_flat(base, BB)
    cur = base.copy()
    for _ in range(3):
        for _ in range(n_edits):
            i = rng.randint(0, n)
            cur[i] = np.asarray(abs(rng.randn()) * 10).astype(dtype)
        deltas, stats = eng.compute_deltas({"x": jnp.asarray(cur)})
        if deltas is None:          # promoted to FULL (past break-even)
            eng.update_digests_full({"x": jnp.asarray(cur)})
            buf = leaf_to_u32_flat(cur, BB)
            continue
        d = deltas[0]
        buf = apply_delta(buf, d.dirty_idx, d.payload, BB)
    got = u32_flat_to_leaf(buf, np.dtype(dtype).str, [n])
    assert np.array_equal(got, cur)


def test_bf16_roundtrip_through_u32():
    import ml_dtypes
    a = np.arange(7).astype(ml_dtypes.bfloat16)
    buf = leaf_to_u32_flat(a, BB)
    got = u32_flat_to_leaf(buf, "bfloat16", [7])
    assert np.array_equal(got.astype(np.float32), a.astype(np.float32))


def test_hash_collision_resistance_smoke():
    """changed bytes change the digest (salted 64-bit lanes)."""
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(4096).astype(np.float32))
    h1 = np.asarray(ops.blockhash(a, BB))
    flips = 0
    for i in rng.randint(0, 4096, size=50):
        b = a.at[int(i)].set(a[int(i)] + 1.0)
        h2 = np.asarray(ops.blockhash(b, BB))
        if not np.array_equal(h1, h2):
            flips += 1
    assert flips == 50
