"""Retention + crash-safe garbage collection of unreferenced chunks.

Policy (``CheckpointConfig.keep_last`` / ``keep_every``):

    keep_last=N     the newest N catalog entries stay published
    keep_every=K    additionally, every checkpoint whose id is a multiple
                    of K stays forever (the "archive one per epoch" knob)
    pinned entries  always stay (manual pin via ``Catalog.pin``)

The sweep is **mark-then-delete** so a crash at any point never loses a
live chunk:

    1. retire entries from the catalog (CAS — the entry disappears
       *first*, so no reader can restore a checkpoint whose chunks are
       about to vanish);
    2. recompute the live set from the *published* catalog and stage the
       condemned-chunk list as ``gc/mark.json`` — before any delete;
    3. delete the marked chunks;
    4. clear the mark.

A crash between 2 and 4 leaves the mark behind; the next collection
**re-verifies** every marked chunk against the current live set before
finishing the sweep (a chunk re-referenced by a newer checkpoint since
the mark was staged is spared), so resuming is safe even if uploads
happened in between.  Invariant tested in tests/test_objstore.py: no
chunk referenced by a published catalog entry is ever deleted.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.objstore.catalog import Catalog
from repro.objstore.chunks import chunk_key
from repro.objstore.client import ObjectStore

GC_MARK_KEY = "gc/mark.json"


def retention_split(ids: Sequence[int], keep_last: Optional[int],
                    keep_every: Optional[int],
                    pinned: Set[int] = frozenset()
                    ) -> Tuple[List[int], List[int]]:
    """→ (keep, retire), both sorted.  ``None`` policy values keep all."""
    ids = sorted(int(i) for i in ids)
    if keep_last is None and keep_every is None:
        return ids, []
    keep = set(pinned)
    if keep_last is not None and keep_last > 0:
        keep.update(ids[-int(keep_last):])
    if keep_every is not None and keep_every > 0:
        keep.update(i for i in ids if i % int(keep_every) == 0)
    return ([i for i in ids if i in keep],
            [i for i in ids if i not in keep])


def _digest_of_key(key: str) -> str:
    return key.rsplit("/", 1)[-1]


def _resume_mark(store: ObjectStore, live: Set[str]) -> int:
    """Finish a crashed sweep: delete marked chunks that are *still*
    unreferenced, spare any the live set reclaimed, then clear the mark."""
    data, _ = store.get_with_etag(GC_MARK_KEY)
    if data is None:
        return 0
    mark = json.loads(data.decode())
    deleted = 0
    for key in mark.get("condemned", []):
        if _digest_of_key(key) in live:
            continue                      # re-referenced since the mark
        store.delete(key)
        deleted += 1
    store.delete(GC_MARK_KEY)
    return deleted


def collect(store: ObjectStore, catalog: Catalog,
            keep_last: Optional[int] = None,
            keep_every: Optional[int] = None,
            sweep: str = "bucket") -> Dict[str, int]:
    """One retention + sweep pass.  Idempotent; safe to re-run after any
    crash (it first resumes a stale mark, re-verified).

    ``sweep`` picks what may be condemned:

    - ``"bucket"`` (default — the offline/admin pass): everything under
      ``chunks/`` not referenced by the published catalog, which also
      reclaims orphans from crashed uploads;
    - ``"retired"`` (what the pipeline's per-store GC uses): only chunks
      the just-retired entries referenced.  This never touches a chunk
      some *other* rank of an in-flight coordinated store has uploaded
      but not yet published — an unpublished chunk was never in a
      retired entry — and costs O(retired), not O(bucket).
    """
    if sweep not in ("bucket", "retired"):
        raise ValueError(f"unknown sweep mode {sweep!r}")
    entries = catalog.entries()
    pinned = {i for i, e in entries.items() if e.get("pinned")}
    _keep, retire = retention_split(list(entries), keep_last, keep_every,
                                    pinned)
    retired_chunks: Set[str] = set()
    if retire:
        for i in retire:
            retired_chunks.update(Catalog.entry_chunks(entries[i]))
        catalog.remove(retire)

    # live set from the *published* catalog — recomputed after retirement
    live = catalog.live_chunks()
    resumed = _resume_mark(store, live)

    if sweep == "bucket":
        candidates = store.list("chunks/")
    else:
        candidates = sorted(chunk_key(h) for h in retired_chunks)
    condemned = [k for k in candidates if _digest_of_key(k) not in live]
    deleted = 0
    if condemned:
        # the mark stages the full condemned list BEFORE any delete: a
        # kill mid-sweep leaves either nothing deleted or a resumable,
        # re-verifiable mark — never an unaccounted half-sweep
        store.put(GC_MARK_KEY, json.dumps(
            {"condemned": condemned}, sort_keys=True).encode())
        for key in condemned:
            store.delete(key)
            deleted += 1
        store.delete(GC_MARK_KEY)
    return {"retired": len(retire), "deleted": deleted,
            "resumed_deleted": resumed, "live": len(live)}
