"""The checkpoint catalog — the object store's source of truth.

One JSON document (``catalog/catalog.json``) records every checkpoint
the store holds: checkpoint id → the committed manifest, the file set
(each file's ordered chunk list — :class:`~repro.objstore.chunks.FileEntry`),
and a pin flag retention honors.  Publication is **atomic and last**:
chunks land first (Place), the local commit renames, and only then does
the catalog entry appear — a crash anywhere mid-upload leaves the
previous catalog state authoritative, so a reader can always trust what
the catalog lists (the "libraries must become more fault tolerant"
requirement: the storage layer survives its own partial failures).

Concurrent writers (the per-rank tiers of a coordinated store, GC)
serialize through a **compare-and-swap epoch guard**: every write carries
``epoch = read_epoch + 1`` and is applied with ``if_match=<etag of the
read state>`` — a lost race surfaces as ``PreconditionFailed`` and the
writer re-reads and retries, so merges never drop another rank's files
and a stale writer can never roll the catalog back.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from repro.objstore.chunks import FileEntry
from repro.objstore.client import (
    ObjectStore,
    ObjectStoreError,
    PreconditionFailed,
)

CATALOG_KEY = "catalog/catalog.json"
_EMPTY = {"version": 1, "epoch": 0, "entries": {}}


class CatalogConflictError(ObjectStoreError):
    """CAS retries exhausted — another writer kept winning the epoch."""


class Catalog:
    def __init__(self, store: ObjectStore, key: str = CATALOG_KEY):
        self.store = store
        self.key = key

    # -- reads ---------------------------------------------------------- #

    def read(self) -> Tuple[Dict[str, Any], Optional[str]]:
        """→ (catalog dict, etag) — etag ``None`` means "not created yet"
        (the CAS token for the first publish)."""
        data, etag = self.store.get_with_etag(self.key)
        if data is None:
            return json.loads(json.dumps(_EMPTY)), None
        return json.loads(data.decode()), etag

    def entries(self) -> Dict[int, Dict[str, Any]]:
        cat, _ = self.read()
        return {int(k): v for k, v in cat["entries"].items()}

    def ids(self) -> List[int]:
        return sorted(self.entries())

    def entry(self, ckpt_id: int) -> Optional[Dict[str, Any]]:
        return self.entries().get(int(ckpt_id))

    def epoch(self) -> int:
        return int(self.read()[0]["epoch"])

    def read_if_newer(self, last_epoch: int
                      ) -> Optional[Tuple[Dict[str, Any], int]]:
        """Epoch watch: one catalog read, ``None`` when nothing was
        published since ``last_epoch`` — the subscriber's poll primitive.
        Every catalog mutation bumps the epoch (CAS guard), so a single
        integer comparison decides "anything new?" without parsing
        entries.  → ``(catalog dict, epoch)`` only when newer."""
        cat, _etag = self.read()
        epoch = int(cat["epoch"])
        if epoch <= int(last_epoch):
            return None
        return cat, epoch

    @staticmethod
    def file_entries(entry: Dict[str, Any]) -> Dict[str, FileEntry]:
        return {name: FileEntry.from_json(name, d)
                for name, d in entry.get("files", {}).items()}

    @staticmethod
    def entry_chunks(entry: Dict[str, Any]) -> List[str]:
        """Every chunk digest an entry references.  Rows are
        ``[digest, offset, nbytes]`` (CDC entries) or the legacy
        ``[digest, nbytes]`` — the digest leads in both."""
        out = []
        for d in entry.get("files", {}).values():
            out.extend(row[0] for row in d.get("chunks", []))
        return out

    # -- CAS writes ----------------------------------------------------- #

    def _cas_update(self, mutate, retries: int = 16) -> Dict[str, Any]:
        """Read → ``mutate(catalog)`` → epoch+1 → conditional write; retry
        on a lost race.  ``mutate`` returns False to abort (no write)."""
        for _ in range(retries):
            cat, etag = self.read()
            if mutate(cat) is False:
                return cat
            cat["epoch"] = int(cat["epoch"]) + 1
            try:
                if etag is None:
                    self.store.put(self.key,
                                   json.dumps(cat, sort_keys=True).encode(),
                                   if_none_match=True)
                else:
                    self.store.put(self.key,
                                   json.dumps(cat, sort_keys=True).encode(),
                                   if_match=etag)
                return cat
            except PreconditionFailed:
                continue
        raise CatalogConflictError(
            f"catalog CAS lost {retries} races on {self.key}")

    def publish(self, ckpt_id: int, manifest: Dict[str, Any],
                files: Dict[str, FileEntry], pinned: bool = False
                ) -> Dict[str, Any]:
        """Publish (or merge into) the entry for ``ckpt_id``.

        Ranks of a coordinated store each publish their own file set under
        the same id; the merge unions ``files`` so the entry converges on
        the full multi-rank set regardless of commit order."""
        def mutate(cat):
            e = cat["entries"].setdefault(str(int(ckpt_id)), {
                "id": int(ckpt_id), "files": {}, "pinned": bool(pinned)})
            e["manifest"] = manifest
            e["pinned"] = bool(e.get("pinned", False) or pinned)
            for name, fe in files.items():
                e["files"][name] = fe.to_json()
        return self._cas_update(mutate)

    def remove(self, ckpt_ids) -> Dict[str, Any]:
        """Drop entries (retention retirement).  Pinned entries survive."""
        ids = {str(int(i)) for i in ckpt_ids}

        def mutate(cat):
            hit = False
            for i in list(cat["entries"]):
                if i in ids and not cat["entries"][i].get("pinned"):
                    del cat["entries"][i]
                    hit = True
            if not hit:
                return False
        return self._cas_update(mutate)

    def pin(self, ckpt_id: int, pinned: bool = True) -> Dict[str, Any]:
        def mutate(cat):
            e = cat["entries"].get(str(int(ckpt_id)))
            if e is None:
                return False
            e["pinned"] = bool(pinned)
        return self._cas_update(mutate)

    def live_chunks(self) -> set:
        """Every chunk digest referenced by any published entry — the GC
        live set."""
        live = set()
        for e in self.entries().values():
            live.update(self.entry_chunks(e))
        return live
