"""Staged pipeline: recovery-ladder order, tier redundancy for every kind
of store (FULL / DIFF / incremental) on every backend, async composition,
and the cross-store digest cache."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends.registry import make_backend
from repro.core.comm import SimulatedCluster
from repro.core.context import CheckpointConfig, CheckpointContext
from repro.core.diff import DiffEngine
from repro.core.storage import CHK_DIFF, CHK_FULL, StorageConfig

WORLD = 4


def _named(rank, val=None):
    return {"w": np.full(256, float(val if val is not None else rank),
                         np.float32),
            "step": np.asarray(np.int32(rank))}


def _backends(tmp_path, name):
    cluster = SimulatedCluster(str(tmp_path / "cluster"), WORLD)
    cfg = StorageConfig(root=str(tmp_path / "shared"), group_size=4,
                        block_bytes=256)
    kw = {"dedicated_thread": False} if name == "fti" else {}
    backends = [make_backend(cfg, c, name, **kw) for c in cluster.comms]
    return cluster, backends


def _store(b, rank, kind, level):
    """One committed checkpoint of `kind` on backend `b` (id of newest)."""
    if kind == "INC":
        inc = b.tcl_store_begin(1, level)
        inc.add({"w": _named(rank)["w"]})
        inc.add({"step": _named(rank)["step"]})
        inc.commit()
        b.tcl_wait()
        return 1
    b.tcl_store(_named(rank), 1, level, CHK_FULL)
    b.tcl_wait()
    if kind == CHK_DIFF:
        named2 = _named(rank)
        named2["w"][3] = -7.0
        b.tcl_store(named2, 2, level, CHK_DIFF)
        b.tcl_wait()
        return 2
    return 1


def test_recovery_ladder_is_l1_to_l4(tmp_path):
    """The read path tries tiers in FTI's ladder order L1→L2→L3→L4, with
    the object store as the final rung (catalog-backed restore — the one
    tier that survives every directory being wiped)."""
    cluster, backends = _backends(tmp_path, "fti")
    names = [t.name for t in backends[0].pipeline.ladder]
    assert names == ["local", "partner", "erasure", "global", "objstore"]
    levels = [t.level for t in backends[0].pipeline.ladder]
    assert levels == sorted(levels) == [1, 2, 3, 4, 5]
    assert backends[0].capabilities()["objstore"] is True


@pytest.mark.parametrize("backend", ["fti", "scr", "veloc"])
@pytest.mark.parametrize("kind", [CHK_FULL, CHK_DIFF, "INC"])
@pytest.mark.parametrize("level", [1, 2, 3, 4])
def test_store_crash_restart_ladder(tmp_path, backend, kind, level):
    """Store → simulated node crash → restart, for every level × backend ×
    store kind; recovery comes from the expected ladder rung."""
    cluster, backends = _backends(tmp_path, backend)
    newest = 0
    for r, b in enumerate(backends):
        newest = _store(b, r, kind, level)

    victim = 1
    # no-crash restore always comes from the ladder's first rung
    named, meta = backends[victim].engine.load_latest()
    assert meta["recovered_via"] == ("global" if level == 4 else "local")

    if level > 1:
        cluster.kill_node(victim)       # L1 alone does not survive this
        got = backends[victim].engine.load_latest()
        assert got is not None, f"L{level} recovery failed after node loss"
        named, meta = got
        assert meta["recovered_via"] == {2: "partner", 3: "erasure",
                                         4: "global"}[level]
    if kind == CHK_DIFF and backend == "fti":
        assert named["w"][3] == -7.0    # diff chain replayed
        assert meta["kind"] == CHK_DIFF
    else:
        assert named["w"][0] == float(victim)
    assert int(named["step"]) == victim
    assert meta["id"] == newest
    for b in backends:
        b.tcl_finalize()


@pytest.mark.parametrize("level", [2, 3])
def test_incremental_gets_level_redundancy(tmp_path, level):
    """Level-2/3 incremental checkpoints are replicated/encoded at commit
    and survive a node loss (routed through the pipeline's Place stage)."""
    cluster, backends = _backends(tmp_path, "fti")
    for r, b in enumerate(backends):
        inc = b.tcl_store_begin(5, level)
        inc.add({"w": np.full(64, float(r), np.float32)})
        rep = inc.commit()
        assert rep is not None and rep.level == level
        b.tcl_wait()
    cluster.kill_node(2)
    got = backends[2].engine.load_latest()
    assert got is not None
    named, meta = got
    assert named["w"][0] == 2.0
    assert meta["incremental"] is True
    assert meta["recovered_via"] == ("partner" if level == 2 else "erasure")


def test_incremental_async_commit_composes(tmp_path):
    """With a CP-dedicated thread, store_begin no longer fences in-flight
    stores, and commit runs Place→Commit asynchronously."""
    cfg = CheckpointConfig(dir=str(tmp_path / "a"), backend="fti",
                           dedicated_thread=True)
    ctx = CheckpointContext(cfg)
    state = {"w": jnp.arange(8.0)}
    ctx.store(state, id=1, level=1)            # async, not waited
    inc = ctx.store_begin(id=2, level=1)       # must not block on store 1
    inc.add({"w": jnp.arange(8.0) + 1})
    assert inc.commit() is None                # async tail → report deferred
    ctx.wait()
    ctx.shutdown()

    ctx2 = CheckpointContext(cfg)
    got = ctx2.load({"w": jnp.zeros(8)})
    assert ctx2.restarted
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0) + 1)
    ctx2.shutdown()


def test_async_diff_chain_composes(tmp_path):
    """Back-to-back DIFF stores on the CP thread keep a consistent digest
    chain (Plan runs synchronously in submission order)."""
    cfg = CheckpointConfig(dir=str(tmp_path / "d"), backend="fti",
                           dedicated_thread=True, block_bytes=256,
                           keep_last_full=2)
    ctx = CheckpointContext(cfg)
    x = jnp.arange(4096, dtype=jnp.float32)
    ctx.store({"x": x}, id=1, level=1)                      # FULL
    x2 = x.at[5].set(-1.0)
    ctx.store({"x": x2}, id=2, level=1, kind=CHK_DIFF)      # async DIFF
    x3 = x2.at[900].set(-2.0)
    ctx.store({"x": x3}, id=3, level=1, kind=CHK_DIFF)      # async DIFF
    ctx.wait()
    ctx.shutdown()

    ctx2 = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "d"),
                                              backend="fti"))
    got = ctx2.load({"x": jnp.zeros(4096)})
    assert float(got["x"][5]) == -1.0 and float(got["x"][900]) == -2.0
    ctx2.shutdown()


def test_digest_cache_skips_clean_jax_leaves(monkeypatch):
    """Identical (immutable) jax leaves skip the blockhash kernel on the
    next store; replaced jax leaves and mutable numpy leaves do not."""
    import repro.core.diff as diff_mod
    calls = []
    real = diff_mod.ops.blockhash
    monkeypatch.setattr(diff_mod.ops, "blockhash",
                        lambda leaf, bb: calls.append(1) or real(leaf, bb))

    eng = DiffEngine(block_bytes=256)
    x = jnp.arange(1024, dtype=jnp.float32)
    npb = np.arange(1024, dtype=np.float32)

    eng.update_digests_full({"a": x, "b": npb})
    assert len(calls) == 2

    # same jax object → clean, hash skipped; numpy always re-hashed
    deltas, stats = eng.compute_deltas({"a": x, "b": npb})
    assert len(calls) == 3                       # only "b"
    assert stats.skipped_leaves == 1
    assert stats.dirty_blocks == 0

    # in-place numpy mutation must be caught (no identity shortcut)
    npb[0] = -1.0
    deltas, stats = eng.compute_deltas({"a": x, "b": npb})
    assert len(calls) == 4
    assert stats.dirty_blocks >= 1

    # replaced jax leaf → re-hashed
    y = x.at[0].set(-1.0)
    deltas, stats = eng.compute_deltas({"a": y, "b": npb})
    assert len(calls) == 6
    assert any(d.path == "a" and d.dirty_idx.shape[0] for d in deltas)


def test_full_digest_bookkeeping_moves_to_cp_thread(tmp_path, monkeypatch):
    """FULL stores on diff-capable backends must not pay a synchronous
    full-tree blockhash in Plan: the digest update runs on the CP thread,
    and an interleaved DIFF fences on it (fresh base, no stale digests)."""
    import threading
    import repro.core.diff as diff_mod
    main = threading.get_ident()
    hash_threads = []
    real = diff_mod.ops.blockhash
    monkeypatch.setattr(
        diff_mod.ops, "blockhash",
        lambda leaf, bb: hash_threads.append(threading.get_ident())
        or real(leaf, bb))

    cfg = CheckpointConfig(dir=str(tmp_path / "h"), backend="fti",
                           dedicated_thread=True, block_bytes=256)
    ctx = CheckpointContext(cfg)
    x1 = jnp.arange(4096, dtype=jnp.float32)
    ctx.store({"x": x1}, id=1, level=1)                     # FULL, async
    x2 = x1.at[7].set(-1.0)
    ctx.store({"x": x2}, id=2, level=1, kind=CHK_DIFF)      # interleaved DIFF
    x3 = x2.at[2048].set(-2.0)
    ctx.store({"x": x3}, id=3, level=1)                     # FULL again
    x4 = x3.at[9].set(-3.0)
    ctx.store({"x": x4}, id=4, level=1, kind=CHK_DIFF)
    ctx.wait()
    ctx.shutdown()

    # FULL digests hashed off-thread; DIFF plans hash on the caller (by
    # design) AFTER the fence, so the order is [cp, main, cp, main]
    assert len(hash_threads) == 4
    assert hash_threads[0] != main and hash_threads[2] != main
    assert hash_threads[1] == main and hash_threads[3] == main

    ctx2 = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "h"),
                                              backend="fti"))
    named, meta = ctx2.tcl.backend.engine.load_latest()
    # id=4 committed as a real DIFF link (a stale/missing base would have
    # promoted it to FULL) and the replayed chain carries every mutation
    assert meta["kind"] == CHK_DIFF and meta["id"] == 4
    assert named["x"][7] == -1.0
    assert named["x"][2048] == -2.0
    assert named["x"][9] == -3.0
    ctx2.shutdown()


def test_deferred_error_surfaces_before_digest_mutation(tmp_path):
    """A failed async store must raise at the next directive BEFORE that
    directive's Plan advances the digest chain (and before an incremental
    commit closes its writer) — otherwise later DIFFs diff against data no
    committed checkpoint holds."""
    import shutil
    cfg = CheckpointConfig(dir=str(tmp_path / "e"), backend="fti",
                           dedicated_thread=True, block_bytes=256)
    ctx = CheckpointContext(cfg)
    eng = ctx.tcl.backend.engine
    x = jnp.arange(1024, dtype=jnp.float32)
    ctx.store({"x": x}, id=1, level=1)
    ctx.wait()
    # break the local tier (file where the ckpt tree must go) → async fail
    shutil.rmtree(eng.local_root)
    open(eng.local_root, "w").write("not a dir")
    ctx.store({"x": x.at[0].set(-1.0)}, id=2, level=1, kind=CHK_DIFF)
    ctx.tcl.backend._cp.wait()              # let the failure land
    digests_before = dict(eng.diff._digests)
    with pytest.raises(RuntimeError, match="asynchronous checkpoint"):
        ctx.store({"x": x.at[1].set(-2.0)}, id=3, level=1, kind=CHK_DIFF)
    # the raising directive must not have advanced the digest chain
    assert all(np.array_equal(digests_before[k], v)
               for k, v in eng.diff._digests.items())
    # after the error surfaced, the context keeps working
    os.remove(eng.local_root)
    os.makedirs(eng.local_root)
    ctx.store({"x": x}, id=4, level=4)
    ctx.wait()
    inc = ctx.store_begin(id=5, level=1)
    inc.add({"x": x})
    inc.commit()
    ctx.wait()
    ctx.shutdown()


def test_incremental_commit_retryable_after_deferred_error(tmp_path):
    """check_errors raising inside commit() leaves the store uncommitted
    and retryable."""
    import shutil
    cfg = CheckpointConfig(dir=str(tmp_path / "r"), backend="fti",
                           dedicated_thread=True, block_bytes=256)
    ctx = CheckpointContext(cfg)
    eng = ctx.tcl.backend.engine
    ctx.store({"x": jnp.ones(16)}, id=1, level=1)
    ctx.wait()
    shutil.rmtree(eng.local_root)
    open(eng.local_root, "w").write("not a dir")
    ctx.store({"x": jnp.zeros(16)}, id=2, level=1)      # async fail
    ctx.tcl.backend._cp.wait()
    os.remove(eng.local_root)
    os.makedirs(eng.local_root)
    inc = ctx.store_begin(id=3, level=1)
    inc.add({"w": jnp.ones(4)})
    with pytest.raises(RuntimeError, match="asynchronous checkpoint"):
        inc.commit()
    assert inc.commit() is None             # retry succeeds (async tail)
    ctx.wait()
    ctx.shutdown()


def test_sync_store_failure_invalidates_digest_chain(tmp_path):
    """A synchronous store that fails after Plan advanced the digest chain
    must invalidate it — the next DIFF may not delta against phantom data."""
    import shutil
    cfg = CheckpointConfig(dir=str(tmp_path / "s"), backend="fti",
                           dedicated_thread=False, block_bytes=256)
    ctx = CheckpointContext(cfg)
    eng = ctx.tcl.backend.engine
    x = jnp.arange(1024, dtype=jnp.float32)
    ctx.store({"x": x}, id=1, level=1)
    shutil.rmtree(eng.local_root)
    open(eng.local_root, "w").write("not a dir")
    with pytest.raises(OSError):
        ctx.store({"x": x * -1.0}, id=2, level=1)      # fails mid-Pack
    os.remove(eng.local_root)
    os.makedirs(eng.local_root)
    # digest base is gone → this DIFF promotes to FULL instead of emitting
    # a delta against the never-committed id=2 content
    rep = ctx.store({"x": x.at[0].set(5.0)}, id=3, level=1, kind=CHK_DIFF)
    assert rep.kind == CHK_FULL and rep.promoted_full
    ctx.shutdown()
    ctx2 = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "s"),
                                              backend="fti"))
    got = ctx2.load({"x": jnp.zeros(1024)})
    assert float(got["x"][0]) == 5.0 and float(got["x"][1]) == 1.0
    ctx2.shutdown()


def test_shutdown_surfaces_final_async_error(tmp_path):
    """A failure in the very last async store must not vanish at shutdown."""
    import shutil
    ctx = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "f"),
                                             backend="fti",
                                             dedicated_thread=True))
    eng = ctx.tcl.backend.engine
    shutil.rmtree(eng.local_root)
    open(eng.local_root, "w").write("not a dir")
    ctx.store({"x": jnp.ones(4)}, id=1, level=1)    # async, will fail
    with pytest.raises(RuntimeError, match="asynchronous checkpoint"):
        ctx.shutdown()


def test_config_dedicated_thread_reaches_veloc(tmp_path):
    """dedicated_thread=False in the user config must make VeloC
    synchronous too, not just FTI."""
    ctx = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "v"),
                                             backend="veloc",
                                             dedicated_thread=False))
    assert ctx.tcl.backend._cp is None
    rep = ctx.store({"x": jnp.ones(8)}, id=1, level=1)
    assert rep is not None and rep.kind == CHK_FULL    # sync → report now
    ctx.shutdown()


def test_backend_capabilities_and_shared_stacks(tmp_path):
    """Backends declare capabilities and compose the shared tier stacks —
    none re-implements placement."""
    from repro.core.comm import LocalComm
    caps = {}
    for name in ("fti", "scr", "veloc"):
        b = make_backend(StorageConfig(root=str(tmp_path / name)),
                         LocalComm(str(tmp_path / name / "nl")), name)
        caps[name] = b.capabilities()
        assert sorted(b.pipeline.stacks) == [1, 2, 3, 4]
        assert [t.name for t in b.pipeline.stacks[3]] == ["local", "erasure"]
        b.tcl_finalize()
    assert caps["fti"]["diff"] and not caps["scr"]["diff"]
    assert caps["veloc"]["dedicated_thread"]
    assert not caps["scr"]["dedicated_thread"]
