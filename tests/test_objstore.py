"""repro.objstore: the client contract (CAS, multipart/resumable put),
content-addressed chunk dedup, the CAS-epoch-guarded catalog, crash-safe
retention GC, and the pipeline-level guarantees — a kill mid-chunk-upload
leaves the previous catalog entry authoritative, a kill mid-GC never
deletes a live chunk, and a run whose L1–L3 (and global) directories are
wiped restores bit-exact from the object store alone on all three
backends."""

import glob
import io
import json
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import LocalComm
from repro.core.context import CheckpointConfig, CheckpointContext, Protect
from repro.core.storage import StorageConfig, StorageEngine
from repro.objstore import gc as objgc
from repro.objstore.catalog import Catalog, CatalogConflictError
from repro.objstore.chunks import ChunkUploader, FileEntry, chunk_key, fetch_file
from repro.objstore.client import (
    LocalFSObjectStore,
    MemoryObjectStore,
    ObjectStoreError,
    PreconditionFailed,
    make_object_store,
)

# ------------------------------------------------------------------ #
# client contract (both backends)
# ------------------------------------------------------------------ #


def _stores(tmp_path):
    return [MemoryObjectStore(),
            LocalFSObjectStore(str(tmp_path / "bucket"))]


def test_put_get_list_delete_and_etags(tmp_path):
    for st in _stores(tmp_path):
        etag = st.put("a/b/one", b"payload-1")
        assert st.get("a/b/one") == b"payload-1"
        data, etag2 = st.get_with_etag("a/b/one")
        assert (data, etag2) == (b"payload-1", etag)
        st.put("a/two", b"payload-2")
        st.put("z", b"payload-3")
        assert st.list("a/") == ["a/b/one", "a/two"]
        assert st.list() == ["a/b/one", "a/two", "z"]
        st.delete("a/two")
        st.delete("a/two")                      # idempotent
        assert not st.exists("a/two")
        assert st.get_with_etag("a/two") == (None, None)
        with pytest.raises(ObjectStoreError):
            st.get("a/two")
        with pytest.raises(ObjectStoreError):
            st.put("../escape", b"x")


def test_conditional_puts_are_cas(tmp_path):
    for st in _stores(tmp_path):
        etag = st.put("k", b"v1")
        # if_none_match: create-only
        with pytest.raises(PreconditionFailed):
            st.put("k", b"v2", if_none_match=True)
        st.put("fresh", b"v", if_none_match=True)
        # if_match: swap only from the observed state
        with pytest.raises(PreconditionFailed):
            st.put("k", b"v2", if_match="not-the-etag")
        etag2 = st.put("k", b"v2", if_match=etag)
        assert st.get("k") == b"v2"
        with pytest.raises(PreconditionFailed):
            st.put("k", b"v3", if_match=etag)    # stale token loses
        st.put("k", b"v3", if_match=etag2)
        # if_match against an absent key fails (nothing to swap from)
        with pytest.raises(PreconditionFailed):
            st.put("absent", b"v", if_match=etag)


def test_multipart_upload_is_resumable_and_atomic(tmp_path):
    for st in _stores(tmp_path):
        uid = st.create_multipart("big/object")
        st.upload_part("big/object", uid, 1, b"AAA-")
        st.upload_part("big/object", uid, 3, b"-CCC")
        assert not st.exists("big/object")       # nothing visible yet
        # a restarted uploader asks which parts already landed
        assert st.list_parts("big/object", uid) == [1, 3]
        st.upload_part("big/object", uid, 2, b"BBB")
        assert st.complete_multipart("big/object", uid)
        assert st.get("big/object") == b"AAA-BBB-CCC"
        assert st.list_parts("big/object", uid) == []   # staging gone
        # abort discards staging without touching the key
        uid2 = st.create_multipart("big/object")
        st.upload_part("big/object", uid2, 1, b"other")
        st.abort_multipart("big/object", uid2)
        assert st.get("big/object") == b"AAA-BBB-CCC"


def test_make_object_store_gates_cloud_clients(tmp_path):
    assert isinstance(make_object_store(f"file:{tmp_path}/b"),
                      LocalFSObjectStore)
    assert isinstance(make_object_store("mem:test"), MemoryObjectStore)
    with pytest.raises(ObjectStoreError, match="boto3"):
        make_object_store("s3://bucket/prefix")
    with pytest.raises(ObjectStoreError, match="unrecognized"):
        make_object_store("ftp://nope")


def test_localfs_internal_state_hidden_from_list(tmp_path):
    st = LocalFSObjectStore(str(tmp_path / "b"))
    uid = st.create_multipart("k")
    st.upload_part("k", uid, 1, b"part")
    assert st.list() == []                       # .mpu staging invisible
    st.put("cas", b"v", if_none_match=True)      # creates the lock file
    assert st.exists("cas")
    assert st.list() == ["cas"]                  # .cas.lock invisible


# ------------------------------------------------------------------ #
# chunk layer: dedup + verified reassembly
# ------------------------------------------------------------------ #


def _write(path, data: bytes):
    with open(path, "wb") as f:
        f.write(data)
    return path


def test_chunk_dedup_across_files(tmp_path):
    st = MemoryObjectStore()
    up = ChunkUploader(st, chunk_bytes=1024, transfers=2)
    shared = os.urandom(4096)
    a = _write(str(tmp_path / "a"), shared + b"tail-a")
    b = _write(str(tmp_path / "b"), shared + b"tail-b-different")
    ea = up.upload_file(a)
    assert up.stats["chunks_uploaded"] == 5 and up.stats["chunks_deduped"] == 0
    eb = up.upload_file(b)
    # the 4 shared 1 KiB chunks dedup; only b's tail uploads
    assert up.stats["chunks_deduped"] == 4
    assert up.stats["chunks_uploaded"] == 6
    assert [h for h, _o, _n in ea.chunks[:4]] == \
        [h for h, _o, _n in eb.chunks[:4]]
    # reassembly verifies digests
    fetch_file(st, ea, str(tmp_path / "a.back"))
    assert open(str(tmp_path / "a.back"), "rb").read() == shared + b"tail-a"
    # a corrupt chunk fails the fetch and leaves no torn file
    st._objects[chunk_key(eb.chunks[-1][0])] = b"corrupted!"
    with pytest.raises(ObjectStoreError, match="corrupt"):
        fetch_file(st, eb, str(tmp_path / "b.back"))
    assert not os.path.exists(str(tmp_path / "b.back"))


# ------------------------------------------------------------------ #
# catalog: CAS epoch guard + multi-writer merge
# ------------------------------------------------------------------ #


def _entry_files(tag: str):
    return {f"rank{tag}.chk5": FileEntry(f"rank{tag}.chk5", 8,
                                         [(f"h-{tag}", 8)])}


def test_catalog_publish_merges_ranks_and_bumps_epoch(tmp_path):
    for st in _stores(tmp_path):
        cat = Catalog(st)
        assert cat.ids() == [] and cat.epoch() == 0
        cat.publish(1, {"kind": "FULL", "level": 4}, _entry_files("0"))
        cat.publish(1, {"kind": "FULL", "level": 4}, _entry_files("1"))
        assert cat.epoch() == 2
        e = cat.entry(1)
        assert sorted(e["files"]) == ["rank0.chk5", "rank1.chk5"]
        assert sorted(cat.entry_chunks(e)) == ["h-0", "h-1"]
        cat.publish(2, {"kind": "FULL", "level": 4}, _entry_files("0"))
        assert cat.ids() == [1, 2]
        assert cat.live_chunks() == {"h-0", "h-1"}


class _RacingStore(MemoryObjectStore):
    """Injects a competing catalog write between a reader's read and its
    CAS put — every conditional put loses its first race."""

    def __init__(self, races: int):
        super().__init__()
        self._races = races

    def put(self, key, data, *, if_match=None, if_none_match=False):
        if (if_match or if_none_match) and self._races > 0:
            self._races -= 1
            doc = json.loads(super().get_with_etag(key)[0] or
                             b'{"version":1,"epoch":0,"entries":{}}')
            doc["epoch"] += 1
            doc["entries"].setdefault("999", {"id": 999, "files": {},
                                              "pinned": False,
                                              "manifest": {}})
            super().put(key, json.dumps(doc).encode())
        return super().put(key, data, if_match=if_match,
                           if_none_match=if_none_match)


def test_catalog_cas_retries_lost_races_without_dropping_entries():
    st = _RacingStore(races=2)
    cat = Catalog(st)
    cat.publish(1, {"kind": "FULL"}, _entry_files("0"))
    # both the raced-in entry and ours survive — no lost update
    assert cat.ids() == [1, 999]
    assert cat.epoch() >= 2

    st2 = _RacingStore(races=10**6)              # every retry loses
    with pytest.raises(CatalogConflictError):
        Catalog(st2).publish(1, {}, _entry_files("0"))


# ------------------------------------------------------------------ #
# retention + GC crash windows
# ------------------------------------------------------------------ #


def test_retention_split_policies():
    ids = [1, 2, 3, 4, 5, 6]
    assert objgc.retention_split(ids, None, None) == (ids, [])
    assert objgc.retention_split(ids, 2, None) == ([5, 6], [1, 2, 3, 4])
    assert objgc.retention_split(ids, 1, 3) == ([3, 6], [1, 2, 4, 5])
    assert objgc.retention_split(ids, 2, None, pinned={1}) == (
        [1, 5, 6], [2, 3, 4])


def _catalog_with_entries(st, n=4, shared_chunk=True):
    """n entries, each with one private chunk; optionally one chunk shared
    by all (the dedup case GC must respect)."""
    cat = Catalog(st)
    for i in range(1, n + 1):
        chunks = [(f"priv-{i}", 8)] + ([("shared", 8)] if shared_chunk else [])
        st.put(chunk_key(f"priv-{i}"), b"x" * 8)
        cat.publish(i, {"kind": "FULL", "level": 4},
                    {"rank0.chk5": FileEntry("rank0.chk5", 8 * len(chunks),
                                             chunks)})
    if shared_chunk:
        st.put(chunk_key("shared"), b"s" * 8)
    return cat


def test_gc_keep_last_leaves_exactly_the_live_chunk_set():
    st = MemoryObjectStore()
    cat = _catalog_with_entries(st, n=4)
    st.put(chunk_key("orphan"), b"never referenced")   # crashed upload debris
    got = objgc.collect(st, cat, keep_last=2)
    assert got["retired"] == 2
    assert cat.ids() == [3, 4]
    live = {chunk_key(h) for h in cat.live_chunks()}
    assert set(st.list("chunks/")) == live == {
        chunk_key("priv-3"), chunk_key("priv-4"), chunk_key("shared")}
    assert not st.exists(objgc.GC_MARK_KEY)
    # idempotent
    assert objgc.collect(st, cat, keep_last=2)["deleted"] == 0


def test_gc_keep_every_and_pinned_survive():
    st = MemoryObjectStore()
    cat = _catalog_with_entries(st, n=6, shared_chunk=False)
    cat.pin(1)
    objgc.collect(st, cat, keep_last=1, keep_every=3)
    # keep: newest (6), every 3rd (3, 6), pinned (1)
    assert cat.ids() == [1, 3, 6]
    assert set(st.list("chunks/")) == {
        chunk_key("priv-1"), chunk_key("priv-3"), chunk_key("priv-6")}


class _DyingDeleteStore(MemoryObjectStore):
    def __init__(self, die_after: int):
        super().__init__()
        self._left = die_after

    def delete(self, key):
        if key.startswith("chunks/"):
            if self._left == 0:
                raise RuntimeError("simulated kill mid-GC sweep")
            self._left -= 1
        super().delete(key)


def test_kill_mid_gc_never_deletes_a_live_chunk_and_resumes():
    st = _DyingDeleteStore(die_after=1)
    cat = _catalog_with_entries(st, n=4)
    with pytest.raises(RuntimeError, match="mid-GC"):
        objgc.collect(st, cat, keep_last=2)
    # catalog already consistent (entries retired first); the mark was
    # staged before any delete; every chunk the catalog references is
    # still present
    assert cat.ids() == [3, 4]
    assert st.exists(objgc.GC_MARK_KEY)
    for h in cat.live_chunks():
        assert st.exists(chunk_key(h)), f"live chunk {h} deleted mid-GC"
    # the resumed sweep finishes the mark and converges on the live set
    st._left = 10**9
    objgc.collect(st, cat, keep_last=2)
    assert not st.exists(objgc.GC_MARK_KEY)
    assert set(st.list("chunks/")) == {chunk_key(h)
                                       for h in cat.live_chunks()}


def test_retired_sweep_spares_unpublished_peer_chunks():
    """The pipeline's per-store GC (sweep="retired") condemns only chunks
    the retired entries referenced — a chunk a peer rank of an in-flight
    coordinated store has uploaded but not yet published is never
    deleted, and orphans are left for the offline bucket sweep."""
    st = MemoryObjectStore()
    cat = _catalog_with_entries(st, n=3)
    st.put(chunk_key("peer-inflight"), b"uploaded, publish pending")
    got = objgc.collect(st, cat, keep_last=2, sweep="retired")
    assert cat.ids() == [2, 3] and got["retired"] == 1
    assert not st.exists(chunk_key("priv-1"))       # retired & dead
    assert st.exists(chunk_key("shared"))           # retired but still live
    assert st.exists(chunk_key("peer-inflight"))    # never in any entry
    # the offline bucket sweep reclaims the orphan once it stays
    # unpublished
    objgc.collect(st, cat, sweep="bucket")
    assert not st.exists(chunk_key("peer-inflight"))
    with pytest.raises(ValueError, match="sweep"):
        objgc.collect(st, cat, sweep="everything")


def test_stale_mark_spares_rereferenced_chunks():
    """A chunk condemned by a crashed sweep but re-referenced by a newer
    checkpoint since is spared when the mark is resumed."""
    st = MemoryObjectStore()
    cat = _catalog_with_entries(st, n=2, shared_chunk=False)
    st.put(objgc.GC_MARK_KEY, json.dumps(
        {"condemned": [chunk_key("priv-2"), chunk_key("gone")]}).encode())
    st.put(chunk_key("gone"), b"zzz")
    objgc.collect(st, cat)                       # no retention, just sweep
    assert st.exists(chunk_key("priv-2"))        # live → spared
    assert not st.exists(chunk_key("gone"))      # still unreferenced → gone
    assert not st.exists(objgc.GC_MARK_KEY)


# ------------------------------------------------------------------ #
# pipeline integration: the L4 objstore rung
# ------------------------------------------------------------------ #


def _engine(tmp_path, tag="e", **cfg_kw):
    cfg = StorageConfig(root=str(tmp_path / "shared"), block_bytes=256,
                        **cfg_kw)
    return StorageEngine(cfg, LocalComm(str(tmp_path / f"nl-{tag}")))


def _state(val=1.0, n=4096):
    return {"w": np.full(n, val, np.float32), "step": np.int32(int(val))}


def _wipe_dirs(tmp_path, *engines):
    """Wipe L1–L3 node-local storage AND the L4 global directory — only
    the object-store bucket survives."""
    for e in engines:
        shutil.rmtree(e.comm.node_local_dir, ignore_errors=True)
    groot = os.path.join(str(tmp_path / "shared"), "global")
    for d in glob.glob(os.path.join(groot, "ckpt-*")):
        shutil.rmtree(d)
    latest = os.path.join(groot, "latest")
    if os.path.exists(latest):
        os.remove(latest)


def test_l4_store_publishes_catalog_and_dedups_second_store(tmp_path):
    # chunks smaller than the payload so unchanged regions can dedup
    # (CDC bounds scaled down to the test's ~16 KiB container); random w
    # so no two chunks within one store share bytes — the uploader dedups
    # repeated chunks in-flight, which would deflate up1 on np.full data
    eng = _engine(tmp_path, objstore_chunk_bytes=1024,
                  objstore_cdc_min_bytes=256,
                  objstore_cdc_avg_bytes=1024,
                  objstore_cdc_max_bytes=4096)
    tier = eng.objstore_tier()
    rng = np.random.default_rng(0)
    st1 = {"w": rng.normal(size=4096).astype(np.float32),
           "step": np.int32(1)}
    eng.store(st1, ckpt_id=1, level=4)
    assert tier.catalog.ids() == [1]
    up1 = tier.uploader.stats["bytes_uploaded"]
    assert up1 > 0
    st2 = {"w": st1["w"].copy(), "step": np.int32(1)}
    st2["w"][:8] = -5.0                          # small delta
    eng.store(st2, ckpt_id=2, level=4)
    up2 = tier.uploader.stats["bytes_uploaded"] - up1
    assert tier.catalog.ids() == [1, 2]
    # unchanged chunks upload nothing: the second store ships < 30% of
    # the first (the acceptance dedup bound; here the payload is small,
    # so the changed chunk + index dominate — still well under)
    assert up2 < 0.30 * up1, (up1, up2)


def test_restore_from_objstore_alone_after_full_wipe(tmp_path):
    eng = _engine(tmp_path)
    eng.store(_state(3.0), ckpt_id=3, level=4)
    _wipe_dirs(tmp_path, eng)
    eng2 = _engine(tmp_path, tag="fresh")
    named, meta = eng2.load_latest()
    assert meta["recovered_via"] == "objstore" and meta["id"] == 3
    np.testing.assert_array_equal(named["w"], _state(3.0)["w"])
    # the cache dir is now a normal committed checkpoint dir; a second
    # load works without touching the bucket's chunks again
    named2, _ = eng2.load_latest()
    np.testing.assert_array_equal(named2["w"], named["w"])


def test_corrupt_or_stale_cache_is_refetched_not_reused(tmp_path):
    """Cache reuse is digest-verified: a same-size corrupt (or stale)
    cached file is refetched from the bucket, never silently returned."""
    from repro.core import manifest as mf
    eng = _engine(tmp_path)
    eng.store(_state(4.0), ckpt_id=4, level=4)
    _wipe_dirs(tmp_path, eng)
    eng2 = _engine(tmp_path, tag="fresh")
    tier = eng2.objstore_tier()
    named, _ = eng2.load_latest()
    np.testing.assert_array_equal(named["w"], _state(4.0)["w"])
    # flip bytes inside the cached container without changing its size
    cached = os.path.join(mf.ckpt_dir(tier.root, 4), "rank0.chk5")
    size = os.path.getsize(cached)
    with open(cached, "r+b") as f:
        f.seek(size // 2)
        f.write(b"\xff" * 16)
    named3, meta3 = eng2.load_latest()
    assert meta3["id"] == 4
    np.testing.assert_array_equal(named3["w"], _state(4.0)["w"])


class _DyingPutStore:
    """Wraps a tier's real store: put raises after N chunk puts — the
    in-process stand-in for a kill mid-chunk-upload."""

    def __init__(self, inner, die_after: int):
        self._inner = inner
        self._left = die_after

    def put(self, key, data, **kw):
        if key.startswith("chunks/"):
            if self._left == 0:
                raise RuntimeError("simulated kill mid-chunk-upload")
            self._left -= 1
        return self._inner.put(key, data, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_kill_mid_chunk_upload_previous_entry_stays_authoritative(tmp_path):
    eng = _engine(tmp_path)
    eng.store(_state(1.0), ckpt_id=1, level=4)
    tier = eng.objstore_tier()
    real = tier.store
    dying = _DyingPutStore(real, die_after=0)
    tier.store = dying
    tier.uploader.store = dying
    st2 = _state(2.0)
    with pytest.raises(RuntimeError, match="mid-chunk-upload"):
        eng.store(st2, ckpt_id=2, level=4)
    tier.store = real
    tier.uploader.store = real
    # the failed store never reached the catalog: entry 1 authoritative
    assert tier.catalog.ids() == [1]
    _wipe_dirs(tmp_path, eng)
    eng2 = _engine(tmp_path, tag="fresh")
    named, meta = eng2.load_latest()
    assert meta["id"] == 1 and meta["recovered_via"] == "objstore"
    np.testing.assert_array_equal(named["w"], _state(1.0)["w"])
    # GC sweeps the crashed upload's orphaned chunks down to the live set
    tier2 = eng2.objstore_tier()
    objgc.collect(tier2.store, tier2.catalog, keep_last=4)
    assert set(tier2.store.list("chunks/")) == {
        chunk_key(h) for h in tier2.catalog.live_chunks()}


def test_pipeline_gc_keep_last_via_config(tmp_path):
    eng = _engine(tmp_path, objstore_keep_last=2)
    tier = eng.objstore_tier()
    for i in (1, 2, 3):
        eng.store(_state(float(i)), ckpt_id=i, level=4)
    assert tier.catalog.ids() == [2, 3]
    assert set(tier.store.list("chunks/")) == {
        chunk_key(h) for h in tier.catalog.live_chunks()}
    assert tier.stats["gc_deleted"] > 0


# ------------------------------------------------------------------ #
# directive-level: wipe L1–L3 (+ global dir) → restore, all 3 backends
# ------------------------------------------------------------------ #


def _tree_state():
    return {"params": {"w": jnp.arange(2048, dtype=jnp.float32),
                       "b": jnp.ones(17)},
            "opt": {"m": jnp.full(33, 0.5)},
            "step": jnp.int32(7)}


@pytest.mark.parametrize("backend", ["fti", "scr", "veloc"])
def test_restore_with_l1_l3_wiped_across_backends(tmp_path, backend):
    d = str(tmp_path / "ck")
    ctx = CheckpointContext(CheckpointConfig(
        dir=d, backend=backend, dedicated_thread=False))
    ctx.protect(Protect("params/**"), Protect("opt/**"), Protect("step"))
    state = _tree_state()
    ctx.store(state, id=1, level=4)
    ctx.shutdown()

    # wipe everything except the object-store bucket
    shutil.rmtree(os.path.join(d, "node-local"))
    for g in glob.glob(os.path.join(d, "global", "ckpt-*")):
        shutil.rmtree(g)
    os.remove(os.path.join(d, "global", "latest"))

    ctx2 = CheckpointContext(CheckpointConfig(
        dir=d, backend=backend, dedicated_thread=False))
    # the recovery really is the objstore rung
    got = ctx2.tcl.backend.engine.load_latest()
    assert got is not None and got[1]["recovered_via"] == "objstore"
    import jax
    template = jax.tree.map(jnp.zeros_like, state)
    ctx2.protect(Protect("params/**"), Protect("opt/**"), Protect("step"))
    restored = ctx2.load(template)
    assert ctx2.restarted
    ctx2.shutdown()
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chkls_lists_catalog_entries_json(tmp_path):
    import contextlib

    from repro.tools.chkls import main as chkls_main
    eng = _engine(tmp_path)
    eng.store(_state(1.0), ckpt_id=5, level=4)
    root = os.path.join(str(tmp_path / "shared"), "objstore")
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert chkls_main([root, "--json"]) == 0
    inv = json.loads(buf.getvalue())["catalog"]
    assert [e["id"] for e in inv["entries"]] == [5]
    e = inv["entries"][0]
    assert e["kind"] == "FULL" and e["level"] == 4
    assert "rank0.chk5" in e["files"]
    assert e["n_chunks"] >= 1 and inv["stored_chunks"] >= 1
    # per-entry chunk-size histogram (power-of-two buckets) + per-file
    # chunking mode ride the inventory
    assert sum(e["chunk_hist"].values()) == e["n_chunks"]
    assert e["chunk_bytes_max"] >= e["chunk_bytes_min"] > 0
    assert all(f["mode"] == "cdc" for f in e["files"].values())
    # human-readable mode also runs
    with contextlib.redirect_stdout(io.StringIO()):
        assert chkls_main([root]) == 0
    # a directory that is not an objstore root fails loudly (exit 2),
    # never "empty catalog"
    import contextlib as _ctxlib
    err = io.StringIO()
    with _ctxlib.redirect_stderr(err):
        assert chkls_main([str(tmp_path / "shared")]) == 2
    assert "not an object-store root" in err.getvalue()


# ------------------------------------------------------------------ #
# the fused (zero-stall) store path: CDC streaming + digest reuse
# ------------------------------------------------------------------ #


def test_store_streams_chunks_and_reuses_layout_for_clean_leaves(tmp_path):
    # first store records each FULL leaf's chunk layout under its
    # device-digest key; a second store of identical bytes replays the
    # layout (no CDC scan) and every chunk dedups
    eng = _engine(tmp_path)
    tier = eng.objstore_tier()
    eng.store(_state(1.0), ckpt_id=1, level=4)
    s1 = dict(tier.uploader.stats)
    assert s1["chunks_uploaded"] > 0
    eng.store(_state(1.0), ckpt_id=2, level=4)   # identical leaf bytes
    s2 = tier.uploader.stats
    assert s2["regions_reused"] > s1["regions_reused"]
    assert s2["bytes_scan_skipped"] > s1["bytes_scan_skipped"]
    assert tier.catalog.ids() == [1, 2]


def test_boundary_shift_reuploads_only_the_neighborhood(tmp_path):
    # insert 1 KiB in the middle of a 2 MiB leaf: a fixed-size chunker
    # would re-upload every chunk past the insertion point (~1 MiB); CDC
    # boundaries re-synchronize within a few chunks
    eng = _engine(tmp_path, objstore_chunk_bytes=4096,
                  objstore_cdc_min_bytes=1024,
                  objstore_cdc_avg_bytes=4096,
                  objstore_cdc_max_bytes=16384)
    tier = eng.objstore_tier()
    rng = np.random.default_rng(0)
    base = rng.integers(0, 256, 2 << 20, dtype=np.uint8)
    at = len(base) // 2
    eng.store({"blob": base}, ckpt_id=1, level=4)
    up1 = tier.uploader.stats["bytes_uploaded"]
    shifted = np.concatenate(
        [base[:at], rng.integers(0, 256, 1024, dtype=np.uint8), base[at:]])
    eng.store({"blob": shifted}, ckpt_id=2, level=4)
    delta = tier.uploader.stats["bytes_uploaded"] - up1
    tail = len(base) - at                        # what fixed-size re-ships
    assert delta < 0.30 * tail, (delta, tail)
    # both stores restore bit-exact from the bucket alone
    _wipe_dirs(tmp_path, eng)
    eng2 = _engine(tmp_path, tag="fresh")
    named, meta = eng2.load_latest()
    assert meta["id"] == 2
    np.testing.assert_array_equal(named["blob"], shifted)


def test_objstore_chunking_config_plumbs_through(tmp_path):
    # CDC bounds reach the uploader and the chunking mode is recorded in
    # the catalog entry
    eng = _engine(tmp_path, objstore_cdc_min_bytes=512,
                  objstore_cdc_avg_bytes=2048,
                  objstore_cdc_max_bytes=8192)
    tier = eng.objstore_tier()
    assert (tier.uploader.cdc.min_bytes, tier.uploader.cdc.avg_bytes,
            tier.uploader.cdc.max_bytes) == (512, 2048, 8192)
    eng.store(_state(1.0), ckpt_id=1, level=4)
    entry = tier.catalog.entry(1)
    assert all(f["mode"] == "cdc" for f in entry["files"].values())
    # "fixed" opts back into the legacy layout end to end
    engf = _engine(tmp_path / "fixed", tag="f", objstore_chunking="fixed")
    tierf = engf.objstore_tier()
    assert tierf.uploader.cdc is None
    engf.store(_state(2.0), ckpt_id=1, level=4)
    entryf = tierf.catalog.entry(1)
    assert all(f["mode"] == "fixed" for f in entryf["files"].values())
    named, _ = engf.load_latest()
    np.testing.assert_array_equal(named["w"], _state(2.0)["w"])


def test_checkpoint_config_maps_cdc_fields(tmp_path):
    cfg = CheckpointConfig(dir=str(tmp_path), objstore_chunking="fixed",
                           objstore_cdc_avg_bytes=123 << 10,
                           objstore_cdc_min_bytes=12 << 10,
                           objstore_cdc_max_bytes=1234 << 10)
    sc = cfg.storage()
    assert sc.objstore_chunking == "fixed"
    assert sc.objstore_cdc_min_bytes == 12 << 10
    assert sc.objstore_cdc_avg_bytes == 123 << 10
    assert sc.objstore_cdc_max_bytes == 1234 << 10
