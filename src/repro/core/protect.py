"""Protection registry: pytree ⇄ named arrays + clause-carrying selectors.

This is the layer that replaces the paper's compiler work (DESIGN.md §2):
Mercurium extracts base address / size / bounds from program symbols; here
pytree flattening extracts (path, dtype, shape, sharding) from the state the
user names. The user writes ``ctx.store(state, ...)`` — nothing is
hand-serialized.

Selectors are the analogue of *self-iterative data expressions* (§5.2):
``"params/groups/*/attn/**"`` expands over the tree exactly like
``{data[i], i=0;4}`` expands over an array.

A :class:`Protect` spec is a selector **plus the paper's per-data clauses**
(``kind(DIFF)``, compression codec, target format/precision, sharding-axis
metadata).  ``ctx.protect(Protect("params/**", kind=CHK_DIFF,
compress="int8"), Protect("step"))`` is the directive-level surface; the
resolved ``{path: Protect}`` map rides the StoreRequest/LoadRequest through
TCL → backend → pipeline, where the Pack-side tiers consume the clauses
(core/tiers.py).  Plain-string selectors remain accepted as a deprecated
shim and convert to clause-less specs.
"""
from __future__ import annotations

import re
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.tree_util import (
    tree_flatten_with_path,
    tree_unflatten,
    keystr,
)

CHK_FULL = "FULL"
CHK_DIFF = "DIFF"

#: codecs the Pack-side compression tier implements (core/tiers.py)
KNOWN_CODECS = ("int8",)
#: container formats the Pack-side format tier can emit
KNOWN_FORMATS = ("chk5",)
#: the gated-dependency message for ``Protect(format="hdf5")`` — raised at
#: *spec validation* time (constructing the spec), never deep in Pack, so
#: a misconfigured protect fails before any checkpoint machinery runs;
#: pinned verbatim by tests/test_protect_specs.py
HDF5_GATE_MSG = (
    "format='hdf5' needs h5py, which this environment does not ship; "
    "CHK5 keeps the same self-describing semantics (format='chk5')")
#: precision clause values → canonical dtype strings (core/formats.py
#: resolves them; bf16/fp8 need ml_dtypes, which jax ships)
PRECISIONS = {
    "bf16": "bfloat16", "bfloat16": "bfloat16",
    "f16": "<f2", "fp16": "<f2", "float16": "<f2",
    "f32": "<f4", "fp32": "<f4", "float32": "<f4",
}


@dataclass
class Protect:
    """One protected subtree: a selector plus per-subtree clauses.

    Clause fields (all optional — a clause-less spec is exactly the old
    flat selector):

    ``kind``       checkpoint kind for this subtree (``CHK_FULL`` /
                   ``CHK_DIFF``); ``None`` inherits the store's kind.
                   Mixed-kind stores (DIFF params + FULL optimizer in one
                   checkpoint) are expressed by giving subtrees different
                   kinds.
    ``compress``   Pack-side payload codec (``"int8"`` — per-block max-abs
                   quantization, dist/compression.py), roundtrip-verified
                   on load.
    ``format``     target container format tier (``"chk5"``).
    ``precision``  store-side dtype cast (``"bf16"`` …); restore casts back
                   to the template dtype.
    ``axis``       explicit axis metadata, e.g. ``{"batch": 1}`` — carried
                   to dist/sharding.py (cache layouts) and recorded as
                   dataset attrs.
    ``max_error``  relative-L2 bound for lossy codecs; a leaf whose
                   roundtrip error exceeds it is stored uncompressed
                   (``codec_fallback`` attr records why).
    """

    selector: str
    kind: Optional[str] = None
    compress: Optional[str] = None
    format: Optional[str] = None
    precision: Optional[str] = None
    axis: Optional[Dict[str, int]] = None
    max_error: Optional[float] = None
    _regex: Any = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        if not isinstance(self.selector, str) or not self.selector:
            raise ValueError(f"Protect selector must be a non-empty string, "
                             f"got {self.selector!r}")
        if self.kind is not None and self.kind not in (CHK_FULL, CHK_DIFF):
            raise ValueError(f"Protect kind must be {CHK_FULL!r} or "
                             f"{CHK_DIFF!r}, got {self.kind!r}")
        if self.compress is not None and self.compress not in KNOWN_CODECS:
            raise ValueError(f"unknown compress codec {self.compress!r}; "
                             f"have {list(KNOWN_CODECS)}")
        if self.format is not None and self.format not in KNOWN_FORMATS:
            if self.format == "hdf5":
                raise ValueError(HDF5_GATE_MSG)
            raise ValueError(f"unknown format {self.format!r}; "
                             f"have {list(KNOWN_FORMATS)}")
        if self.precision is not None and self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"have {sorted(PRECISIONS)}")
        if self.axis is not None and not all(
                isinstance(k, str) and isinstance(v, int)
                for k, v in self.axis.items()):
            raise ValueError(f"Protect axis must map str → int dim, "
                             f"got {self.axis!r}")
        self._regex = _selector_regex(self.selector)

    # ------------------------------------------------------------------ #

    def matches(self, path: str) -> bool:
        return self._regex.match(path) is not None

    def clauses(self) -> Dict[str, Any]:
        """The non-empty clause fields — what the format tier records as
        dataset attributes (and ``chkls`` prints)."""
        out: Dict[str, Any] = {}
        for f in ("kind", "compress", "format", "precision", "axis",
                  "max_error"):
            v = getattr(self, f)
            if v is not None:
                out[f] = v
        return out


def _selector_regex(pat: str):
    """``**`` crosses slashes; ``*`` does not."""
    esc = re.escape(pat)
    esc = esc.replace(r"\*\*", ".*").replace(r"\*", "[^/]*")
    return re.compile("^" + esc + "$")


def normalize_protects(
    specs: Optional[Sequence[Union[str, Protect]]],
) -> Optional[List[Protect]]:
    """Directive-level shim: accept ``Protect`` specs and (deprecated)
    plain selector strings; strings become clause-less specs."""
    if not specs:
        return None
    out: List[Protect] = []
    legacy = []
    for s in specs:
        if isinstance(s, Protect):
            out.append(s)
        elif isinstance(s, str):
            legacy.append(s)
            out.append(Protect(s))
        else:
            raise TypeError(f"protect() takes Protect specs or selector "
                            f"strings, got {type(s).__name__}")
    if legacy:
        warnings.warn(
            f"flat selector strings {legacy} are deprecated; use "
            f"Protect(selector, ...) specs (clauses: kind/compress/"
            f"format/precision/axis)", DeprecationWarning, stacklevel=3)
    return out


def _key_str(k) -> str:
    """One pytree key → its path component, stripping only the keystr
    delimiters: ``['name']`` → ``name``, ``[0]`` → ``0``, ``.attr`` →
    ``attr``.  A dict key like ``".hidden"`` or ``"w.q"`` keeps its dots
    and quotes-in-content intact (the old ``strip("[]'\\".")`` ate them)."""
    s = keystr((k,))
    if s.startswith("[") and s.endswith("]"):
        s = s[1:-1]
        if len(s) >= 2 and s[0] == s[-1] and s[0] in ("'", '"'):
            s = s[1:-1]
    elif s.startswith("."):
        s = s[1:]
    return s


def _path_str(path) -> str:
    """KeyPath → canonical slash path: ('params','groups',0,'attn','wq') →
    "params/groups/0/attn/wq"."""
    return "/".join(_key_str(k) for k in path)


def flatten_named(tree: Any) -> Tuple[Dict[str, Any], Any]:
    """→ ({path: leaf}, treedef). Paths are stable across runs (dict order
    canonicalized by jax pytree registry)."""
    leaves, treedef = tree_flatten_with_path(tree)
    named = {}
    for path, leaf in leaves:
        p = _path_str(path)
        if p in named:
            raise ValueError(f"duplicate pytree path {p!r}")
        named[p] = leaf
    return named, treedef


def unflatten_named(treedef, named: Dict[str, Any], template: Any) -> Any:
    """Rebuild a tree shaped like ``template`` from named leaves (match by
    path; order-free — unlike the paper's order-critical load/store lists)."""
    t_leaves, t_def = tree_flatten_with_path(template)
    out = []
    for path, leaf in t_leaves:
        p = _path_str(path)
        if p not in named:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        out.append(named[p])
    return tree_unflatten(t_def, out)


def resolve_specs(
    named: Dict[str, Any],
    protects: Optional[Sequence[Union[str, Protect]]],
) -> Dict[str, Optional[Protect]]:
    """Resolve clause specs over the flattened tree → ``{path: spec}``.

    ``None``/empty → every leaf, clause-less (``{path: None}``).  A leaf
    matched by several specs is selected **once**, governed by the *first*
    matching spec (specs are ordered, most-specific first by convention).
    A spec that matches no leaf is an error naming the offending selector —
    this is the "matched no leaves" path that ``ctx.load``/``ctx.store``
    surface to the user."""
    if not protects:
        return {path: None for path in named}
    specs = normalize_protects(protects)
    out: Dict[str, Optional[Protect]] = {}
    unmatched = []
    for spec in specs:
        hit = False
        for path in named:
            if spec.matches(path):
                hit = True
                out.setdefault(path, spec)     # first matching spec governs
        if not hit:
            unmatched.append(spec.selector)
    if unmatched:
        raise ValueError(
            f"Protect selectors {unmatched} matched no leaves "
            f"(all selectors: {[s.selector for s in specs]}; "
            f"protected paths: {sorted(named)[:8]}"
            f"{' …' if len(named) > 8 else ''})")
    # keep the tree's canonical leaf order, not match order
    return {path: out[path] for path in named if path in out}


def select(named: Dict[str, Any], patterns: Optional[List[str]]) -> Dict[str, Any]:
    """Glob-select protected leaves. ``None`` → everything. ``**`` crosses
    slashes; ``*`` does not.  (Compatibility wrapper over
    :func:`resolve_specs` — kept for callers that only need the leaves.)"""
    if not patterns:
        return dict(named)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        chosen = resolve_specs(named, list(patterns))
    return {path: named[path] for path in chosen}


def to_host(named: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Device→host snapshot of every protected leaf (one fused transfer)."""
    arrs = jax.device_get(list(named.values()))
    return {k: np.asarray(v) for k, v in zip(named.keys(), arrs)}


def leaf_meta(named: Dict[str, Any]) -> Dict[str, Dict]:
    out = {}
    for k, v in named.items():
        out[k] = {"dtype": np.dtype(v.dtype).str, "shape": list(v.shape)}
    return out
