"""Elastic restore: rebuild state saved under one world/mesh layout onto
another (node-count changes after failures, pod rescale, DP-width change).

Two restore paths live here:

- **mesh-level** (``reshard_tree`` / ``gather_tree``): single-process
  multi-device. Checkpoints gather sharded leaves to host at Plan; restore
  places them onto the restart template's shardings (``tcl.load`` honors
  the template leaf's ``.sharding``) — store on a 4×4 mesh, restart on
  2×8 or 16×1, bit-exact (tests/test_mesh_restart.py).
- **rank-file-level** (``ElasticLoader`` et al., below): multi-process.

Shards are recorded per rank with explicit index metadata (axis-0 chunking —
the DP/ZeRO layout), so a loader for world W2 assembles its slice from any
number of W1 chunk files, reading only overlapping byte ranges via CHK5
partial reads.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.formats import CHK5Reader, CHK5Writer, str_to_dtype


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """Place every leaf of ``tree`` per ``shardings`` (a matching pytree of
    jax ``Sharding``s — e.g. ``repro.dist.sharding.param_shardings`` under
    a target mesh). Works host→mesh and mesh→mesh; this is how a restart
    template declares the layout a checkpoint should restore onto."""
    import jax
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def gather_tree(tree: Any) -> Any:
    """Gather every (possibly sharded) leaf to a host ``np.ndarray`` —
    the bit-exact global view, independent of the mesh it lived on."""
    import jax
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def shard_bounds(n_rows: int, world: int, rank: int) -> Tuple[int, int]:
    """Even axis-0 partition with remainder spread over the first ranks."""
    base, rem = divmod(n_rows, world)
    lo = rank * base + min(rank, rem)
    hi = lo + base + (1 if rank < rem else 0)
    return lo, hi


def save_sharded(path: str, named_global_slices: Dict[str, np.ndarray],
                 offsets: Dict[str, int], global_shapes: Dict[str, List[int]],
                 meta: Optional[Dict[str, Any]] = None) -> None:
    """Write this rank's chunks (+ index metadata) into one CHK5 file."""
    with CHK5Writer(path) as w:
        w.set_attrs("", dict(meta or {}, sharded=True))
        for name, arr in named_global_slices.items():
            w.write_dataset(f"shard/{name}", np.asarray(arr), {
                "row_offset": int(offsets[name]),
                "global_shape": [int(x) for x in global_shapes[name]],
            })


class ElasticLoader:
    """Assemble arbitrary row ranges of the global arrays from chunk files."""

    def __init__(self, files: List[str]):
        self.readers = [CHK5Reader(f) for f in files]
        # name → [(reader, dataset, row_offset, n_rows, row_elems, dtype, gshape)]
        self.chunks: Dict[str, List[tuple]] = {}
        for rd in self.readers:
            for ds in rd.datasets():
                if not ds.startswith("shard/"):
                    continue
                name = ds[len("shard/"):]
                m = rd.info(ds)
                a = m["attrs"]
                gshape = a["global_shape"]
                row_elems = int(np.prod(gshape[1:])) if len(gshape) > 1 else 1
                self.chunks.setdefault(name, []).append(
                    (rd, ds, a["row_offset"], m["shape"][0], row_elems,
                     m["dtype"], gshape))
        for v in self.chunks.values():
            v.sort(key=lambda c: c[2])

    def names(self) -> List[str]:
        return sorted(self.chunks)

    def global_shape(self, name: str) -> List[int]:
        return self.chunks[name][0][6]

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Assemble global rows [lo, hi) of ``name`` from overlapping chunks,
        reading only the overlapping element ranges of each file."""
        parts = []
        cur = lo
        for rd, ds, off, n, row_elems, dtype, gshape in self.chunks[name]:
            c_lo, c_hi = off, off + n
            if c_hi <= cur or c_lo >= hi:
                continue
            take_lo = max(cur, c_lo)
            take_hi = min(hi, c_hi)
            start_elem = (take_lo - c_lo) * row_elems
            arr = rd.read_range(ds, start_elem, (take_hi - take_lo) * row_elems)
            parts.append(arr)
            cur = take_hi
        if cur != hi:
            raise ValueError(
                f"{name}: rows [{lo},{hi}) not fully covered (got to {cur})")
        dt = str_to_dtype(self.chunks[name][0][5])
        flat = np.concatenate([p.view(dt) for p in parts]) if parts else \
            np.zeros(0, dt)
        gshape = self.global_shape(name)
        return flat.reshape([hi - lo] + list(gshape[1:]))

    def read_for_rank(self, name: str, world: int, rank: int) -> np.ndarray:
        g = self.global_shape(name)
        lo, hi = shard_bounds(g[0] if g else 1, world, rank)
        return self.read_rows(name, lo, hi)

    def close(self):
        for r in self.readers:
            r.close()


def elastic_restore(ckpt_dir_path: str, new_world: int, new_rank: int
                    ) -> Dict[str, np.ndarray]:
    """Restore this new rank's slice of every sharded array in a committed
    checkpoint directory (any number of original rank files)."""
    files = [os.path.join(ckpt_dir_path, f) for f in os.listdir(ckpt_dir_path)
             if f.endswith(".chk5") and f.startswith("rank")
             and ".partner" not in f]
    loader = ElasticLoader(sorted(files))
    out = {}
    for name in loader.names():
        out[name] = loader.read_for_rank(name, new_world, new_rank)
    loader.close()
    return out
