"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba (SSD chunked form).

TPU adaptation (DESIGN.md §2/§5): the sequential selective scans are recast
as *chunked* recurrences — chunk-local pairwise matmuls (MXU work) plus a
`lax.scan` over chunks carrying the O(1) state. Decay products are computed
as bounded ratios ``exp(logdecay_t − logdecay_s) ≤ 1`` (s ≤ t, log-decays
non-positive), so no overflow-prone factorization is needed.

- RWKV6: data-dependent **vector** decay w_t ∈ (0,1)^K per head, bonus u for
  the current token, ddlerp token-shift mixing [arXiv:2404.05892].
- Mamba: **scalar**-per-head decay a_t = exp(−Δ_t·A_h) (Mamba-2/SSD algebra
  [arXiv:2405.21060]); short causal conv; gated output norm.

Both expose train-time (B,T,d)→(B,T,d) forms and O(1)-state decode steps.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, groupnorm_heads

Params = Dict[str, Any]

_MIX_LORA = 32
_DECAY_LORA = 64


# =========================================================================== #
# RWKV6
# =========================================================================== #


def init_rwkv6_layer(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    f = cfg.d_ff
    h = d // cfg.ssm.head_dim
    ks = jax.random.split(key, 16)
    p: Params = {
        # ddlerp token-shift: base mus + per-target lora (w,k,v,r,g)
        "mu_base": jnp.zeros((d,), dtype),
        "mu_wkvrg": jnp.zeros((5, d), dtype),
        "lora_A": dense_init(ks[0], d, 5 * _MIX_LORA, dtype),
        "lora_B": (jnp.zeros((5, _MIX_LORA, d))).astype(dtype),
        # decay lora
        "w0": jnp.full((d,), -6.0, dtype),
        "wA": dense_init(ks[1], d, _DECAY_LORA, dtype),
        "wB": jnp.zeros((_DECAY_LORA, d), dtype),
        # projections
        "wr": dense_init(ks[2], d, d, dtype),
        "wk": dense_init(ks[3], d, d, dtype),
        "wv": dense_init(ks[4], d, d, dtype),
        "wg": dense_init(ks[5], d, d, dtype),
        "wo": dense_init(ks[6], d, d, dtype),
        "u": jnp.zeros((d,), dtype),              # per-channel bonus (heads×K)
        # channel mix
        "cm_mu_k": jnp.zeros((d,), dtype),
        "cm_mu_r": jnp.zeros((d,), dtype),
        "cm_wk": dense_init(ks[7], d, f, dtype),
        "cm_wv": dense_init(ks[8], f, d, dtype),
        "cm_wr": dense_init(ks[9], d, d, dtype),
    }
    del h
    return p


def _ddlerp(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent token-shift interpolation → (5, B, T, d) mixed inputs."""
    delta = x_prev - x
    xxx = x + delta * p["mu_base"]
    lora = jnp.tanh(xxx @ p["lora_A"])
    b, t, _ = x.shape
    lora = lora.reshape(b, t, 5, _MIX_LORA)
    dyn = jnp.einsum("btlr,lrd->lbtd", lora, p["lora_B"])
    mix = p["mu_wkvrg"][:, None, None, :] + dyn               # (5,B,T,d)
    return x[None] + delta[None] * mix


def _wkv6_chunked(r, k, v, logw, u, chunk: int):
    """Chunked WKV6. r,k,logw: (B,T,H,K); v: (B,T,H,V); u: (H,K)."""
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    c = min(chunk, t)
    assert t % c == 0
    n = t // c

    def to_chunks(a):  # (B,T,H,...) → (N,B,C,H,...)
        return a.reshape(b, n, c, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    r_, k_, v_, lw_ = map(to_chunks, (r, k, v, logw))
    bsum = jnp.cumsum(lw_, axis=2)                            # inclusive (N,B,C,H,K)
    bprev = bsum - lw_                                        # exclusive

    tri = jnp.tril(jnp.ones((c, c), bool), -1)                # strict lower

    def body(S, inp):
        rc, kc, vc, bs, bp, lwc = inp                         # (B,C,H,*)
        # intra-chunk: A[t,s] = Σ_k r_t k_s exp(bp_t − bs_s), s<t (bounded ≤1)
        ratio = jnp.exp(jnp.clip(bp[:, :, None] - bs[:, None, :], -60.0, 0.0))
        A = jnp.einsum("bthk,bshk,btshk->bhts", rc, kc, ratio)
        A = jnp.where(tri[None, None], A, 0.0)
        # current-token bonus u
        diag = jnp.einsum("bthk,bthk->bth", rc * u[None, None], kc)
        o = jnp.einsum("bhts,bshv->bthv", A, vc)
        o = o + diag[..., None] * vc
        # inter-chunk: r_t ⊙ exp(bp_t) attends the carried state
        o = o + jnp.einsum("bthk,bhkv->bthv", rc * jnp.exp(bp), S)
        # state update: S ← exp(bs_C) S + Σ_s (k_s exp(bs_C − bs_s)) ⊗ v_s
        dec_end = jnp.exp(bs[:, -1])                          # (B,H,K)
        kdec = kc * jnp.exp(jnp.clip(bs[:, -1][:, None] - bs, -60.0, 0.0))
        S = dec_end[..., None] * S + jnp.einsum("bshk,bshv->bhkv", kdec, vc)
        return S, o

    S0 = jnp.zeros((b, h, kk, vv), jnp.float32)
    _, out = jax.lax.scan(body, S0, (r_, k_, v_, bsum, bprev, lw_))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, t, h, vv)   # (B,T,H,V)


def rwkv6_time_mix(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                   x_shift: jnp.ndarray | None = None) -> jnp.ndarray:
    b, t, d = x.shape
    hd = cfg.ssm.head_dim
    h = d // hd
    first = jnp.zeros((b, 1, d), x.dtype) if x_shift is None else x_shift
    x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)

    logw = -jnp.exp(
        (p["w0"].astype(jnp.float32) +
         (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32)))
    r = (xr @ p["wr"]).reshape(b, t, h, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, t, h, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, t, h, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    logw = logw.reshape(b, t, h, hd)
    u = p["u"].astype(jnp.float32).reshape(h, hd)

    o = _wkv6_chunked(r, k, v, logw, u, cfg.ssm.chunk)
    o = groupnorm_heads(o.reshape(b, t, d).astype(x.dtype), h, cfg.norm_eps)
    return (o * g) @ p["wo"]


def rwkv6_channel_mix(p: Params, x: jnp.ndarray,
                      x_shift: jnp.ndarray | None = None) -> jnp.ndarray:
    b, _, d = x.shape
    first = jnp.zeros((b, 1, d), x.dtype) if x_shift is None else x_shift
    x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["cm_mu_k"]
    xr = x + (x_prev - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])


class RWKVState(NamedTuple):
    tm_shift: jnp.ndarray        # (B, 1, d)
    cm_shift: jnp.ndarray        # (B, 1, d)
    wkv: jnp.ndarray             # (B, H, K, V) fp32


def init_rwkv_state(batch: int, cfg: ArchConfig, dtype) -> RWKVState:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    return RWKVState(
        jnp.zeros((batch, 1, d), dtype),
        jnp.zeros((batch, 1, d), dtype),
        jnp.zeros((batch, h, hd, hd), jnp.float32),
    )


def rwkv6_decode_step(p: Params, x: jnp.ndarray, st: RWKVState,
                      cfg: ArchConfig) -> Tuple[jnp.ndarray, RWKVState]:
    """x (B,1,d) → (out_time_mix + channel_mix applied by caller per block)."""
    b, _, d = x.shape
    hd = cfg.ssm.head_dim
    h = d // hd
    xw, xk, xv, xr, xg = _ddlerp(p, x, st.tm_shift)
    logw = -jnp.exp(
        (p["w0"].astype(jnp.float32) +
         (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32)))
    r = (xr @ p["wr"]).reshape(b, 1, h, hd).astype(jnp.float32)[:, 0]
    k = (xk @ p["wk"]).reshape(b, 1, h, hd).astype(jnp.float32)[:, 0]
    v = (xv @ p["wv"]).reshape(b, 1, h, hd).astype(jnp.float32)[:, 0]
    g = jax.nn.silu(xg @ p["wg"])[:, 0]
    w = jnp.exp(logw.reshape(b, h, hd))
    u = p["u"].astype(jnp.float32).reshape(h, hd)

    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    o = jnp.einsum("bhk,bhkv->bhv", r, st.wkv + u[None, ..., None] * kv)
    S = w[..., None] * st.wkv + kv
    o = groupnorm_heads(o.reshape(b, 1, d).astype(x.dtype), h, cfg.norm_eps)
    out = (o * g[:, None]) @ p["wo"]
    return out, RWKVState(x, st.cm_shift, S)


def rwkv6_channel_mix_decode(p: Params, x: jnp.ndarray, st: RWKVState
                             ) -> Tuple[jnp.ndarray, RWKVState]:
    xk = x + (st.cm_shift - x) * p["cm_mu_k"]
    xr = x + (st.cm_shift - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"])
    return out, RWKVState(st.tm_shift, x, st.wkv)


# =========================================================================== #
# Mamba (SSD chunked)
# =========================================================================== #


def init_mamba_layer(key, cfg: ArchConfig, dtype) -> Params:
    """Split projections (z, x, B, C, dt) so z/x column-shard cleanly on the
    model axis; B/C/dt are small and replicated (DESIGN.md §4)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    h = di // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, s.d_state, dtype),
        "w_C": dense_init(ks[3], d, s.d_state, dtype),
        "w_dt": dense_init(ks[4], d, h, dtype),
        "conv_x_w": (jax.random.normal(ks[5], (s.conv_width, di)) * 0.2).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": (jax.random.normal(ks[6], (s.conv_width, 2 * s.d_state)) * 0.2).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * s.d_state,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),     # A = exp(A_log) > 0
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[7], di, d, dtype),
    }


def _ssd_chunked(x, Bm, Cm, loga, chunk: int):
    """x:(B,T,H,P) Bm/Cm:(B,T,N) loga:(B,T,H) ≤0 → y:(B,T,H,P)."""
    b, t, h, pp = x.shape
    nn = Bm.shape[-1]
    c = min(chunk, t)
    assert t % c == 0
    n = t // c

    def to_chunks(a):
        return a.reshape(b, n, c, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    x_, B_, C_, la_ = map(to_chunks, (x, Bm, Cm, loga))
    cs = jnp.cumsum(la_, axis=2)                              # inclusive (N,B,C,H)

    tri = jnp.tril(jnp.ones((c, c), bool))                    # include diagonal

    def body(S, inp):
        xc, bc, cc, ls, lw = inp
        ratio = jnp.exp(jnp.clip(ls[:, :, None] - ls[:, None, :], -60.0, 0.0))
        ratio = jnp.where(tri[None, :, :, None], ratio, 0.0)  # (B,C,C,H)
        M = jnp.einsum("btn,bsn->bts", cc, bc)                # (B,C,C)
        y = jnp.einsum("bts,btsh,bshp->bthp", M, ratio, xc)
        # inter-chunk from carried state
        y = y + jnp.einsum("btn,bhpn,bth->bthp", cc, S, jnp.exp(ls))
        # state update
        dec_end = jnp.exp(ls[:, -1])                          # (B,H)
        xdec = xc * jnp.exp(jnp.clip(ls[:, -1][:, None] - ls, -60.0, 0.0))[..., None]
        S = dec_end[..., None, None] * S + jnp.einsum("bshp,bsn->bhpn", xdec, bc)
        return S, y

    S0 = jnp.zeros((b, h, pp, nn), jnp.float32)
    _, out = jax.lax.scan(body, S0, (x_, B_, C_, cs, la_))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, t, h, pp)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b_: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal short conv; x (B,T,C), w (W,C)."""
    bsz, t, _ = x.shape
    width = w.shape[0]
    pad = jnp.zeros((bsz, width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    return sum(xp[:, i: i + t] * w[i][None, None] for i in range(width)) + b_


def mamba_block(p: Params, u: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """u (B,T,d) → (B,T,d)."""
    s = cfg.ssm
    b, t, _ = u.shape
    di = s.expand * cfg.d_model
    h = di // s.head_dim

    z = u @ p["w_z"]
    xs = u @ p["w_x"]
    bc = jnp.concatenate([u @ p["w_B"], u @ p["w_C"]], axis=-1)
    dt = u @ p["w_dt"]

    xs = jax.nn.silu(_causal_conv(xs, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(_causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"]))
    x = xs.reshape(b, t, h, s.head_dim)
    Bm = bc[..., : s.d_state]
    Cm = bc[..., s.d_state:]

    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    loga = -delta * jnp.exp(p["A_log"])[None, None]
    xin = (x.astype(jnp.float32) * delta[..., None])

    y = _ssd_chunked(xin, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                     loga, s.chunk)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, t, di).astype(u.dtype)
    # gated RMSNorm (Mamba-2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(u.dtype)
    y = y * p["norm_scale"]
    return y @ p["out_proj"]


class MambaState(NamedTuple):
    conv_x: jnp.ndarray          # (B, W-1, d_inner)
    conv_bc: jnp.ndarray         # (B, W-1, 2N)
    ssm: jnp.ndarray             # (B, H, P, N) fp32


def init_mamba_state(batch: int, cfg: ArchConfig, dtype) -> MambaState:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    h = di // s.head_dim
    return MambaState(
        jnp.zeros((batch, s.conv_width - 1, di), dtype),
        jnp.zeros((batch, s.conv_width - 1, 2 * s.d_state), dtype),
        jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    )


def mamba_decode_step(p: Params, u: jnp.ndarray, st: MambaState,
                      cfg: ArchConfig) -> Tuple[jnp.ndarray, MambaState]:
    s = cfg.ssm
    b, _, _ = u.shape
    di = s.expand * cfg.d_model
    h = di // s.head_dim

    z = u @ p["w_z"]
    xs = (u @ p["w_x"])[:, 0]
    bc = jnp.concatenate([u @ p["w_B"], u @ p["w_C"]], axis=-1)[:, 0]
    dt = u @ p["w_dt"]

    win_x = jnp.concatenate([st.conv_x, xs[:, None]], axis=1)   # (B, W, di)
    win_bc = jnp.concatenate([st.conv_bc, bc[:, None]], axis=1)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_x, p["conv_x_w"]) + p["conv_x_b"])
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_bc, p["conv_bc_w"]) + p["conv_bc_b"])
    x = xs.reshape(b, h, s.head_dim)
    Bm = bc[..., : s.d_state]
    Cm = bc[..., s.d_state:]

    delta = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-delta * jnp.exp(p["A_log"])[None])
    xin = x.astype(jnp.float32) * delta[..., None]
    S = a[..., None, None] * st.ssm + jnp.einsum(
        "bhp,bn->bhpn", xin, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(u.dtype)
    y = y * p["norm_scale"]
    return y @ p["out_proj"], MambaState(win_x[:, 1:], win_bc[:, 1:], S)
