"""ft/supervisor: kill/restart policy under a simulated clock, plus the
real multi-process supervised-kill scenario.

The fake world drives Supervisor's injectable clock/wall/sleep/popen so
the startup-grace, stale-heartbeat, backoff-reset, MTTR and MTBF-feed
behaviors are asserted deterministically — no real sleeps, no real
processes — and one subprocess test runs the whole
``launch/train.py --supervise`` path end to end.
"""
import json
import os

import pytest

from repro.chaos.cadence import MTBFFeed
from repro.ft.supervisor import Supervisor, SupervisorConfig

WALL0 = 1000.0  # arbitrary wall-clock origin for the fake world


class FakeProc:
    def __init__(self):
        self.rc = None
        self.killed = False

    def poll(self):
        return self.rc

    def kill(self):
        self.killed = True
        self.rc = -9

    def wait(self):
        return self.rc


class World:
    """Simulated time: sleep() advances the clock and fires scheduled
    events (worker beats, worker exits) as their times pass."""

    def __init__(self, hb_path):
        self.t = 0.0
        self.hb_path = str(hb_path)
        self.events = []  # sorted (t, fn)

    def clock(self):
        return self.t

    def wall(self):
        return WALL0 + self.t

    def sleep(self, d):
        target = self.t + d
        while self.events and self.events[0][0] <= target:
            et, fn = self.events.pop(0)
            self.t = max(self.t, et)
            fn()
        self.t = target

    def at(self, t, fn):
        self.events.append((t, fn))
        self.events.sort(key=lambda e: e[0])

    def beat_at(self, t, step):
        def write():
            with open(self.hb_path, "w") as f:
                f.write(f"{self.wall()} {step}")
        self.at(t, write)

    def exit_at(self, t, proc, rc):
        def die():
            proc.rc = rc
        self.at(t, die)


def make_sup(tmp_path, procs, world, logs, **cfg_kw):
    cfg = SupervisorConfig(heartbeat_path=str(tmp_path / "hb"), **cfg_kw)
    it = iter(procs)
    return Supervisor(
        ["worker"], {}, cfg,
        clock=world.clock, wall=world.wall, sleep=world.sleep,
        popen=lambda cmd, env: next(it), log=logs.append)


def test_startup_grace_kills_beatless_worker(tmp_path):
    """The old inline loop's blind spot: a worker that wedges before its
    first heartbeat now dies at 2x the heartbeat timeout."""
    world = World(tmp_path / "hb")
    procs = [FakeProc(), FakeProc()]
    logs = []
    sup = make_sup(tmp_path, procs, world, logs,
                   heartbeat_timeout_s=10.0, max_restarts=1, poll_s=1.0,
                   backoff_base_s=1.0)
    assert sup.run() == 1                      # both attempts wedge
    assert all(p.killed for p in procs)
    assert sup.gap_kills == 2 and sup.deaths == 2
    assert any("startup grace" in m for m in logs)
    # each kill landed at the grace deadline (2x timeout), not the
    # heartbeat timeout and not never
    assert world.t == pytest.approx(20.0 + 1.0 + 20.0, abs=2.0)
    # hang kills are real failure observations: the estimate moved down
    assert sup.estimator.failures == 2
    assert sup.estimator.estimate() < sup.cfg.prior_mtbf_s


def test_stale_previous_heartbeat_does_not_mask_wedge(tmp_path):
    """A heartbeat file left by the dead predecessor (wall time older than
    this attempt's spawn) must not count as liveness."""
    (tmp_path / "hb").write_text(f"{WALL0 - 50.0} 7")   # stale beat
    world = World(tmp_path / "hb")
    p = FakeProc()
    logs = []
    sup = make_sup(tmp_path, [p], world, logs,
                   heartbeat_timeout_s=10.0, max_restarts=0, poll_s=1.0)
    assert sup.run() == 1
    assert p.killed and sup.gap_kills == 1
    assert world.t == pytest.approx(20.0, abs=2.0)      # grace, not timeout


def test_heartbeat_gap_kills_beating_then_silent_worker(tmp_path):
    world = World(tmp_path / "hb")
    p = FakeProc()
    logs = []
    world.beat_at(1.0, 1)                      # one beat, then silence
    sup = make_sup(tmp_path, [p], world, logs,
                   heartbeat_timeout_s=5.0, max_restarts=0, poll_s=1.0)
    assert sup.run() == 1
    assert p.killed
    assert any("heartbeat timeout" in m for m in logs)
    # killed ~5s after the beat — well before the 10s startup grace
    assert world.t == pytest.approx(6.0, abs=2.0)


def test_backoff_resets_after_sustained_healthy_run(tmp_path):
    """The old inline loop's other blind spot: one early crash must not
    tax every later restart at the doubled price."""
    world = World(tmp_path / "hb")
    p1, p2, p3 = FakeProc(), FakeProc(), FakeProc()
    logs = []
    world.exit_at(2.0, p1, 1)                  # crash 1: fast death
    # worker 2 spawns at ~6 (death + 4s backoff): beats 7..29, dies at 30
    for t in range(7, 30):
        world.beat_at(float(t), t)
    world.exit_at(30.0, p2, 1)
    # worker 3 spawns at ~34: beats, then clean exit
    world.beat_at(35.0, 35)
    world.exit_at(36.0, p3, 0)
    sup = make_sup(tmp_path, [p1, p2, p3], world, logs,
                   heartbeat_timeout_s=10.0, healthy_reset_s=10.0,
                   max_restarts=2, poll_s=1.0, backoff_base_s=4.0)
    assert sup.run() == 0
    delays = [float(m.split("backing off ")[1].split("s")[0])
              for m in logs if "backing off" in m]
    # without the reset the second delay would be 8.0
    assert delays == [4.0, 4.0]
    assert len(sup.mttr_s) == 2                # both deaths recovered from
    assert all(m > 0 for m in sup.mttr_s)


def test_mttr_recorded_and_feed_written(tmp_path):
    world = World(tmp_path / "hb")
    p1, p2 = FakeProc(), FakeProc()
    logs = []
    world.exit_at(1.0, p1, 1)                  # death at t=1
    world.beat_at(4.0, 4)                      # recovery beat at t=4
    world.exit_at(5.0, p2, 0)
    feed_path = str(tmp_path / "feed.json")
    sup = make_sup(tmp_path, [p1, p2], world, logs,
                   heartbeat_timeout_s=10.0, max_restarts=1, poll_s=1.0,
                   backoff_base_s=1.0, mtbf_feed_path=feed_path,
                   prior_mtbf_s=3600.0)
    assert sup.run() == 0
    (mttr,) = sup.mttr_s
    assert mttr == pytest.approx(3.0, abs=1.5)  # death t=1 → beat t=4
    blob = json.loads(open(feed_path).read())
    assert blob["deaths"] == 1 and blob["failures"] == 1
    assert blob["estimate_s"] < 3600.0
    assert blob["mttr_s"] == [round(mttr, 6)]
    # the feed seeds a fresh estimator (what a restarted worker does)
    from repro.chaos.cadence import MTBFEstimator
    est = MTBFEstimator(prior_mtbf_s=3600.0)
    assert MTBFFeed(feed_path).seed(est)
    assert est.estimate() == pytest.approx(blob["estimate_s"], rel=1e-6)


def test_success_without_death_writes_feed_once(tmp_path):
    world = World(tmp_path / "hb")
    p = FakeProc()
    world.beat_at(1.0, 1)
    world.exit_at(2.0, p, 0)
    feed_path = str(tmp_path / "feed.json")
    sup = make_sup(tmp_path, [p], world, [],
                   heartbeat_timeout_s=10.0, mtbf_feed_path=feed_path)
    assert sup.run() == 0
    blob = json.loads(open(feed_path).read())
    assert blob["deaths"] == 0 and blob["failures"] == 0
    assert sup.mttr_s == []                    # nothing to recover from


def test_supervised_kill_scenario_end_to_end(tmp_path):
    """The real thing: launch/train.py --supervise workers, an exit-mode
    chaos spec kills the first child at step 8, the supervisor detects,
    backs off, restarts, and the durable counters keep child 2 alive."""
    from repro.chaos.scenarios import run_scenario
    r = run_scenario("supervised-kill", "fti", str(tmp_path))
    assert r.ok, r.detail
    assert r.detail["resumed_from_step_6"]     # never from step 0
    assert r.detail["exactly_one_restart"] and r.detail["backoff_paced"]
    assert r.detail["feed"]["deaths"] == 1
    assert r.data_loss_bytes == 0 and r.mttr_s > 0
