"""``python -m repro.tools.chkls <file.chk5>`` — inspect CHK5 containers.

The paper's HDF5 argument: checkpoints double as analyzable datasets, with
standard tools. This is that tool for CHK5.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.core.formats import CHK5Reader


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="list CHK5 checkpoint contents")
    ap.add_argument("file")
    ap.add_argument("--verify", action="store_true", help="check all crc32s")
    ap.add_argument("--stats", action="store_true",
                    help="per-dataset min/max/mean for float data")
    args = ap.parse_args(argv)

    rd = CHK5Reader(args.file, verify=args.verify)
    root_attrs = rd.attrs("")
    if root_attrs:
        print(f"attrs: {root_attrs}")
    total = 0
    for name in rd.datasets():
        m = rd.info(name)
        total += m["nbytes"]
        line = (f"  {name:60s} {m['dtype']:>10s} "
                f"{str(tuple(m['shape'])):>20s} {m['nbytes']:>12,d} B")
        if args.stats and m["dtype"] != "bytes":
            try:
                a = rd.read_dataset(name).astype(np.float32)
                if a.size:
                    line += (f"  [{a.min():+.3e}, {a.max():+.3e}]"
                             f" μ={a.mean():+.3e}")
            except (TypeError, ValueError):
                pass
        print(line)
    print(f"{len(rd.datasets())} datasets, {total:,} bytes"
          + ("  (crc OK)" if args.verify else ""))
    rd.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
