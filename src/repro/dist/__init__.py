"""repro.dist — the distributed execution layer.

Three concerns, three modules:

- :mod:`repro.dist.context` — mesh axis conventions (``DATA``/``MODEL``),
  the ``use_mesh`` ambient-mesh context, and the divisibility-aware
  sharding-hint layer (``shard_hint`` / ``shard_decode_kv``) that model
  code calls unconditionally: every hint is a no-op without an active
  mesh, so the same model runs on a laptop CPU and a multi-pod mesh.
- :mod:`repro.dist.sharding` — path-based parameter sharding rules over
  ``param_struct()`` pytrees, batch sharding with pod→data folding, and
  decode-cache shardings.
- :mod:`repro.dist.compression` — int8 gradient compression (per-block
  max-abs scaling) for bandwidth-bound gradient exchange and compressed
  checkpoint payloads.

Checkpointing interaction: shardings live *outside* the checkpoint. The
pipeline's Plan stage snapshots sharded leaves **shard-locally** (one host
buffer per owned shard — never a gathered global array;
``core/resharding.snapshot_shards``), Pack spreads the shards over
parallel ``rank<r>.shard<j>.chk5`` files, and restore assembles exactly
the regions each device of the restart template's mesh needs
(``core/resharding.ElasticLoader`` /
``assemble_onto``) — so a checkpoint written under one mesh restores
under another without the global array ever existing on host.
"""
from repro.dist.context import (  # noqa: F401
    DATA,
    MODEL,
    POD,
    constraint_hints,
    data_axes,
    resolve_spec,
    shard_decode_kv,
    shard_hint,
    use_mesh,
)
from repro.dist.sharding import (  # noqa: F401
    batch_sharding,
    cache_shardings,
    param_shardings,
)
