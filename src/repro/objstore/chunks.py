"""Content-addressed chunk layer — DIFF semantics at the storage layer.

Checkpoint files (CHK5 containers and their sibling shard files) are
split into fixed-size chunks; each chunk is stored under its sha256
(``chunks/<h[:2]>/<h>``), so a chunk that already exists in the store is
never uploaded again.  Consecutive checkpoints of a training run share
almost all of their payload bytes — the container layout is append-only
and deterministic, so an unchanged leaf produces byte-identical chunks
at the same offsets — which makes the second upload a small fraction of
the first (the ``objstore_dedup_ratio`` datapoint CI gates).

Uploads run on a bounded pool of transfer threads
(``StorageConfig.objstore_transfers``, same pattern as
``shard_writers``): :meth:`ChunkUploader.submit_file` returns a
:class:`PendingFile` immediately and the Place stage overlaps the
transfers with the rest of the store tail; ``result()`` joins them.

Content addressing is also the resume story: re-running an interrupted
upload re-splits the file and skips every chunk that already landed —
no partial-object state to reconcile (the client's multipart API exists
for single large objects that are *not* chunked, e.g. future
whole-container mirroring).
"""
from __future__ import annotations

import hashlib
import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.objstore.client import ObjectStore, ObjectStoreError

DEFAULT_CHUNK_BYTES = 1 << 20


def chunk_key(digest: str) -> str:
    return f"chunks/{digest[:2]}/{digest}"


def iter_file_chunks(path: str, chunk_bytes: int
                     ) -> Iterator[Tuple[str, bytes]]:
    """→ (sha256 hex, chunk bytes) for every fixed-size chunk of ``path``."""
    with open(path, "rb") as f:
        while True:
            data = f.read(chunk_bytes)
            if not data:
                break
            yield hashlib.sha256(data).hexdigest(), data


@dataclass
class FileEntry:
    """One file of a catalog entry: its size plus the ordered chunk list
    (digest, nbytes) that reassembles it."""
    name: str
    size: int
    chunks: List[Tuple[str, int]]

    def to_json(self) -> Dict:
        return {"size": self.size,
                "chunks": [[h, n] for h, n in self.chunks]}

    @staticmethod
    def from_json(name: str, d: Dict) -> "FileEntry":
        return FileEntry(name=name, size=int(d["size"]),
                         chunks=[(h, int(n)) for h, n in d["chunks"]])


@dataclass
class PendingFile:
    """An in-flight chunked upload: metadata is final, transfers may not
    be — ``result()`` joins them (raising the first failure).  Holds the
    source file open until then (transfer workers ``pread`` from it, so
    the upload survives the stage dir's commit-time rename; dropping an
    unjoined PendingFile closes the file on GC)."""
    name: str
    size: int
    chunks: List[Tuple[str, int]]
    futures: List[Future] = field(default_factory=list)
    _file: object = None

    def result(self) -> FileEntry:
        try:
            for f in self.futures:
                f.result()
        finally:
            if self._file is not None:
                self._file.close()
                self._file = None
        return FileEntry(self.name, self.size, self.chunks)


class ChunkUploader:
    """Dedup-aware parallel chunk uploads against one object store."""

    def __init__(self, store: ObjectStore,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES, transfers: int = 4):
        self.store = store
        self.chunk_bytes = int(chunk_bytes)
        self.transfers = max(1, int(transfers))
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "chunks_uploaded": 0, "chunks_deduped": 0,
            "bytes_uploaded": 0, "bytes_deduped": 0,
        }

    def _executor(self) -> ThreadPoolExecutor:
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.transfers,
                    thread_name_prefix="objstore-up")
            return self._pool

    def _put_chunk(self, fd: int, offset: int, nbytes: int,
                   digest: str) -> None:
        # re-read in the worker (os.pread — positionless, thread-safe):
        # capturing the chunk bytes in the executor queue would hold the
        # whole un-deduped payload in RAM at once on a first store
        data = os.pread(fd, nbytes, offset)
        self.store.put(chunk_key(digest), data)
        with self._lock:
            self.stats["chunks_uploaded"] += 1
            self.stats["bytes_uploaded"] += nbytes

    def submit_file(self, path: str, name: Optional[str] = None
                    ) -> PendingFile:
        """Split ``path`` and submit every *missing* chunk to the transfer
        pool; chunks already in the store are skipped (dedup).  Returns
        immediately — the caller joins via :meth:`PendingFile.result`."""
        pend = PendingFile(name=name or os.path.basename(path),
                           size=os.path.getsize(path), chunks=[])
        pend._file = open(path, "rb")
        fd = pend._file.fileno()
        ex = self._executor()
        offset = 0
        for digest, data in iter_file_chunks(path, self.chunk_bytes):
            nbytes = len(data)
            pend.chunks.append((digest, nbytes))
            if self.store.exists(chunk_key(digest)):
                with self._lock:
                    self.stats["chunks_deduped"] += 1
                    self.stats["bytes_deduped"] += nbytes
            else:
                pend.futures.append(
                    ex.submit(self._put_chunk, fd, offset, nbytes, digest))
            offset += nbytes
        return pend

    def upload_file(self, path: str, name: Optional[str] = None) -> FileEntry:
        """Synchronous convenience: submit + join."""
        return self.submit_file(path, name).result()


def fetch_file(store: ObjectStore, entry: FileEntry, dest: str) -> None:
    """Reassemble ``entry`` at ``dest``, verifying every chunk's digest
    (a corrupt or truncated chunk fails the fetch, never a silent torn
    file — the staged ``.part`` only replaces ``dest`` when complete)."""
    os.makedirs(os.path.dirname(dest), exist_ok=True)
    tmp = dest + ".part"
    with open(tmp, "wb") as f:
        for digest, nbytes in entry.chunks:
            data = store.get(chunk_key(digest))
            if len(data) != nbytes or \
                    hashlib.sha256(data).hexdigest() != digest:
                raise ObjectStoreError(
                    f"chunk {digest[:12]}… of {entry.name} is corrupt "
                    f"({len(data)} bytes vs recorded {nbytes})")
            f.write(data)
    if os.path.getsize(tmp) != entry.size:
        raise ObjectStoreError(
            f"{entry.name}: reassembled size {os.path.getsize(tmp)} != "
            f"recorded {entry.size}")
    os.replace(tmp, dest)
