"""Checkpoint directory layout, manifests, atomic commit, retention.

Layout (one tree per storage tier)::

    <tier_root>/
      ckpt-<id>/                 (committed — atomic os.replace from .tmp)
        manifest.json            (written last inside .tmp, so a committed
                                  dir always has a complete manifest)
        rank<k>.chk5             per-rank payload (shard index for sharded
                                  stores)
        rank<k>.shard<j>.chk5    shard payload files of a sharded store
        rank<k>.partner<j>.chk5  partner replica of rank j held by rank k (L2)
        rank<k>.partner<j>.shard<s>.chk5  partner replica of a shard file
        parity.group<g>.chk5     erasure parity for node-group g (L3)
      latest                     text file: id of newest committed checkpoint

The object-store tier (repro.objstore) adds two trees outside this
layout: the bucket itself (``<root>/objstore/`` under the default
file: backend — content-addressed ``chunks/``, ``catalog/catalog.json``,
``gc/``) and a node-local restore cache
(``<node-local>/objstore-cache/ckpt-<id>/``) where catalog entries are
materialized back into exactly this per-checkpoint dir shape, manifest
included, so the recovery walk treats them like any committed dir.

Commit protocol (coordinated checkpointing, §4.2.1): every rank writes its
payload into ``ckpt-<id>.tmp``; rank 0 writes the manifest after an
allgather of per-rank status; the .tmp → final rename is the commit point.
Multi-file shard sets stage into the same ``.tmp`` dir and each rank's
status lists its full file set, so the rename commits (or a crash loses)
the set atomically — no partial shard set is ever restorable
(``missing_files`` detects post-commit losses; the restore walk refuses
them).  A checkpoint with a quorum of rank payloads + partner copies
covering the stragglers is still restorable (straggler mitigation —
ft/straggler.py).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional

MANIFEST = "manifest.json"
LATEST = "latest"


def ckpt_dir(root: str, ckpt_id: int, tmp: bool = False) -> str:
    return os.path.join(root, f"ckpt-{ckpt_id}" + (".tmp" if tmp else ""))


def rank_file(root: str, ckpt_id: int, rank: int, tmp: bool = False) -> str:
    return os.path.join(ckpt_dir(root, ckpt_id, tmp), f"rank{rank}.chk5")


def begin(root: str, ckpt_id: int) -> str:
    d = ckpt_dir(root, ckpt_id, tmp=True)
    os.makedirs(d, exist_ok=True)
    return d


def write_manifest(root: str, ckpt_id: int, meta: Dict[str, Any]) -> None:
    d = ckpt_dir(root, ckpt_id, tmp=True)
    meta = dict(meta, id=ckpt_id, wall_time=time.time())
    tmp = os.path.join(d, MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(d, MANIFEST))


def commit(root: str, ckpt_id: int, keep_last: int = 2) -> str:
    """Atomic rename; updates ``latest``; prunes old checkpoints.

    If the destination already exists (coordinated store on a *shared* tier:
    another rank committed first), the commit merges — per-rank files are
    disjoint, so files are moved in and the manifest is refreshed."""
    src = ckpt_dir(root, ckpt_id, tmp=True)
    dst = ckpt_dir(root, ckpt_id)
    if not os.path.exists(os.path.join(src, MANIFEST)):
        raise RuntimeError(f"commit without manifest: {src}")
    if os.path.exists(dst):
        for name in os.listdir(src):
            os.replace(os.path.join(src, name), os.path.join(dst, name))
        shutil.rmtree(src, ignore_errors=True)
    else:
        os.replace(src, dst)
    # durable 'latest' pointer
    tmp = os.path.join(root, LATEST + ".tmp")
    with open(tmp, "w") as f:
        f.write(str(ckpt_id))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, LATEST))
    prune(root, keep_last)
    return dst


def abort(root: str, ckpt_id: int) -> None:
    src = ckpt_dir(root, ckpt_id, tmp=True)
    if os.path.isdir(src):
        shutil.rmtree(src)


def list_committed(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for n in os.listdir(root):
        if n.startswith("ckpt-") and not n.endswith(".tmp"):
            if os.path.exists(os.path.join(root, n, MANIFEST)):
                try:
                    out.append(int(n[5:]))
                except ValueError:
                    pass
    return sorted(out)


def latest_id(root: str) -> Optional[int]:
    """Newest committed id — trusts ``latest`` but falls back to scanning
    (the pointer write could be lost in a crash; the data is still there)."""
    ids = list_committed(root)
    if not ids:
        return None
    p = os.path.join(root, LATEST)
    if os.path.exists(p):
        try:
            cand = int(open(p).read().strip())
            if cand in ids:
                return cand
        except ValueError:
            pass
    return ids[-1]


def read_manifest(root: str, ckpt_id: int) -> Dict[str, Any]:
    with open(os.path.join(ckpt_dir(root, ckpt_id), MANIFEST)) as f:
        return json.load(f)


def try_read_manifest(root: str, ckpt_id: int) -> Optional[Dict[str, Any]]:
    """``read_manifest`` or None — for roots that may not exist yet (the
    objstore cache dir is materialized *during* recovery, so the manifest
    appears only after the catalog tier ran)."""
    try:
        return read_manifest(root, ckpt_id)
    except (OSError, json.JSONDecodeError):
        return None


def manifest_files(meta: Dict[str, Any]) -> List[str]:
    """Every payload file the manifest covers (per-rank containers plus
    their shard files — the multi-file commit surface)."""
    out: List[str] = []
    for st in meta.get("ranks") or []:
        if not st:
            continue
        files = st.get("files")
        if files:
            out.extend(files)
        elif "file" in st:              # pre-shard manifests
            out.append(st["file"])
    return out


def missing_files(root: str, ckpt_id: int) -> List[str]:
    """Manifest-covered files absent from a committed checkpoint dir — a
    non-empty result means the (multi-file) payload set is incomplete and
    the checkpoint must not be treated as restorable."""
    d = ckpt_dir(root, ckpt_id)
    meta = read_manifest(root, ckpt_id)
    return [f for f in manifest_files(meta)
            if not os.path.exists(os.path.join(d, f))]


def prune(root: str, keep_last: int) -> None:
    ids = list_committed(root)
    for i in ids[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(ckpt_dir(root, i), ignore_errors=True)
