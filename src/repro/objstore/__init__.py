"""repro.objstore — content-addressed object-store L4: an S3-shaped
client abstraction, chunk-level dedup uploads, a CAS-guarded checkpoint
catalog, crash-safe retention GC, and the ``ObjectStoreTier`` that
composes them into the checkpoint pipeline's level-4 stack."""
from repro.objstore.catalog import Catalog, CatalogConflictError
from repro.objstore.cdc import CDCParams, Chunker
from repro.objstore.chunks import (
    ChunkCache,
    ChunkStream,
    ChunkUploader,
    FileEntry,
    chunk_key,
    fetch_file_delta,
)
from repro.objstore.client import (
    LocalFSObjectStore,
    MemoryObjectStore,
    ObjectStore,
    ObjectStoreError,
    PreconditionFailed,
    make_object_store,
)
from repro.objstore.gc import collect, retention_split
from repro.objstore.inspect import (
    CatalogView,
    ChunkDelta,
    EntryInfo,
    FileInfo,
)
from repro.objstore.subscriber import CatalogSubscriber, DeploySelector

__all__ = [
    "CDCParams", "Catalog", "CatalogConflictError", "CatalogSubscriber",
    "CatalogView", "ChunkCache", "ChunkDelta", "ChunkStream",
    "ChunkUploader", "Chunker", "DeploySelector", "EntryInfo", "FileEntry",
    "FileInfo", "LocalFSObjectStore", "MemoryObjectStore", "ObjectStore",
    "ObjectStoreError", "PreconditionFailed", "chunk_key", "collect",
    "fetch_file_delta", "make_object_store", "retention_split",
]
