"""Pallas TPU flash attention (forward) — beyond-paper perf feature.

The dry-run shows most full-attention cells are *memory-bound* on score
traffic (§Roofline): blockwise attention writes/reads the (S×S_k) score
matrix through HBM. This fused kernel keeps scores in VMEM with the
standard online-softmax recurrence (FlashAttention [arXiv:2205.14135],
tiled for the MXU): grid (batch·heads, q_blocks, kv_blocks), the kv axis
sequential ("arbitrary"), carrying running max/denominator/accumulator in
VMEM scratch.

Used on TPU via ``REPRO_ATTN_IMPL=flash`` (models/attention.py); validated
here in interpret mode against the jnp oracle. The analytic roofline's
``attn_impl="flash"`` knob models exactly this kernel's traffic: no score
HBM round-trip, streaming K/V reads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,               # (BH, S, dh) — batch·heads flattened
    k: jnp.ndarray,               # (BH, Sk, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, dh = q.shape
    sk = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, sk)
    assert s % bq == 0 and sk % bk == 0, (s, sk, bq, bk)
    scale = 1.0 / np.sqrt(dh)
    grid = (bh, s // bq, sk // bk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention_bshd(q, k, v, *, causal=True, interpret=False,
                         bq=DEFAULT_BQ, bk=DEFAULT_BK):
    """(B, S, H, dh) convenience wrapper (KV already repeated to H heads)."""
    b, s, h, dh = q.shape

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], dh)

    o = flash_attention(flat(q), flat(k), flat(v), causal=causal,
                        interpret=interpret, bq=bq, bk=bk)
    return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
