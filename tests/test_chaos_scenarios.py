"""The chaos scenario matrix: every scenario × every backend, zero loss.

Each cell runs the real store→inject→restart→verify cycle (scenarios.py);
this file asserts the harness contract — bit-exact restores, machine-
readable reports, and zero data loss everywhere — rather than re-testing
the mechanics the scenarios themselves verify.
"""
import json

import pytest

from repro.chaos import inject as chaos
from repro.chaos.scenarios import BACKENDS, SCENARIOS, run_matrix, run_scenario


@pytest.fixture(autouse=True)
def _clean_registry():
    chaos.reset()
    yield
    chaos.reset()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_bit_exact_zero_loss(tmp_path, name, backend):
    r = run_scenario(name, backend, str(tmp_path))
    assert r.ok, f"{name}×{backend}: {r.detail}"
    assert r.data_loss_bytes == 0
    assert r.faults_fired >= 1
    assert r.recovery_path in ("local", "partner", "erasure", "global",
                               "objstore", "elastic")


def test_matrix_report_is_machine_readable(tmp_path):
    report = run_matrix(str(tmp_path), backends=("fti",),
                        names=["corrupt-chunk"])
    blob = json.loads(json.dumps(report))       # JSON round-trip
    assert blob["ok"] and blob["passed"] == blob["total"] == 1
    assert blob["data_loss_bytes"] == 0
    (cell,) = blob["scenarios"]
    for key in ("name", "backend", "ok", "faults_fired", "recovery_path",
                "recovery_s", "data_loss_bytes", "detail"):
        assert key in cell


def test_crashed_scenario_reports_failure_not_raise(tmp_path):
    SCENARIOS["_boom"] = lambda w, b: 1 / 0
    try:
        r = run_scenario("_boom", "fti", str(tmp_path))
        assert not r.ok and "ZeroDivisionError" in r.detail["error"]
    finally:
        del SCENARIOS["_boom"]


def test_runner_cli_writes_report(tmp_path, capsys):
    from repro.chaos.runner import main
    out = tmp_path / "report.json"
    rc = main(["--workdir", str(tmp_path / "w"), "--backend", "fti",
               "--scenario", "node-loss-mid-store", "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] and report["total"] == 1
    assert "PASS" in capsys.readouterr().out
