"""CP-dedicated thread semantics, data-cursor determinism, elastic restore."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_arch
from repro.core.async_engine import CPDedicatedThread
from repro.core.resharding import ElasticLoader, save_sharded, shard_bounds
from repro.data.synthetic import SyntheticDataset, init_data_state, next_batch


# ------------------------------ async engine ------------------------------ #


def test_async_runs_off_thread():
    cp = CPDedicatedThread()
    tid = {}
    cp.submit(1, lambda: tid.setdefault("worker", threading.get_ident()))
    cp.wait()
    assert tid["worker"] != threading.get_ident()
    cp.shutdown()


def test_async_error_surfaces_later_not_at_submit():
    cp = CPDedicatedThread()

    def boom():
        raise IOError("disk full")

    res = cp.submit(1, boom)
    res.done.wait()
    # FTI semantics: the *next* directive surfaces the failure
    with pytest.raises(RuntimeError, match="disk full"):
        cp.check_errors()
    cp.check_errors()          # cleared after surfacing
    cp.shutdown()


def test_async_inflight_fence():
    cp = CPDedicatedThread(max_inflight=1)
    order = []

    def slow(i):
        def f():
            time.sleep(0.05)
            order.append(i)
        return f

    cp.submit(1, slow(1))
    cp.submit(2, slow(2))      # blocks until 1 finishes (fence)
    cp.wait()
    assert order == [1, 2]
    cp.shutdown()


def test_async_shutdown_drains():
    cp = CPDedicatedThread()
    hits = []
    cp.submit(1, lambda: hits.append(1))
    cp.shutdown()
    assert hits == [1]
    with pytest.raises(RuntimeError):
        cp.submit(2, lambda: None)


# ------------------------------ data cursor ------------------------------- #


def test_cursor_restart_resumes_same_stream():
    cfg = get_arch("tinyllama-1.1b").reduced()
    ds = SyntheticDataset(cfg, 2, 16, seed=7)
    first = [next(ds) for _ in range(3)]
    saved = ds.get_state()
    a = next(ds)
    ds2 = SyntheticDataset(cfg, 2, 16, seed=7)
    ds2.set_state(saved)
    b = next(ds2)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), pos=st.integers(0, 20))
def test_cursor_pure_function(seed, pos):
    cfg = get_arch("tinyllama-1.1b").reduced()
    st0 = init_data_state(seed)
    st0 = st0._replace(position=jnp.int32(pos))
    b1, n1 = next_batch(st0, cfg, 2, 16)
    b2, n2 = next_batch(st0, cfg, 2, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert int(n1.position) == pos + 1


def test_vlm_batch_masks_patch_labels():
    cfg = get_arch("internvl2-1b").reduced()
    b, _ = next_batch(init_data_state(0), cfg, 2, 32)
    p = cfg.n_frontend_tokens
    assert b["labels"].shape == (2, 32)
    assert np.all(np.asarray(b["labels"][:, :p]) == -1)
    assert b["tokens"].shape == (2, 32 - p)


# ---------------------------- elastic restore ----------------------------- #


def _write_shards(tmp_path, world, arrays):
    files = []
    for r in range(world):
        named, offs, gshapes = {}, {}, {}
        for name, arr in arrays.items():
            lo, hi = shard_bounds(arr.shape[0], world, r)
            named[name] = arr[lo:hi]
            offs[name] = lo
            gshapes[name] = list(arr.shape)
        p = str(tmp_path / f"rank{r}.chk5")
        save_sharded(p, named, offs, gshapes, {"world": world})
        files.append(p)
    return files


@settings(max_examples=8, deadline=None)
@given(w1=st.integers(1, 6), w2=st.integers(1, 6),
       rows=st.integers(1, 40), seed=st.integers(0, 100))
def test_elastic_restore_any_world_change(tmp_path_factory, w1, w2, rows, seed):
    tmp = tmp_path_factory.mktemp("el")
    rng = np.random.RandomState(seed)
    arrays = {
        "w": rng.randn(rows, 3).astype(np.float32),
        "m": rng.randn(rows).astype(np.float32),
    }
    files = _write_shards(tmp, w1, arrays)
    loader = ElasticLoader(files)
    for name, arr in arrays.items():
        parts = [loader.read_for_rank(name, w2, r) for r in range(w2)]
        got = np.concatenate(parts, axis=0)
        np.testing.assert_array_equal(got, arr)
    loader.close()


def test_elastic_restore_function(tmp_path):
    from repro.core.resharding import elastic_restore
    arrays = {"w": np.arange(24, dtype=np.float32).reshape(12, 2)}
    _write_shards(tmp_path, 4, arrays)
    got = [elastic_restore(str(tmp_path), 3, r)["w"] for r in range(3)]
    np.testing.assert_array_equal(np.concatenate(got), arrays["w"])
