"""Per-arch smoke tests: reduced config, one forward + train step + decode
step on CPU; output shapes + finiteness. (Full configs are exercised only
via the dry-run — ShapeDtypeStruct, no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_arch
from repro.data.synthetic import init_data_state, next_batch
from repro.models.zoo import build_model, make_dummy_batch
from repro.train.optimizer import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ALL_ARCHS:
        cfg = get_arch(name).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        out[name] = (cfg, m, params)
    return out


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_finite(built, name):
    cfg, m, params = built[name]
    batch = make_dummy_batch(cfg, BATCH, SEQ)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_reduces_loss_shape(built, name):
    cfg, m, params = built[name]
    state = init_train_state(params, jax.random.PRNGKey(1), init_data_state())
    step = jax.jit(make_train_step(m, AdamWConfig(total_steps=5,
                                                  warmup_steps=1)))
    batch, _ = next_batch(state.data_state, cfg, BATCH, SEQ)
    s1, metrics = step(state, batch)
    assert int(s1.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_step(built, name):
    cfg, m, params = built[name]
    caches = m.init_caches(BATCH, 64)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, caches2 = jax.jit(
        lambda p, t, c, pos: m.decode_step(p, t, c, pos))(
        params, tok, caches, jnp.int32(3))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache pytree structure is preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_forward_tinyllama(built):
    """Incremental decode logits == teacher-forced forward logits."""
    cfg, m, params = built["tinyllama-1.1b"]
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              cfg.vocab_size, jnp.int32)
    full_logits, _ = m.forward(params, {"tokens": toks})
    caches = m.init_caches(1, 16)
    outs = []
    for i in range(8):
        lg, caches = m.decode_step(params, toks[:, i: i + 1], caches,
                                   jnp.int32(i))
        outs.append(lg)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_rwkv(built):
    cfg, m, params = built["rwkv6-3b"]
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size, jnp.int32)
    full_logits, _ = m.forward(params, {"tokens": toks})
    caches = m.init_caches(1, 16)
    outs = []
    for i in range(8):
        lg, caches = m.decode_step(params, toks[:, i: i + 1], caches,
                                   jnp.int32(i))
        outs.append(lg)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_swa_mask_matches_window(built):
    """Mixtral's SWA: tokens beyond the window are masked out."""
    from repro.models.attention import blockwise_attention
    b, s, h, dh = 1, 64, 2, 8
    k = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh))
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    full = blockwise_attention(q, k, v, causal=True, window=16, q_block=16)
    # reference: dense masked attention
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = (ki <= qi) & (ki > qi - 16)
    scores = jnp.where(mask[None, None], scores, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(full), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
