"""Fault tolerance: injection, detection, elastic rescale, stragglers."""
