"""Training loop with OpenCHK integration — the end-to-end driver core.

The whole CR surface in the loop is exactly the paper's five lines:

    ctx = CheckpointContext(cfg, comm)                 # chk init
    state = ctx.load(state)                            # chk load
    ...
    ctx.store(state, id=step, level=lv, if_=cond)      # chk store
    ctx.shutdown()                                     # chk shutdown

Level cycling follows FTI practice: frequent cheap L1, periodic L2/L3,
rare L4 (PFS). Heartbeats feed the launcher's failure detector.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax

from repro.chaos import inject as chaos
from repro.chaos.cadence import CadenceController
from repro.core.context import CHK_FULL, CheckpointContext
from repro.data.synthetic import next_batch
from repro.ft.detector import Heartbeat
from repro.ft.failures import FaultInjector, SimulatedFault
from repro.models.zoo import Model
from repro.telemetry import trace as ttrace
from repro.train.state import TrainState


@dataclass
class LevelSchedule:
    """FTI-style level cycle: which level for the k-th checkpoint."""
    l1_every: int = 1
    l2_every: int = 2
    l3_every: int = 4
    l4_every: int = 8

    def level_for(self, ckpt_index: int) -> int:
        if self.l4_every and ckpt_index % self.l4_every == 0:
            return 4
        if self.l3_every and ckpt_index % self.l3_every == 0:
            return 3
        if self.l2_every and ckpt_index % self.l2_every == 0:
            return 2
        return 1


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 10
    kind: str = CHK_FULL            # CHK_DIFF → differential checkpoints
    levels: LevelSchedule = field(default_factory=LevelSchedule)
    heartbeat_path: Optional[str] = None
    log_every: int = 10
    #: Daly-optimal adaptive cadence (chaos/cadence.py).  When set, the
    #: fixed ckpt_every/LevelSchedule cycle is replaced by wall-time
    #: intervals the controller derives per tier from measured store cost
    #: and its online MTBF estimate — L1 stays frequent (tiny delta), L4
    #: tracks the Daly optimum.
    cadence: Optional[CadenceController] = None
    #: treat a silent gap longer than this between observed steps as a
    #: failure in the cadence controller's MTBF estimator (wired from the
    #: launcher's --heartbeat-timeout so worker and supervisor agree on
    #: what "hung" means)
    gap_failure_s: Optional[float] = None


def run_training(
    model: Model,
    train_step: Callable,
    state: TrainState,
    ckpt: CheckpointContext,
    loop: LoopConfig,
    global_batch: int,
    seq_len: int,
    injector: Optional[FaultInjector] = None,
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Run (or resume) training to total_steps. Returns summary metrics."""
    hb = Heartbeat(loop.heartbeat_path) if loop.heartbeat_path else None
    jit_step = jax.jit(train_step) if not hasattr(train_step, "lower") else train_step

    cadence = loop.cadence
    if cadence is not None:
        ckpt.observe_store_reports(cadence.note_report)  # store-cost feed
        if loop.gap_failure_s is not None and \
                cadence.mtbf.gap_failure_s is None:
            cadence.mtbf.gap_failure_s = loop.gap_failure_s

    # ---- chk load: transparent restart ---------------------------------- #
    t_load = time.time()
    with ttrace.span("train.load"):
        state = ckpt.load(state)
    start = int(state.step)
    if ckpt.restarted:
        # the resume marker chktrace pairs with the chaos.fault instant:
        # fault → death → restart → THIS event is the recovery narrative
        ttrace.instant("train.resume", step=start)
        log(f"[openchk] restart detected → resuming from step {start}")
        if cadence is not None:
            # a restart is a failure observation plus a recovery-cost sample
            cadence.note_failure()
            cadence.note_recovery(4, time.time() - t_load)

    t0 = time.time()
    metrics: Dict[str, Any] = {}
    n_ckpts = 0
    batch_fn = jax.jit(lambda ds: next_batch(ds, model.cfg, global_batch, seq_len))

    for step in range(start, loop.total_steps):
        batch, next_ds = batch_fn(state.data_state)
        state, metrics = jit_step(state, batch)
        state = state._replace(data_state=next_ds)   # exactly-once cursor

        if injector is not None:
            injector.maybe_fail(step + 1)
        # chaos site: scheduled/probabilistic/repeating step faults armed
        # via OPENCHK_CHAOS (generalizes the one-fault-at-90% injector)
        chaos.fire(chaos.SITES.TRAIN_STEP, exc=SimulatedFault, step=step + 1)

        # ---- chk store with if_/id/level/kind clauses ------------------- #
        if cadence is not None:
            cadence.note_step()
            cadence.ingest_chaos_history()
            due = cadence.due_levels(kind=loop.kind)
            is_ckpt = bool(due)
            if is_ckpt:
                n_ckpts += 1
                level = due[0]           # strongest due tier (stacks nest)
                cadence.mark_stored(level)
            else:
                level = 1
        else:
            is_ckpt = (step + 1) % loop.ckpt_every == 0
            if is_ckpt:
                n_ckpts += 1
            level = loop.levels.level_for(n_ckpts)
        ckpt.store(
            state,
            id=step + 1,
            level=level,
            kind=loop.kind,
            if_=is_ckpt,
        )

        if hb is not None:
            hb.beat(step + 1)
        if (step + 1) % loop.log_every == 0:
            log(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                f"({(time.time() - t0):.1f}s)")

    ckpt.wait()
    summary = {
        "final_step": loop.total_steps,
        "loss": float(metrics.get("loss", float("nan"))),
        "seconds": time.time() - t0,
        "restarted": ckpt.restarted,
        "stats": dict(ckpt.stats),
        "state": state,
    }
    if cadence is not None:
        summary["cadence"] = cadence.datapoints()
    return summary
