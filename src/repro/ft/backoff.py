"""Shared exponential backoff — the retry policy proven in serve/deploy.

One formula, used by the deployer's pinned-replica retries and the
launcher's restart loop: delay for the N-th consecutive failure is
``base * 2**(N-1)`` capped at ``max_s``. Kept as both a pure function
(:func:`backoff_delay`, for callers that track their own failure count)
and a small stateful helper (:class:`ExponentialBackoff`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


def backoff_delay(failures: int, base_s: float = 1.0,
                  max_s: float = 30.0) -> float:
    """Delay after the *failures*-th consecutive failure (1-based)."""
    if failures <= 0:
        return 0.0
    return min(base_s * (2 ** (failures - 1)), max_s)


@dataclass
class ExponentialBackoff:
    """Counts consecutive failures; ``failed()`` returns the next delay."""

    base_s: float = 1.0
    max_s: float = 30.0
    failures: int = field(default=0, init=False)

    def failed(self) -> float:
        self.failures += 1
        return self.delay()

    def delay(self) -> float:
        return backoff_delay(self.failures, self.base_s, self.max_s)

    def reset(self) -> None:
        self.failures = 0

    def note_healthy_span(self, span_s: float, reset_after_s: float) -> bool:
        """Forget past failures once the caller has stayed healthy for
        *span_s* >= *reset_after_s* — one early crash must not tax every
        later restart at full exponential price.  Returns True iff the
        counter was actually reset."""
        if self.failures and span_s >= reset_after_s:
            self.reset()
            return True
        return False

    def sleep_after_failure(self, sleep_fn=time.sleep) -> float:
        d = self.failed()
        if d > 0:
            sleep_fn(d)
        return d
