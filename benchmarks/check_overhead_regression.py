"""CI gate: store-path overhead must not regress vs BENCH_overhead.json.

Runs benchmarks/bench_overhead.py (fault + restart, all three backends)
and compares the measured ``overhead_ratio_*`` (OpenCHK / native wall
time, same host, same run — the noise-robust store-path metric) against
the committed baseline. Fails on a >25 % slowdown of any ratio; ratios at
or under the absolute noise floor never fail. Writes the fresh numbers as
a JSON artifact so CI uploads them per run.

Also gates the compressed-store datapoint (``Protect(compress="int8")``):

- ``compress_ratio_int8`` — int8/uncompressed payload bytes.  Nearly
  deterministic (codec math, not wall time), so the ceiling is hard: the
  tier must actually shrink the payload ~4x.
- ``compress_store_overhead_int8`` — compressed/uncompressed store wall
  time (the quantize + roundtrip-verify cost against a 4x smaller
  write).  Noise-gated like the overhead ratios, with its own floor.

And the objstore datapoints:

- ``objstore_dedup_ratio`` — the bytes a second store after a small
  param delta uploads, relative to the first; hard-gated at 0.30 since
  chunk dedup is byte-deterministic.
- ``objstore_shift_dedup_vs_fixed`` — CDC vs fixed-size re-upload bytes
  after a 1 KiB mid-payload insert; hard-gated at 0.30 (byte-
  deterministic: content-defined cuts must re-synchronize where fixed
  offsets shift everything).
- ``serve_swap_delta_ratio`` / ``serve_swap_delta_predicted`` — the
  bytes a warm serving replica pulls to hot-swap to a fine-tune
  successor (measured through ``EntryPuller``) and the catalog-level
  ``CatalogView.diff`` prediction; both hard-gated at 0.30 (byte-
  deterministic — a deploy is a chunk delta, never a full re-download).
- ``objstore_goodput_bps`` — payload bytes over first-store wall time on
  the fused Pack → chunk-stream path.  Must be present (the fused path
  is this repo's zero-stall claim) and must not fall below the committed
  baseline divided by ``GOODPUT_REGRESSION`` (wider than the generic
  ratio threshold — goodput is an absolute-seconds datapoint and eats
  the box's full wall-clock noise).

- ``telemetry_overhead_ratio`` — traced/untraced wall time of the same
  L4 store (span recorder + metrics registry live vs the disabled no-op
  fast path); hard-gated at 1.05 — observability must never cost real
  store time.

And the chaos recovery datapoints (node-loss-mid-store, best-of-N):

- ``chaos_mttr_s`` — wall time from node death to a verified bit-exact
  partner restore.  Absolute seconds like goodput, so it only fails
  above a 5 s floor AND a wide multiple of the committed baseline.
- ``chaos_data_loss_bytes`` — hard-gated at exactly 0: a fault may cost
  recovery time, never checkpoint data.

And the sharded-store datapoint (forced-16-device mesh, 64 MiB leaf):
``sharded_store_s`` (shard-local Plan snapshot + parallel shard-file
writes) must not exceed ``gathered_store_s`` (full-tree gather) — the
no-gather path moves the same bytes while skipping the global host
buffer, so measuring slower than the gather means the store path
regressed (it currently runs ~2x faster; the gate allows the margin to
shrink to parity before failing).

Update BENCH_overhead.json in the same PR when the pipeline legitimately
changes.

Usage:
  PYTHONPATH=src:. python benchmarks/check_overhead_regression.py \
      --baseline BENCH_overhead.json --out bench-overhead.json
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks import bench_overhead

# ratios this close to native are within the paper's envelope regardless
# of what the baseline measured — don't fail on noise around 1.0
ABS_FLOOR = 1.15
# int8 payload must stay ~4x smaller; anything above this means the codec
# stopped engaging (bytes are deterministic — no noise allowance needed)
COMPRESS_RATIO_CEILING = 0.30
# the second objstore store after a small param delta must upload <30%
# of the first store's bytes — content-addressed dedup is byte-
# deterministic (unchanged chunks hash identically), so the gate is hard:
# above it, the chunk layer stopped deduping (layout no longer stable, or
# the exists-check broke)
OBJSTORE_DEDUP_CEILING = 0.30
# CDC must beat a fixed-size chunker by >3x on the boundary-shift store
# (byte-deterministic: same payloads, same seeded insert every run)
SHIFT_DEDUP_CEILING = 0.30
# a rolling hot-swap deploy (serve_swap_delta) must pull <30% of the
# full weight bytes when moving a warm replica to a fine-tune successor —
# byte-deterministic like the dedup gates: above the ceiling, either the
# replica ChunkCache stopped hitting or the catalog delta grew (chunk
# layout unstable between publishes)
SERVE_SWAP_DELTA_CEILING = 0.30
# the veloc overhead ratio runs at/under parity with the fused streaming
# store path; it gets a hard parity ceiling instead of the generic noise
# floor — the committed baseline itself must sit at <= 1.0
VELOC_RATIO_CEILING = 1.0
# compressed stores pay quantize+verify CPU against a 4x smaller write;
# the ratio's denominator (a fast uncompressed store) is noisy, so below
# this wall-time ratio the datapoint never fails — the gate exists to
# catch pathological regressions (accidental double-verify, device
# round-trips in Pack), not scheduler noise.  Tightened from 4.0 after
# the vectorized quantize pass + f32 roundtrip-error landed (measured
# ~1.5; 2.5 leaves scheduler headroom without readmitting the old cost)
COMPRESS_OVERHEAD_FLOOR = 2.5
# the cadence controller's L4 interval vs the closed-form Daly optimum —
# deterministic (synthetic failures at exact MTBF spacing), so the band
# is hard: outside ±10%, the MTBF estimator or the interval math broke
CADENCE_INTERVAL_BAND = (0.90, 1.10)
# checkpoint_efficiency is deterministic too, but the platform point may
# legitimately move when the cadence model changes — floor it against the
# committed baseline with a small absolute slack instead of a hard value
CADENCE_EFFICIENCY_SLACK = 0.05
# the telemetry plane (span recorder + metrics registry) must be free at
# store granularity: traced/untraced wall-time ratio of the same 16 MiB
# L4 store (interleaved repeats, ratio of mins).  Hard ceiling — above
# 5% the plane is costing real store time and the "observability is
# always on-able" contract is broken
TELEMETRY_OVERHEAD_CEILING = 1.05
# goodput is payload bytes over objstore store wall time — a single
# absolute-seconds measurement, so it inherits the full +/-50% wall-clock
# noise of this box (the ratio gates cancel that noise; goodput can't).
# The committed baseline is a best-of-N snapshot, so the floor divisor is
# wider than the generic ratio threshold: fail only when goodput drops
# below baseline/1.9 — past every noise trough observed while calibrating
# (2.0-2.8e7 B/s against a 2.8e7 baseline), while a real extra pass over
# the bytes (the pre-fused path cost ~2x) still trips it
GOODPUT_REGRESSION = 1.9
# chaos MTTR (node death → verified bit-exact partner restore) is an
# absolute-seconds measurement like goodput: sub-second restores never
# fail (the floor), and above the floor the gate allows a wide multiple
# of the committed best-of-N baseline before declaring the recovery path
# regressed
CHAOS_MTTR_ABS_FLOOR = 5.0
CHAOS_MTTR_REGRESSION = 3.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_overhead.json")
    ap.add_argument("--out", default=None, help="write fresh results here")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max allowed ratio-vs-baseline slowdown factor")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)["results"]
    res = bench_overhead.run(repeats=args.repeats)
    print(json.dumps(res, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "bench_overhead (CI run)",
                       "baseline": args.baseline, "results": res}, f, indent=1)

    failures = []
    # the veloc baseline must itself satisfy the parity ceiling — a PR
    # that regresses the ratio cannot "fix" CI by committing a worse
    # baseline (deterministic check, no fresh measurement involved)
    base_veloc = base.get("overhead_ratio_veloc")
    if base_veloc is not None and base_veloc > VELOC_RATIO_CEILING:
        failures.append(f"baseline overhead_ratio_veloc: {base_veloc:.3f} "
                        f"> {VELOC_RATIO_CEILING} (committed baseline "
                        f"must sit at or under parity)")
    for key, got in sorted(res.items()):
        if not key.startswith("overhead_ratio_"):
            continue
        ref = base.get(key)
        if ref is None:
            continue
        if key == "overhead_ratio_veloc":
            # parity ceiling: the ref is NOT floored to 1.0 — the fused
            # store path holds veloc at/under native, and a measured
            # ratio above parity AND above the noise-threshold multiple
            # of the (sub-1.0) baseline is a real regression
            if got > VELOC_RATIO_CEILING and got > ref * args.threshold:
                failures.append(f"{key}: {got:.3f} vs baseline {ref:.3f} "
                                f"(> {VELOC_RATIO_CEILING} parity ceiling "
                                f"and > {args.threshold:.2f}x baseline)")
            continue
        # a baseline that got a lucky fast run (ratio < 1) must not
        # tighten the gate below "25% worse than parity": ±50% run-to-run
        # noise on shared runners would then fail an unchanged store path
        ref = max(ref, 1.0)
        if got > ABS_FLOOR and got > ref * args.threshold:
            failures.append(f"{key}: {got:.3f} vs baseline {ref:.3f} "
                            f"(> {args.threshold:.2f}x)")

    # compressed-store datapoint: hard byte ceiling + noise-gated wall time
    ratio = res.get("compress_ratio_int8")
    if ratio is not None and ratio > COMPRESS_RATIO_CEILING:
        failures.append(f"compress_ratio_int8: {ratio:.3f} > "
                        f"{COMPRESS_RATIO_CEILING} (codec not engaging)")
    ovh = res.get("compress_store_overhead_int8")
    ref = max(base.get("compress_store_overhead_int8", 1.0), 1.0)
    if (ovh is not None and ovh > COMPRESS_OVERHEAD_FLOOR
            and ovh > ref * args.threshold):
        failures.append(f"compress_store_overhead_int8: {ovh:.3f} vs "
                        f"baseline {ref:.3f} (> {args.threshold:.2f}x)")

    # objstore datapoint: hard dedup ceiling (byte-deterministic)
    ded = res.get("objstore_dedup_ratio")
    if ded is not None and ded > OBJSTORE_DEDUP_CEILING:
        failures.append(f"objstore_dedup_ratio: {ded:.3f} > "
                        f"{OBJSTORE_DEDUP_CEILING} (chunk dedup not "
                        f"engaging on the second store)")

    # boundary-shift datapoint: CDC cuts must re-synchronize after an
    # insert (byte-deterministic — seeded payloads, fixed insert point)
    shift = res.get("objstore_shift_dedup_vs_fixed")
    if shift is not None and shift > SHIFT_DEDUP_CEILING:
        failures.append(f"objstore_shift_dedup_vs_fixed: {shift:.3f} > "
                        f"{SHIFT_DEDUP_CEILING} (content-defined chunking "
                        f"not re-syncing after a boundary shift)")

    # serve hot-swap datapoint: hard delta ceiling (byte-deterministic) —
    # both the measured pull and the catalog-level prediction must agree
    # that a fine-tune deploy moves only the changed chunks
    for key in ("serve_swap_delta_ratio", "serve_swap_delta_predicted"):
        swp = res.get(key)
        if swp is not None and swp > SERVE_SWAP_DELTA_CEILING:
            failures.append(f"{key}: {swp:.3f} > "
                            f"{SERVE_SWAP_DELTA_CEILING} (hot-swap deploy "
                            f"no longer chunk-delta — pulling full weights)")

    # telemetry datapoint: tracing+metrics must stay free on the store
    # path (hard ceiling — the interleaved min-of-N ratio sheds noise)
    tel = res.get("telemetry_overhead_ratio")
    if tel is not None and tel > TELEMETRY_OVERHEAD_CEILING:
        failures.append(f"telemetry_overhead_ratio: {tel:.3f} > "
                        f"{TELEMETRY_OVERHEAD_CEILING} (tracing/metrics "
                        f"plane costing real store time)")

    # goodput datapoint: the fused Pack → upload path must exist and must
    # not fall more than the noise threshold below the baseline
    gp = res.get("objstore_goodput_bps")
    gp_ref = base.get("objstore_goodput_bps")
    if gp_ref is not None and gp is None:
        failures.append("objstore_goodput_bps: missing from results "
                        "(baseline has it — the fused store path "
                        "datapoint was dropped)")
    elif gp is not None and gp_ref is not None and \
            gp < gp_ref / GOODPUT_REGRESSION:
        failures.append(f"objstore_goodput_bps: {gp:.3e} < baseline "
                        f"{gp_ref:.3e} / {GOODPUT_REGRESSION:.2f} "
                        f"(store-path goodput regressed)")

    # cadence datapoints: the controller must track the closed-form Daly
    # optimum (hard band — deterministic inputs) and the efficiency at
    # its schedule must not fall below the committed baseline
    civ = res.get("cadence_interval_vs_optimum")
    if civ is not None and not (
            CADENCE_INTERVAL_BAND[0] <= civ <= CADENCE_INTERVAL_BAND[1]):
        failures.append(f"cadence_interval_vs_optimum: {civ:.3f} outside "
                        f"{CADENCE_INTERVAL_BAND} (controller no longer "
                        f"tracking the Daly optimum)")
    eff = res.get("checkpoint_efficiency")
    eff_ref = base.get("checkpoint_efficiency")
    if eff_ref is not None and eff is None:
        failures.append("checkpoint_efficiency: missing from results "
                        "(baseline has it — the cadence datapoint was "
                        "dropped)")
    elif eff is not None and eff_ref is not None and \
            eff < eff_ref - CADENCE_EFFICIENCY_SLACK:
        failures.append(f"checkpoint_efficiency: {eff:.4f} < baseline "
                        f"{eff_ref:.4f} - {CADENCE_EFFICIENCY_SLACK} "
                        f"(cadence efficiency regressed)")

    # chaos recovery datapoints: MTTR floored + wide-multiple gated, and
    # the zero-loss invariant is hard (a fault may cost time, never data)
    cm = res.get("chaos_mttr_s")
    cm_ref = base.get("chaos_mttr_s")
    if cm_ref is not None and cm is None:
        failures.append("chaos_mttr_s: missing from results (baseline has "
                        "it — the compound-fault recovery datapoint was "
                        "dropped)")
    elif cm is not None and cm > CHAOS_MTTR_ABS_FLOOR and (
            cm_ref is None or cm > cm_ref * CHAOS_MTTR_REGRESSION):
        failures.append(f"chaos_mttr_s: {cm:.3f}s > "
                        f"max({CHAOS_MTTR_ABS_FLOOR}s floor, baseline "
                        f"{cm_ref} x {CHAOS_MTTR_REGRESSION}) "
                        f"(fault recovery path regressed)")
    cl = res.get("chaos_data_loss_bytes")
    if cl is not None and cl != 0:
        failures.append(f"chaos_data_loss_bytes: {cl} != 0 (a chaos "
                        f"scenario lost checkpoint data)")

    # sharded-store datapoint: the shard-local path must not lose to the
    # gathered path (it currently wins ~2x — parity is the hard floor)
    sh, ga = res.get("sharded_store_s"), res.get("gathered_store_s")
    if sh is not None and ga is not None and sh > ga:
        failures.append(f"sharded_store_s: {sh:.3f} > gathered_store_s "
                        f"{ga:.3f} (shard-local store path regressed)")
    if failures:
        print("store-path regression:\n" + "\n".join(failures),
              file=sys.stderr)
        return 1
    print("store-path overhead within budget vs", args.baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
