"""Straggler mitigation for coordinated checkpoints.

Two mechanisms (DESIGN.md §4):

1. **CP-dedicated threads** (core/async_engine.py) keep slow I/O off the
   step path entirely — a slow disk delays the *next* checkpoint, not the
   training step.
2. **Quorum commit**: an L2 checkpoint is restorable when, for every rank,
   either its own payload or its partner's replica exists. The commit
   validator below implements that rule, so a straggler (or dead) writer
   does not block the commit — its partner's copy covers it.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core import manifest as mf
from repro.redundancy.groups import Topology

_SHARD_RE = re.compile(r"^rank(\d+)\.shard(\d+)\.chk5$")
_PARTNER_SHARD_RE = re.compile(r"^rank(\d+)\.partner(\d+)\.shard(\d+)\.chk5$")


@dataclass
class QuorumReport:
    restorable: bool
    present: List[int]
    covered_by_partner: List[int]
    lost: List[int]
    #: (rank, shard) pairs whose own shard file is gone but whose partner
    #: replica covers them — PR 4's multi-file shard sets enter the quorum
    #: rule piecewise, not just the main container
    shards_covered: List[Tuple[int, int]] = field(default_factory=list)


def _shard_inventory(ckpt_dir_path: str
                     ) -> Tuple[Dict[int, Set[int]], Dict[int, Set[int]]]:
    """(own, partner) maps: rank → shard indices present for it.

    Partner shard replicas are discovered by name (any holder counts —
    the file's existence is the coverage, whoever stored it)."""
    own: Dict[int, Set[int]] = {}
    partner: Dict[int, Set[int]] = {}
    try:
        names = os.listdir(ckpt_dir_path)
    except OSError:
        return own, partner
    for name in names:
        m = _SHARD_RE.match(name)
        if m:
            own.setdefault(int(m.group(1)), set()).add(int(m.group(2)))
            continue
        m = _PARTNER_SHARD_RE.match(name)
        if m:
            partner.setdefault(int(m.group(2)), set()).add(int(m.group(3)))
    return own, partner


def validate_quorum(ckpt_dir_path: str, topo: Topology) -> QuorumReport:
    """Is this (possibly incomplete) checkpoint restorable for all ranks?

    A rank is restorable when its container AND every shard file of its
    set is present either as the rank's own write or as a partner
    replica.  The expected shard set is the union of what the rank wrote
    and what its partner holds for it — a shard lost on the straggler's
    disk is covered by ``rank<h>.partner<r>.shard<j>.chk5``."""
    present, covered, lost = [], [], []
    shards_covered: List[Tuple[int, int]] = []
    own_shards, partner_shards = _shard_inventory(ckpt_dir_path)
    for r in range(topo.world):
        own = os.path.join(ckpt_dir_path, f"rank{r}.chk5")
        holder = topo.partner_of(r)
        rep = os.path.join(ckpt_dir_path, f"rank{holder}.partner{r}.chk5")
        container_own = os.path.exists(own)
        container_covered = os.path.exists(rep)
        if not container_own and not container_covered:
            lost.append(r)
            continue
        mine = own_shards.get(r, set())
        held = partner_shards.get(r, set())
        # contiguity check: shard files are numbered 0..n-1, so a hole in
        # the union (own ∪ partner) is a shard nobody holds — lost
        expected = range(len(mine | held))
        if any(j not in mine and j not in held for j in expected):
            lost.append(r)
        elif container_own and not (held - mine):
            present.append(r)
        else:
            covered.append(r)
            shards_covered.extend((r, j) for j in sorted(held - mine))
    return QuorumReport(not lost, present, covered, lost, shards_covered)


def commit_if_quorum(root: str, ckpt_id: int, topo: Topology,
                     extra_meta: Optional[dict] = None) -> bool:
    """Commit a .tmp checkpoint when the quorum rule holds (straggler-safe
    commit path used by the training loop's watchdog)."""
    d = mf.ckpt_dir(root, ckpt_id, tmp=True)
    if not os.path.isdir(d):
        return False
    rep = validate_quorum(d, topo)
    if not rep.restorable:
        return False
    mf.write_manifest(root, ckpt_id, dict(
        extra_meta or {}, kind="FULL", level=2, world=topo.world,
        quorum={"present": rep.present, "partner": rep.covered_by_partner}))
    mf.commit(root, ckpt_id)
    return True
