"""OpenCHK core: the paper's directive model as a JAX checkpoint API."""
