"""Fig. 12 analogue: wall-time overhead of OpenCHK vs native backends.

Methodology reproduced from §6.1: first run with a fault injected at 90 %
progress, then restart to completion; time the whole process. Ratio
OpenCHK/native should be ≈1 (paper: within noise, <2 % worst case).
"""
from __future__ import annotations

import shutil
import time
from typing import Dict

from benchmarks.apps import heat2d_fti, heat2d_openchk, heat2d_scr, heat2d_veloc
from repro.ft.failures import FaultInjector, SimulatedFault

STEPS = 200
N = 768             # 2.25 MB grid → checkpoint I/O is non-trivial
EVERY = 20          # 10 checkpoints per run, like the paper's 1/minute × 10


def timed_run_with_fault(mod, ckpt_dir, backend=None) -> float:
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    # warm the jit cache so compile time isn't charged to the first variant
    from benchmarks.apps.heat2d_common import heat_step, init_grid
    heat_step(init_grid(N)).block_until_ready()
    t0 = time.time()
    inj = FaultInjector(total_steps=STEPS, at_progress=0.9)
    try:
        mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                injector=inj, backend=backend)
    except SimulatedFault:
        # a real abort kills the CP thread with the process; the in-process
        # simulation must drain it so the restart doesn't race an orphan
        from repro.core.async_engine import drain_all
        drain_all()
    out = mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                  backend=backend)
    assert out["restarted"], "restart did not engage"
    dt = time.time() - t0
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    return dt


def compressed_store(repeats: int = 3) -> Dict[str, float]:
    """Compressed-store datapoint: payload ratio and store-path overhead
    of an int8-compressed FULL store (Pack-side Int8CompressTier,
    ``Protect(compress="int8")``) vs an uncompressed FULL store of the
    same state.  Synchronous fti so the Pack tail is inside the timing.

    The byte ratio is deterministic (~0.25 + scale/index overhead); the
    time ratio pays the quantize+roundtrip-verify cost against a 4x
    smaller write — CI gates both (check_overhead_regression.py)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext, Protect

    n = 1 << 22                      # 16 MiB of f32 payload
    rng = np.random.default_rng(0)
    state = {"params": {"w": jnp.asarray(rng.normal(size=n)
                                         .astype(np.float32))}}
    best: Dict[str, tuple] = {}
    variants = {"full": [Protect("params/**")],
                "int8": [Protect("params/**", compress="int8")]}
    for tag, protects in variants.items():
        times, nbytes = [], 0
        for r in range(repeats):
            d = f"/tmp/bo-compress-{tag}"
            shutil.rmtree(d, ignore_errors=True)
            ctx = CheckpointContext(CheckpointConfig(
                dir=d, backend="fti", dedicated_thread=False))
            ctx.protect(*protects)
            t0 = time.time()
            rep = ctx.store(state, id=1, level=1)
            times.append(time.time() - t0)
            nbytes = rep.bytes_payload
            ctx.shutdown()
            shutil.rmtree(d, ignore_errors=True)
        best[tag] = (min(times), nbytes)
    return {
        "compress_full_store_s": best["full"][0],
        "compress_int8_store_s": best["int8"][0],
        "compress_ratio_int8": best["int8"][1] / best["full"][1],
        "compress_store_overhead_int8": best["int8"][0] / best["full"][0],
    }


def run(repeats: int = 3) -> Dict[str, float]:
    natives = {"fti": heat2d_fti, "scr": heat2d_scr, "veloc": heat2d_veloc}
    out: Dict[str, float] = {}
    for backend, native_mod in natives.items():
        t_native = min(timed_run_with_fault(
            native_mod, f"/tmp/bo-native-{backend}") for _ in range(repeats))
        t_openchk = min(timed_run_with_fault(
            heat2d_openchk, f"/tmp/bo-openchk-{backend}", backend=backend)
            for _ in range(repeats))
        out[f"native_{backend}_s"] = t_native
        out[f"openchk_{backend}_s"] = t_openchk
        out[f"overhead_ratio_{backend}"] = t_openchk / t_native
    out.update(compressed_store(repeats=repeats))
    return out


def rows(repeats: int = 2):
    r = run(repeats)
    return [("overhead/" + k, v * 1e6 if k.endswith("_s") else 0.0, v)
            for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
