"""Mesh axis conventions and the sharding-hint layer.

Axis conventions (every mesh in this repo uses these names):

- ``MODEL`` (``"model"``) — tensor parallelism. The only axis parameter
  feature dims ever shard over.
- ``DATA`` (``"data"``) — data parallelism (batch dim, ZeRO/FSDP shards).
- ``POD`` (``"pod"``) — an outer data-parallel axis on multi-pod meshes.
  Anything that shards on ``DATA`` folds ``pod`` in: requesting ``DATA``
  resolves to *every non-model axis* of the active mesh, so model code
  never cares whether it runs on ``(data, model)`` or ``(pod, data,
  model)``.

The hint layer is deliberately no-op-safe: model code calls
``shard_hint`` unconditionally; without an active mesh (CPU tests,
single-device serving) or with hints disabled (``constraint_hints(False)``
— the dp-only ablation) the input is returned unchanged, so hints never
constrain programs that did not opt in via ``use_mesh``.

Every resolution is divisibility-aware: an axis is kept only when the dim
it shards divides evenly by the axis size (GSPMD would otherwise pad or
fail); dims that do not divide degrade to replicated, and a spec whose
every requested axis dissolved resolves to ``None`` (caller falls back).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA = "data"
MODEL = "model"
POD = "pod"

_state = threading.local()


def _st():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.hints = True
    return _state


def active_mesh() -> Optional[Mesh]:
    """The mesh entered via ``use_mesh``, or None (hints no-op)."""
    return _st().mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Make ``mesh`` ambient for the hint layer (trace-time: wrap the
    ``jit``/``lower`` call, not the execution)."""
    st = _st()
    prev = st.mesh
    st.mesh = mesh
    try:
        yield mesh
    finally:
        st.mesh = prev


@contextlib.contextmanager
def constraint_hints(enabled: bool):
    """Toggle the hint layer (``False`` → every hint is identity). The
    dp-only dry-run disables hints so TP constraints never fight a
    replicated-parameter layout."""
    st = _st()
    prev = st.hints
    st.hints = bool(enabled)
    try:
        yield
    finally:
        st.hints = prev


def hints_enabled() -> bool:
    return _st().hints


# --------------------------------------------------------------------------- #
# axis resolution
# --------------------------------------------------------------------------- #


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Every non-model axis, mesh order — ``("data",)`` or
    ``("pod", "data")``. This is the pod→data folding rule."""
    return tuple(a for a in mesh.axis_names if a != MODEL)


def _axis_size(mesh: Mesh, axis: Any) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _expand(mesh: Mesh, axis: Any) -> Any:
    """Expand an axis request against the active mesh: ``DATA`` folds all
    data axes; names absent from the mesh dissolve to None."""
    if axis is None:
        return None
    if axis == DATA:
        dax = data_axes(mesh)
        if not dax:
            return None
        return dax[0] if len(dax) == 1 else dax
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def resolve_spec(mesh: Mesh, axes: Sequence[Any],
                 shape: Sequence[int]) -> Optional[P]:
    """Divisibility-aware spec resolution.

    Per dim: keep the requested axis iff the dim divides by the (folded)
    axis size, else degrade that dim to replicated. Returns ``None`` when
    every requested axis dissolved — the caller's signal to fall back to
    its next rule rather than emit an all-replicated constraint.
    """
    dims = []
    kept = 0
    for i, dim in enumerate(shape):
        axis = _expand(mesh, axes[i] if i < len(axes) else None)
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            dims.append(axis)
            kept += 1
        else:
            dims.append(None)
    if kept == 0:
        return None
    return P(*dims)


# --------------------------------------------------------------------------- #
# hints (no-op-safe: identity without an active mesh)
# --------------------------------------------------------------------------- #


def shard_hint(x: Any, *axes: Any) -> Any:
    """``with_sharding_constraint`` against the active mesh, or ``x``
    unchanged when there is no mesh, hints are disabled, or no requested
    axis survives divisibility."""
    mesh = active_mesh()
    if mesh is None or not _st().hints:
        return x
    spec = resolve_spec(mesh, axes, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_decode_kv(x: Any, model_dim: Optional[int] = 2) -> Any:
    """Decode-path KV/latent cache constraint, layout chosen by shape:

    - batch divides the data axes → batch-sharded decode (dim 0 on DATA);
    - else sequence-sharded long-context decode (dim 1 — the cache-seq
      dim — on DATA): scores/softmax/PV reduce over the sharded dim and
      GSPMD emits partial-softmax all-reduces instead of a KV gather;
    - ``model_dim`` (the repeated-heads dim; None for MLA latents) shards
      on MODEL when divisible.
    """
    mesh = active_mesh()
    if mesh is None or not _st().hints:
        return x
    dax = _expand(mesh, DATA)
    dims: list = [None] * x.ndim
    if dax is not None:
        dsize = _axis_size(mesh, dax)
        if x.shape[0] % dsize == 0:
            dims[0] = dax
        elif x.ndim >= 2 and x.shape[1] % dsize == 0:
            dims[1] = dax
    if (model_dim is not None and model_dim < x.ndim
            and MODEL in mesh.axis_names
            and x.shape[model_dim] % mesh.shape[MODEL] == 0):
        dims[model_dim] = MODEL
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*dims)))
