"""Multi-level checkpoint cost: store latency and bytes per level L1–L4,
on a 4-rank simulated cluster (partner copies and RS parity are real work).
"""
from __future__ import annotations

import os
import shutil
import time
from typing import Dict

import numpy as np

from repro.core.comm import SimulatedCluster
from repro.core.storage import StorageConfig, StorageEngine

MB = 8


def run() -> Dict[str, float]:
    out: Dict[str, float] = {}
    payload = {"arr": np.random.RandomState(0).randn(
        MB * 2**18).astype(np.float32)}
    for level in (1, 2, 3, 4):
        root = f"/tmp/bl-{level}"
        shutil.rmtree(root, ignore_errors=True)
        cluster = SimulatedCluster(os.path.join(root, "c"), 4)
        cfg = StorageConfig(root=os.path.join(root, "shared"), group_size=4,
                            erasure_scheme="rs", rs_parity=2)
        engines = [StorageEngine(cfg, c) for c in cluster.comms]
        t0 = time.time()
        reports = [e.store(payload, 1, level=level) for e in engines]
        dt = time.time() - t0
        out[f"l{level}_store_s_4ranks"] = dt
        out[f"l{level}_bytes_per_rank"] = float(reports[0].bytes_payload)
        shutil.rmtree(root, ignore_errors=True)
    return out


def rows():
    r = run()
    return [("levels/" + k, v * 1e6 if k.endswith("_s_4ranks") else 0.0, v)
            for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
