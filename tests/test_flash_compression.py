"""Flash-attention Pallas kernel vs oracle; int8 gradient compression."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.dist.compression import (
    compress_roundtrip_error,
    dequantize_int8,
    quantize_int8,
)
from repro.kernels.flashattn import flash_attention, flash_attention_bshd
from repro.models.attention import blockwise_attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 64)])
def test_flash_matches_blockwise(causal, s, bq, bk):
    rng = np.random.RandomState(0)
    b, h, dh = 2, 2, 64
    q = jnp.asarray(rng.randn(b, s, h, dh).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.randn(b, s, h, dh).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.randn(b, s, h, dh).astype(np.float32))
    got = np.asarray(flash_attention_bshd(q, k, v, causal=causal,
                                          interpret=True, bq=bq, bk=bk))
    want = np.asarray(blockwise_attention(q, k, v, causal=causal, q_block=64))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(2, 128, 64).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.randn(2, 128, 64).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.randn(2, 128, 64).astype(np.float32)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    assert o.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(o.astype(jnp.float32))))


def test_flash_extreme_logits_stable():
    """online softmax must survive large score magnitudes."""
    q = jnp.full((1, 128, 64), 8.0, jnp.float32)
    k = jnp.full((1, 128, 64), 8.0, jnp.float32)
    v = jnp.ones((1, 128, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o), 1.0, rtol=1e-5)


# ------------------------------ compression ------------------------------- #


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 10_000),
       scale=st.floats(1e-6, 1e4))
def test_int8_roundtrip_bounded_error(seed, n, scale):
    rng = np.random.RandomState(seed)
    g = jnp.asarray((rng.randn(n) * scale).astype(np.float32))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.shape)
    # per-block max-abs scaling → elementwise error ≤ scale/127 ≤ max/127
    err = np.abs(np.asarray(back) - np.asarray(g))
    bound = np.max(np.abs(np.asarray(g))) / 127.0 + 1e-12
    assert err.max() <= bound * 1.01


def test_int8_zero_grad_exact():
    g = jnp.zeros(100)
    q, s = quantize_int8(g)
    assert np.all(np.asarray(dequantize_int8(q, s, g.shape)) == 0)


def test_roundtrip_error_metric_small():
    rng = np.random.RandomState(3)
    g = jnp.asarray(rng.randn(4, 1000).astype(np.float32))
    rel = float(compress_roundtrip_error(g))
    assert 0.0 < rel < 0.01          # int8 ≈ 1/127 per-block relative error
    assert float(compress_roundtrip_error(jnp.zeros(64))) == 0.0


def test_error_feedback_reduces_bias():
    """with feedback, the *accumulated* quantization error stays bounded
    instead of growing linearly (the 1-bit-Adam argument)."""
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(4096).astype(np.float32)) * 1e-3
    err = jnp.zeros_like(g)
    acc_fb = jnp.zeros_like(g)      # sum of dequantized (with feedback)
    acc_nofb = jnp.zeros_like(g)
    for _ in range(20):
        q, s = quantize_int8(g + err)
        deq = dequantize_int8(q, s, g.shape)
        err = (g + err) - deq
        acc_fb = acc_fb + deq
        q2, s2 = quantize_int8(g)
        acc_nofb = acc_nofb + dequantize_int8(q2, s2, g.shape)
    true = np.asarray(g) * 20
    err_fb = np.linalg.norm(np.asarray(acc_fb) - true)
    err_nofb = np.linalg.norm(np.asarray(acc_nofb) - true)
    assert err_fb <= err_nofb * 1.05
    assert err_fb < np.linalg.norm(true) * 0.05
