"""L2 partner-copy replication (FTI/SCR PARTNER scheme).

Each rank ships its checkpoint payload to its ring partner, which stores it
next to its own (``rank<k>.partner<j>.chk5``). A lost node's state is then
recovered from its partner's node-local storage — no PFS round-trip.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.core.comm import Communicator
from repro.redundancy.groups import Topology


def partner_tag(ckpt_id: int) -> str:
    return f"partner:{ckpt_id}"


def replicate(comm: Communicator, topo: Topology, ckpt_id: int,
              payload: bytes) -> int:
    """Send my payload to my partner; returns the partner rank."""
    partner = topo.partner_of(comm.rank)
    comm.post(partner_tag(ckpt_id), partner, payload)
    return partner


def store_partner_copy(comm: Communicator, topo: Topology, ckpt_id: int,
                       tier_dir: str) -> Optional[str]:
    """Collect the replica posted *to me* and persist it locally."""
    # whoever has me as partner:
    src = next((r for r in range(comm.world) if topo.partner_of(r) == comm.rank),
               None)
    if src is None:
        return None
    payload = comm.collect(partner_tag(ckpt_id), src)
    if payload is None:
        return None
    os.makedirs(tier_dir, exist_ok=True)
    path = os.path.join(tier_dir, f"rank{comm.rank}.partner{src}.chk5")
    with open(path, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    return path


def find_partner_copy(topo: Topology, ckpt_dir_path: str, lost_rank: int
                      ) -> Optional[str]:
    """Locate the replica of ``lost_rank`` inside a checkpoint directory."""
    holder = topo.partner_of(lost_rank)
    path = os.path.join(ckpt_dir_path, f"rank{holder}.partner{lost_rank}.chk5")
    return path if os.path.exists(path) else None
