"""Quickstart: the OpenCHK directives on a toy training loop.

The paper's full CR surface is five lines (§6.3):
    init (2: config + context), load (1), store (1), shutdown (1).

Run:  PYTHONPATH=src python examples/quickstart.py
      (run it twice — the second run restarts from the checkpoint)
"""
import jax.numpy as jnp

from repro.core.context import CheckpointConfig, CheckpointContext

# --- application state: any pytree -------------------------------------- #
state = {"step": jnp.int32(0), "w": jnp.zeros(16)}


def update(s):
    return {"step": s["step"] + 1, "w": s["w"] + 0.1}


# --- the five CR lines --------------------------------------------------- #
cfg = CheckpointConfig(dir="/tmp/openchk-quickstart")            # 1 (config)
ctx = CheckpointContext(cfg)                                     # 2 (chk init)
state = ctx.load(state)                                          # 3 (chk load)

start = int(state["step"])
if ctx.restarted:
    print(f"transparent restart: resuming from step {start}")

for t in range(start, 50):
    state = update(state)
    ctx.store(state, id=t + 1, level=1, if_=(t + 1) % 10 == 0)   # 4 (chk store)

ctx.shutdown()                                                   # 5 (chk shutdown)
print(f"done at step {int(state['step'])}, w[0]={float(state['w'][0]):.2f}")
print("run me again to see the restart path; rm -rf /tmp/openchk-quickstart to reset")
