"""``python -m repro.tools.chkls <file.chk5 | objstore-root>`` — inspect
CHK5 containers and object-store checkpoint catalogs.

The paper's HDF5 argument: checkpoints double as analyzable datasets, with
standard tools. This is that tool for CHK5.  Clause-carrying stores
(core/protect.Protect) record their clauses as dataset attributes — the
listing shows the interesting ones (codec, kind, precision, fallbacks) and
``--json`` emits the full machine-readable inventory so CI can assert on
container contents.

Pointed at a *directory* (an object-store root — the ``file:`` bucket of
repro.objstore, e.g. ``<ckpt-dir>/objstore``), it lists the checkpoint
catalog instead: every published entry (id, kind/level from the recorded
manifest, file set with chunk counts, pin state) plus the store-wide
chunk inventory — ``--json`` again machine-readable for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.core.formats import CHK5Reader

#: clause/codec attrs worth a column in the human listing
_CLAUSE_ATTRS = ("codec", "kind", "precision", "codec_fallback",
                 "precision_fallback")


def _clause_str(name: str, attrs: dict) -> str:
    parts = []
    if name.startswith("shardidx/"):
        # the shard index of a sharded leaf: summarize the chunk set so the
        # listing shows where the payload actually lives
        parts.append(f"sharded n_chunks={attrs.get('n_chunks')} "
                     f"global={tuple(attrs.get('global_shape', ()))} "
                     f"over {len(set(attrs.get('files', [])))} file(s)")
    for k in _CLAUSE_ATTRS:
        if k in attrs:
            parts.append(f"{k}={attrs[k]}")
    return " ".join(parts)


def catalog_inventory(root: str) -> dict:
    """Deprecated shim: the machine-readable catalog listing for an
    object-store root.  The typed surface is
    ``repro.objstore.inspect.CatalogView`` — this keeps the historical
    dict shape for callers that still want plain JSON."""
    from repro.objstore.inspect import CatalogView
    return CatalogView.from_root(root, count_chunks=True).to_inventory(root)


def list_catalog(root: str, as_json: bool) -> int:
    from repro.objstore.catalog import CATALOG_KEY
    from repro.objstore.client import make_object_store
    store = make_object_store(f"file:{root}")
    # refuse to call an arbitrary directory an "empty catalog" — a wrong
    # path (the ckpt root instead of <root>/objstore) must fail loudly,
    # not read as a store that exists and holds nothing
    if not store.exists(CATALOG_KEY) and not store.list("chunks/"):
        print(f"{root}: not an object-store root (no {CATALOG_KEY}, no "
              f"chunks/) — point chkls at the bucket, e.g. "
              f"<ckpt-dir>/objstore", file=sys.stderr)
        return 2
    inv = catalog_inventory(root)
    if as_json:
        print(json.dumps({"catalog": inv}, indent=1, sort_keys=True))
        return 0
    if not inv["entries"]:
        print(f"{root}: empty catalog (epoch {inv['epoch']})")
        return 0
    print(f"catalog at {root}: epoch {inv['epoch']}, "
          f"{inv['stored_chunks']} stored chunks")
    for e in inv["entries"]:
        pin = " pinned" if e["pinned"] else ""
        hist = " ".join(f"{k}:{v}" for k, v in e["chunk_hist"].items())
        print(f"  ckpt {e['id']:<6d} kind={e['kind']} level={e['level']}"
              f" files={len(e['files'])} chunks={e['n_chunks']}"
              f" {e['total_bytes']:,d} B{pin}")
        if hist:
            print(f"    chunk sizes [{e['chunk_bytes_min']:,d}"
                  f"..{e['chunk_bytes_max']:,d}] B  hist {hist}")
        for name, f in e["files"].items():
            print(f"    {name:40s} {f['size']:>12,d} B"
                  f"  ({f['n_chunks']} {f['mode']} chunks)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="list CHK5 checkpoint contents (or, for a directory, "
                    "an object-store checkpoint catalog)")
    ap.add_argument("file")
    ap.add_argument("--verify", action="store_true", help="check all crc32s")
    ap.add_argument("--stats", action="store_true",
                    help="per-dataset min/max/mean for float data")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable inventory (attrs included)")
    args = ap.parse_args(argv)

    if os.path.isdir(args.file):
        return list_catalog(args.file, args.as_json)

    rd = CHK5Reader(args.file, verify=args.verify)

    if args.as_json:
        datasets = []
        for name in rd.datasets():
            m = rd.info(name)
            datasets.append({"name": name, "dtype": m["dtype"],
                             "shape": list(m["shape"]),
                             "nbytes": m["nbytes"],
                             "attrs": m.get("attrs", {})})
        inv = {
            "file": args.file,
            "attrs": rd.attrs(""),
            "datasets": datasets,
            "total_bytes": sum(d["nbytes"] for d in datasets),
            "verified": bool(args.verify),
        }
        print(json.dumps(inv, indent=1, sort_keys=True))
        rd.close()
        return 0

    root_attrs = rd.attrs("")
    if root_attrs:
        print(f"attrs: {root_attrs}")
    total = 0
    for name in rd.datasets():
        m = rd.info(name)
        total += m["nbytes"]
        line = (f"  {name:60s} {m['dtype']:>10s} "
                f"{str(tuple(m['shape'])):>20s} {m['nbytes']:>12,d} B")
        clauses = _clause_str(name, m.get("attrs", {}))
        if clauses:
            line += f"  [{clauses}]"
        if args.stats and m["dtype"] != "bytes":
            try:
                a = rd.read_dataset(name).astype(np.float32)
                if a.size:
                    line += (f"  [{a.min():+.3e}, {a.max():+.3e}]"
                             f" μ={a.mean():+.3e}")
            except (TypeError, ValueError):
                pass
        print(line)
    print(f"{len(rd.datasets())} datasets, {total:,} bytes"
          + ("  (crc OK)" if args.verify else ""))
    rd.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
