"""Pallas TPU kernel: per-block checkpoint hashing at HBM bandwidth.

The paper's differential checkpointing (FTI dCP) hashes protected data in
blocks on the host CPU. On TPU that would mean DMA-ing *all* bytes to the
host first — defeating the point. This kernel computes the dirty-map on
device: protected arrays are viewed as (n_blocks, block_elems) uint32 and
hashed in VMEM tiles; only the (tiny) hash vector and the dirty blocks ever
cross the PCIe boundary (DESIGN.md §2, hardware adaptation).

Tiling: grid (n_blocks / BR, block_elems / BE); the elems axis is
"arbitrary" (sequential) and accumulates into the output block with a
wrapping-add fold, which matches the commutative oracle in ref.py exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

from repro.kernels.ref import HASH_SALT_A, HASH_SALT_B

BR = 8          # block rows per tile
BE = 2048       # elems per tile (8·2048·4B = 64 KiB VMEM per input tile)


def _hash_kernel(x_ref, out_ref, *, salt: np.uint32, be: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.uint32)                      # (BR, BE)
    base = (j * np.uint32(be)).astype(jnp.uint32)
    idx = (base + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)) * salt
    h = x ^ idx
    h = h ^ (h >> 16)
    h = h * np.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * np.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    partial = jnp.sum(h, axis=1, dtype=jnp.uint32)         # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def blockhash_pallas(blocks_u32: jnp.ndarray, salt: np.uint32 = HASH_SALT_A,
                     interpret: bool = False) -> jnp.ndarray:
    """(n_blocks, elems) uint32 → (n_blocks,) uint32. elems % BE == 0 and
    n_blocks % BR == 0 (ops.py pads)."""
    n, e = blocks_u32.shape
    assert n % BR == 0 and e % BE == 0, (n, e)
    grid = (n // BR, e // BE)
    return pl.pallas_call(
        functools.partial(_hash_kernel, salt=salt, be=BE),
        grid=grid,
        in_specs=[pl.BlockSpec((BR, BE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BR,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(blocks_u32)


def blockhash2_pallas(blocks_u32: jnp.ndarray, interpret: bool = False
                      ) -> jnp.ndarray:
    """Two salt lanes → (n_blocks, 2) uint32 (64-bit digest)."""
    a = blockhash_pallas(blocks_u32, HASH_SALT_A, interpret=interpret)
    b = blockhash_pallas(blocks_u32, HASH_SALT_B, interpret=interpret)
    return jnp.stack([a, b], axis=1)
