"""Backends: TCL surface parity, native APIs, diff support matrix."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends.fti import FTIBackend
from repro.backends.registry import ENV_VAR, make_backend
from repro.backends.scr import SCRBackend
from repro.backends.veloc import VELOC_FAILURE, VELOC_SUCCESS, VeloCBackend
from repro.core.comm import LocalComm
from repro.core.context import CHK_DIFF, CHK_FULL, CheckpointConfig, CheckpointContext
from repro.core.storage import StorageConfig


def _cfg(tmp_path, name):
    return StorageConfig(root=str(tmp_path / name))


def _comm(tmp_path, name):
    return LocalComm(str(tmp_path / name / "node-local"))


NAMED = {"a": np.arange(10, dtype=np.float32),
         "b": np.ones((3, 3), np.int32)}


@pytest.mark.parametrize("backend", ["fti", "scr", "veloc"])
def test_tcl_surface_roundtrip(tmp_path, backend):
    b = make_backend(_cfg(tmp_path, backend), _comm(tmp_path, backend),
                     backend)
    assert b.tcl_load() is None
    b.tcl_store(NAMED, 1, 4, CHK_FULL)
    b.tcl_wait()
    got = b.tcl_load()
    assert set(got) == {"a", "b"}
    assert np.array_equal(got["a"], NAMED["a"])
    b.tcl_finalize()


@pytest.mark.parametrize("backend,fallbacks", [("fti", 0), ("scr", 1),
                                               ("veloc", 1)])
def test_diff_support_matrix(tmp_path, backend, fallbacks):
    """Paper §3: only FTI has checkpoint kinds; others fall back to FULL."""
    b = make_backend(_cfg(tmp_path, backend), _comm(tmp_path, backend),
                     backend)
    b.tcl_store(NAMED, 1, 1, CHK_FULL)
    b.tcl_wait()
    b.tcl_store(NAMED, 2, 1, CHK_DIFF)
    b.tcl_wait()
    assert b.stats["diff_fallbacks"] == fallbacks
    got = b.tcl_load()
    assert np.array_equal(got["a"], NAMED["a"])
    b.tcl_finalize()


def test_fti_native_api(tmp_path):
    b = FTIBackend(_cfg(tmp_path, "f"), _comm(tmp_path, "f"),
                   dedicated_thread=False)
    assert b.status() is False
    b.protect(0, "step", np.int32(7))
    b.protect(1, "data", np.arange(5.0))
    rep = b.checkpoint(1, level=1)
    assert rep is not None and rep.kind == CHK_FULL
    assert b.status() is True
    got = b.recover()
    assert got[0] == 7 and np.array_equal(got[1], np.arange(5.0))
    b.finalize()


def test_fti_differential_payload_shrinks(tmp_path):
    b = FTIBackend(_cfg(tmp_path, "fd"), _comm(tmp_path, "fd"),
                   dedicated_thread=False)
    big = np.zeros(100_000, np.float32)
    b.protect(0, "big", big)
    full = b.checkpoint(1, level=1)
    big2 = big.copy()
    big2[5] = 1.0
    b.protect(0, "big", big2)
    diff = b.checkpoint(2, level=1, differential=True)
    assert diff.kind == CHK_DIFF
    assert diff.bytes_payload < full.bytes_payload / 3
    got = b.recover()
    assert np.array_equal(got[0], big2)


def test_scr_native_file_mode(tmp_path):
    b = SCRBackend(_cfg(tmp_path, "s"), _comm(tmp_path, "s"))
    b.start_checkpoint(1, level=1)
    path = b.route_file("my.ckpt")
    from repro.core.formats import CHK5Writer
    with CHK5Writer(path) as w:
        w.write_dataset("data/x", np.arange(4.0))
    rep = b.complete_checkpoint(valid=True)
    assert rep is not None
    assert b.have_restart() == 1
    cid = b.start_restart()
    rpath = b.route_file("my.ckpt")
    from repro.core.formats import CHK5Reader
    assert np.array_equal(CHK5Reader(rpath).read_dataset("data/x"),
                          np.arange(4.0))
    b.complete_restart(True)


def test_scr_invalid_checkpoint_aborts(tmp_path):
    b = SCRBackend(_cfg(tmp_path, "sa"), _comm(tmp_path, "sa"))
    b.start_checkpoint(1, level=1)
    b.route_file("x")
    assert b.complete_checkpoint(valid=False) is None
    assert b.have_restart() is None


def test_veloc_native_api(tmp_path):
    b = VeloCBackend(_cfg(tmp_path, "v"), _comm(tmp_path, "v"))
    assert b.restart_test("job") == VELOC_FAILURE
    b.mem_protect(0, np.int32(3), "t")
    b.mem_protect(1, np.arange(6.0), "arr")
    assert b.checkpoint("job", 1) == VELOC_SUCCESS
    assert b.checkpoint_wait() == VELOC_SUCCESS
    assert b.restart_test("job") == 1
    assert b.restart("job", 1) == VELOC_SUCCESS
    assert np.array_equal(b.recovered(1), np.arange(6.0))
    b.tcl_finalize()


def test_env_backend_selection(tmp_path, monkeypatch):
    monkeypatch.setenv(ENV_VAR, "veloc")
    b = make_backend(_cfg(tmp_path, "e"), _comm(tmp_path, "e"))
    assert b.name == "veloc"
    monkeypatch.setenv(ENV_VAR, "nope")
    with pytest.raises(KeyError):
        make_backend(_cfg(tmp_path, "e2"), _comm(tmp_path, "e2"))


def test_portability_same_code_all_backends(tmp_path, monkeypatch):
    """The paper's portability claim: identical app code, backend from env."""
    state = {"w": jnp.arange(8.0), "step": jnp.int32(0)}
    results = {}
    for backend in ("fti", "scr", "veloc"):
        monkeypatch.setenv(ENV_VAR, backend)
        d = str(tmp_path / f"port-{backend}")
        # -- identical application code, no backend mention --
        ctx = CheckpointContext(CheckpointConfig(dir=d))
        s = ctx.load(state)
        s = {"w": s["w"] + 1, "step": s["step"] + 1}
        ctx.store(s, id=1, level=1)
        ctx.shutdown()
        ctx2 = CheckpointContext(CheckpointConfig(dir=d))
        s2 = ctx2.load(state)
        results[backend] = (ctx2.restarted, np.asarray(s2["w"]))
        ctx2.shutdown()
    for backend, (restarted, w) in results.items():
        assert restarted, backend
        assert np.array_equal(w, np.arange(8.0) + 1), backend
