"""serve substrate: the batched engine plus the checkpoint-as-deployment
control plane (catalog subscriber → chunk-delta pull → rolling atomic
weight swap)."""
from repro.serve.engine import (
    ServeState,
    ServingEngine,
    WeightsHandle,
    make_serve_step,
)

__all__ = ["ServeState", "ServingEngine", "WeightsHandle",
           "make_serve_step"]
