"""End-to-end training driver with OpenCHK checkpoint/restart.

Modes:
  direct:      python -m repro.launch.train --arch tinyllama-1.1b --steps 200
  supervised:  python -m repro.launch.train --supervise --inject-at 0.9 ...
               (launcher spawns the worker, injects a fault at 90 % progress,
               detects death via exit code / heartbeat timeout, restarts; the
               worker resumes from the last checkpoint via ``ctx.load`` — the
               paper's §6.1 methodology end to end)

Reduced configs run on CPU; ``--full`` uses the assigned config (TPU-scale).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def worker(args) -> int:
    import jax
    from repro.configs import get_arch
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.data.synthetic import init_data_state
    from repro.ft.failures import FaultInjector, should_inject_from_env
    from repro.models.zoo import build_model
    from repro.train.loop import LevelSchedule, LoopConfig, run_training
    from repro.train.optimizer import AdamWConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    state = init_train_state(params, jax.random.PRNGKey(args.seed + 1),
                             init_data_state(args.seed))
    step_fn = make_train_step(
        model, AdamWConfig(total_steps=args.steps, warmup_steps=args.steps // 10),
        remat=not args.no_remat, num_microbatches=args.microbatches)

    ckpt = CheckpointContext(CheckpointConfig(
        dir=args.ckpt_dir, backend=args.backend,
        dedicated_thread=not args.no_dedicated_thread))

    inject_at = args.inject_at if args.inject_at else should_inject_from_env()
    injector = FaultInjector(args.steps, inject_at, hard=args.hard_fault) \
        if inject_at else None

    cadence = None
    if args.cadence:
        from repro.chaos.cadence import (
            CadenceConfig, CadenceController, MTBFFeed)
        cadence = CadenceController(CadenceConfig(
            prior_mtbf_s=args.cadence_mtbf,
            gap_failure_s=args.heartbeat_timeout))
        # the supervisor's live failure record (real worker deaths +
        # heartbeat-gap kills): a restarted worker resumes from observed
        # MTBF reality instead of the prior
        MTBFFeed(os.path.join(args.ckpt_dir, "mtbf-feed.json")).seed(
            cadence.mtbf)

    loop = LoopConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        kind="DIFF" if args.differential else "FULL",
        levels=LevelSchedule(),
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat"),
        cadence=cadence,
        gap_failure_s=args.heartbeat_timeout,
    )
    try:
        summary = run_training(model, step_fn, state, ckpt, loop,
                               args.batch, args.seq, injector=injector)
    finally:
        ckpt.shutdown()
    brief = {k: v for k, v in summary.items() if k != "state"}
    print(f"[train] done: {brief}")
    return 0


def supervise(args) -> int:
    """Restart launcher: run worker until success, restarting on failure.

    Thin wrapper over :class:`repro.ft.supervisor.Supervisor` — the
    kill-detect / startup-grace / backoff-reset / MTBF-feed policy lives
    (and is unit-tested) there.  Chaos specs survive restarts with
    spec-declared ``rearm`` semantics: their durable counters
    (``OPENCHK_CHAOS_STATE``, defaulted into the checkpoint dir) keep an
    exhausted kill spec from re-killing every restarted child."""
    from repro.chaos import inject
    from repro.ft.supervisor import Supervisor, SupervisorConfig

    cmd = [sys.executable, "-m", "repro.launch.train"] + [
        a for a in sys.argv[1:] if a not in ("--supervise",)]
    env = dict(os.environ)
    if args.inject_at:
        env["OPENCHK_INJECT_AT"] = str(args.inject_at)
        cmd = [c for c in cmd if not c.startswith("--inject-at")
               and c != str(args.inject_at)]
    if env.get(inject.CHAOS_ENV) and not env.get(inject.CHAOS_STATE_ENV):
        env[inject.CHAOS_STATE_ENV] = os.path.join(
            args.ckpt_dir, "chaos-state.json")
    sup = Supervisor(cmd, env, SupervisorConfig(
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat"),
        heartbeat_timeout_s=args.heartbeat_timeout,
        startup_grace_s=args.startup_grace,
        healthy_reset_s=args.healthy_reset,
        max_restarts=args.max_restarts,
        backoff_base_s=args.restart_backoff,
        backoff_max_s=args.restart_backoff_max,
        mtbf_feed_path=os.path.join(args.ckpt_dir, "mtbf-feed.json"),
        prior_mtbf_s=args.cadence_mtbf,
        health_port=args.health_port,
    ))
    return sup.run()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/openchk-train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--backend", default=None, help="fti|scr|veloc (or env)")
    ap.add_argument("--differential", action="store_true")
    ap.add_argument("--full", action="store_true", help="full (TPU-size) config")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-dedicated-thread", action="store_true")
    ap.add_argument("--inject-at", type=float, default=None)
    ap.add_argument("--hard-fault", action="store_true",
                    help="os._exit instead of exception")
    ap.add_argument("--supervise", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0)
    ap.add_argument("--restart-backoff", type=float, default=1.0,
                    help="base seconds between restart attempts (doubles "
                         "per consecutive failure)")
    ap.add_argument("--restart-backoff-max", type=float, default=30.0)
    ap.add_argument("--startup-grace", type=float, default=None,
                    help="kill a worker that never beats within this many "
                         "seconds (default: 2x --heartbeat-timeout)")
    ap.add_argument("--healthy-reset", type=float, default=None,
                    help="forget restart-backoff failures after the worker "
                         "stays healthy this long (default: "
                         "--heartbeat-timeout)")
    ap.add_argument("--cadence", action="store_true",
                    help="Daly-optimal adaptive checkpoint cadence instead "
                         "of the fixed --ckpt-every cycle")
    ap.add_argument("--cadence-mtbf", type=float, default=3600.0,
                    help="prior MTBF seconds for the cadence controller")
    ap.add_argument("--trace-dir", default=None,
                    help="write perfetto trace files (trace-<pid>.json) "
                         "into this dir; under --supervise the supervisor "
                         "merges worker files into one trace.json")
    ap.add_argument("--health-port", type=int, default=None,
                    help="with --supervise: serve /healthz /readyz "
                         "/metrics on this port (0 = ephemeral)")
    args = ap.parse_args()
    os.makedirs(args.ckpt_dir, exist_ok=True)
    if args.trace_dir:
        # env, not a direct enable: the worker subprocesses a supervisor
        # spawns inherit it (each process writes trace-<pid>.json)
        os.makedirs(args.trace_dir, exist_ok=True)
        os.environ["OPENCHK_TRACE_DIR"] = args.trace_dir
    if args.supervise:
        return supervise(args)
    return worker(args)


if __name__ == "__main__":
    sys.exit(main())
