"""repro: OpenCHK-JAX — directive-style checkpoint/restart for multi-pod JAX.

Reproduction of "Extending the OpenCHK Model with Advanced Checkpoint
Features" (Maroñas et al., 2020) as a production-grade JAX training
framework. See DESIGN.md.
"""
__version__ = "1.0.0"
