"""Injection plane: named fault sites over the stack's existing seams.

The pipeline already has well-defined failure boundaries — tier place/commit
loops in ``core/pipeline.py``, ``ObjectStore.put/get``, ``ChunkStream``
chunk-boundary emits, the heartbeat write, ``FleetDeployer`` swap attempts,
and the per-step hook in ``train/loop.py``. Each of those calls
:func:`fire` with a dotted site name; a site is inert (one dict lookup)
until a :class:`FaultSpec` is armed against it.

Specs generalize ``ft/failures.FaultInjector`` from one-fault-at-90% to:

* scheduled  — ``at=N`` fires on the N-th hit of the site
* repeating  — ``every=K`` fires on every K-th hit, up to ``times`` fires
* probabilistic — ``prob=p`` fires each hit with probability ``p``
  (seeded; deterministic per spec)

and four modes: ``error`` (raise the site's natural exception type),
``exit`` (``os._exit(39)``, same hard-kill contract as FaultInjector),
``delay`` (sleep ``delay_s`` — stragglers), ``corrupt`` (flip bytes in the
payload passing through the site), ``skip`` (suppress the operation — e.g.
a heartbeat write that never lands).

Process-safe activation: ``OPENCHK_CHAOS`` holds either a JSON list of spec
dicts or ``@/path/to/spec.json``. The registry loads it lazily on first
use, so subprocess children of ``launch/train.py`` and the forced-16-device
lanes arm the same faults without code changes. Malformed specs warn and
are ignored — a bad env var must never crash a launcher at import time.

Restart-durable counters: ``OPENCHK_CHAOS_STATE`` names a JSON file where
each spec's hit/fired counters (and RNG state, for ``prob`` specs) persist
across process deaths — written atomically on every counted hit and, for
``exit`` mode, *before* ``os._exit``. A restarted child reloads the file
and resumes each spec mid-schedule: an exhausted ``every=8, times=1`` kill
spec stays exhausted instead of re-killing every restart at the same hit
count. Specs declare ``rearm`` (default True = stay armed across
restarts); :func:`restart_env` applies those semantics for supervisors,
replacing the old blanket ``env.pop(OPENCHK_CHAOS)``. Malformed state
warns and is ignored, like the env protocol.

Stdlib-only on purpose: every instrumented module (objstore client, chunk
streams, pipeline, detector) can import this leaf without cycles.  The one
repro import is :mod:`repro.telemetry` — itself a stdlib-only leaf — so
every fired fault is also a trace instant and a fault counter.
"""
from __future__ import annotations

import fnmatch
import hashlib
import json
import os
import random
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# telemetry is the one permitted repro import: like this module it is a
# stdlib-only leaf, so the no-cycle rule holds.  Every fired fault lands
# on the trace timeline (the fault → kill → restart → resume narrative
# chktrace reconstructs) and on the fault counters.
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace

CHAOS_ENV = "OPENCHK_CHAOS"
CHAOS_STATE_ENV = "OPENCHK_CHAOS_STATE"
LEGACY_INJECT_ENV = "OPENCHK_INJECT_AT"
EXIT_CODE = 39  # matches ft.failures.FaultInjector's hard-kill contract

_MODES = ("error", "exit", "delay", "corrupt", "skip")


class InjectedFault(RuntimeError):
    """Raised by an ``error``-mode spec when the site has no natural type."""


@dataclass
class FaultSpec:
    """One armed fault: where it triggers, when, and what it does."""

    site: str  # dotted site name; fnmatch globs allowed ("objstore.*")
    mode: str = "error"
    at: Optional[int] = None  # fire on the at-th hit (1-based)
    every: Optional[int] = None  # fire on every every-th hit
    prob: Optional[float] = None  # fire each hit with this probability
    times: Optional[int] = 1  # max fires (None = unlimited)
    delay_s: float = 0.0  # sleep length for mode="delay"
    seed: int = 0  # rng seed for prob specs
    match: Dict[str, Any] = field(default_factory=dict)  # ctx filter
    message: str = ""
    rearm: bool = True  # stay armed across supervised restarts

    def __post_init__(self) -> None:
        if self.mode not in _MODES:
            raise ValueError(f"unknown chaos mode {self.mode!r} (want one of {_MODES})")
        if self.at is None and self.every is None and self.prob is None:
            self.at = 1  # default: fire on the first hit
        self._hits = 0
        self._fired = 0
        self._rng = random.Random(self.seed)

    def matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        if not fnmatch.fnmatchcase(site, self.site):
            return False
        for k, want in self.match.items():
            if str(ctx.get(k)) != str(want):
                return False
        return True

    def should_fire(self) -> bool:
        """Count a hit; decide whether this spec fires on it."""
        if self.times is not None and self._fired >= self.times:
            return False
        self._hits += 1
        fire = False
        if self.at is not None and self._hits == self.at:
            fire = True
        if self.every is not None and self._hits % self.every == 0:
            fire = True
        if self.prob is not None and self._rng.random() < self.prob:
            fire = True
        if fire:
            self._fired += 1
        return fire

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"site": self.site, "mode": self.mode}
        for k in ("at", "every", "prob"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.times != 1:
            d["times"] = self.times
        if self.delay_s:
            d["delay_s"] = self.delay_s
        if self.seed:
            d["seed"] = self.seed
        if self.match:
            d["match"] = self.match
        if self.message:
            d["message"] = self.message
        if not self.rearm:
            d["rearm"] = False
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        known = {
            "site", "mode", "at", "every", "prob", "times",
            "delay_s", "seed", "match", "message", "rearm",
        }
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown chaos spec keys {sorted(extra)}")
        return cls(**d)

    # -- restart-durable counters -----------------------------------------
    def state_key(self) -> str:
        """Stable content hash naming this spec in the durable state file.

        Keyed on the serialized spec, not its position in the env list, so
        a supervisor that rewrites ``OPENCHK_CHAOS`` (dropping a
        ``rearm=False`` sibling) still matches the surviving specs to
        their persisted counters."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def counters(self) -> Dict[str, Any]:
        st: Dict[str, Any] = {"hits": self._hits, "fired": self._fired}
        if self.prob is not None:
            version, ints, gauss = self._rng.getstate()
            st["rng"] = [version, list(ints), gauss]
        return st

    def restore_counters(self, st: Dict[str, Any]) -> None:
        self._hits = int(st.get("hits", 0))
        self._fired = int(st.get("fired", 0))
        rng = st.get("rng")
        if rng is not None and self.prob is not None:
            version, ints, gauss = rng
            self._rng.setstate(
                (int(version), tuple(int(i) for i in ints), gauss))


@dataclass
class FiredFault:
    """History record of one fired fault — feeds the MTBF estimator."""

    site: str
    mode: str
    t: float  # time.monotonic() at fire
    ctx: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Outcome:
    """What :func:`fire` decided: possibly-corrupted payload + skip flag."""

    data: Any = None
    skipped: bool = False
    fired: int = 0


_NOOP = Outcome()


def _corrupt_bytes(data: Any) -> Any:
    """Flip the first byte (and a mid byte) of a bytes-like payload."""
    if data is None:
        return None
    b = bytearray(bytes(data))
    if not b:
        return bytes(b)
    b[0] ^= 0xFF
    b[len(b) // 2] ^= 0xFF
    return bytes(b)


class ChaosRegistry:
    """Armed fault specs + per-site counters + fired-fault history.

    Thread-safe: chunk uploads fire from worker threads. The fast path
    (nothing armed) is a single attribute read.
    """

    def __init__(self, env: Optional[Dict[str, str]] = None) -> None:
        self._lock = threading.Lock()
        self._specs: List[FaultSpec] = []
        self._env = env  # None -> os.environ, resolved lazily
        self._env_loaded = False
        self.enabled = False
        self.history: List[FiredFault] = []
        self.site_hits: Dict[str, int] = {}
        self._state_path: Optional[str] = None
        self._persisted: Dict[str, Dict[str, Any]] = {}

    # -- arming -----------------------------------------------------------
    def arm(self, spec: FaultSpec) -> FaultSpec:
        with self._lock:
            self._apply_state_locked(spec)
            self._specs.append(spec)
            self.enabled = True
        return spec

    def disarm_all(self) -> None:
        with self._lock:
            self._specs = []
            self.enabled = False

    def reset(self) -> None:
        """Drop specs, counters, and history; env will be re-read."""
        with self._lock:
            self._specs = []
            self._env_loaded = False
            self.enabled = False
            self.history = []
            self.site_hits = {}
            self._state_path = None
            self._persisted = {}

    # -- restart-durable state file ---------------------------------------
    def set_state_path(self, path: Optional[str]) -> None:
        """Point at the durable counter file; reload + apply to armed specs."""
        with self._lock:
            self._state_path = path
            self._persisted = self._read_state(path) if path else {}
            for spec in self._specs:
                self._apply_state_locked(spec)

    @staticmethod
    def _read_state(path: str) -> Dict[str, Dict[str, Any]]:
        try:
            with open(path, "r", encoding="utf-8") as f:
                st = json.load(f)
            if not isinstance(st, dict):
                raise ValueError("state root must be a JSON object")
            return {str(k): dict(v) for k, v in st.items()}
        except FileNotFoundError:
            return {}
        except (OSError, ValueError, TypeError, AttributeError) as e:
            warnings.warn(
                f"ignoring malformed chaos state at {path}: {e}",
                RuntimeWarning, stacklevel=2)
            return {}

    def _apply_state_locked(self, spec: FaultSpec) -> None:
        st = self._persisted.get(spec.state_key())
        if st is None:
            return
        try:
            spec.restore_counters(st)
        except (ValueError, TypeError) as e:
            warnings.warn(
                f"ignoring malformed chaos state for spec {spec.site!r}: {e}",
                RuntimeWarning, stacklevel=2)

    def _persist_state_locked(self) -> None:
        """Atomically write every armed spec's counters (tmp + replace).

        Called on each counted hit while a state path is set, and — the
        load-bearing case — immediately before an ``exit``-mode
        ``os._exit``, so the kill the spec just dealt is on disk before
        the process dies."""
        if self._state_path is None:
            return
        state = dict(self._persisted)
        for spec in self._specs:
            state[spec.state_key()] = spec.counters()
        tmp = self._state_path + ".tmp"
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(state, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._state_path)
        except OSError as e:
            warnings.warn(
                f"could not persist chaos state to {self._state_path}: {e}",
                RuntimeWarning, stacklevel=2)

    def specs(self) -> List[FaultSpec]:
        self._ensure_env_loaded()
        with self._lock:
            return list(self._specs)

    # -- env activation protocol ------------------------------------------
    def load_env(self, env: Optional[Dict[str, str]] = None) -> int:
        """Parse ``OPENCHK_CHAOS`` and arm its specs. Returns count armed.

        Malformed values warn and arm nothing — never raise at launch time.
        """
        environ = env if env is not None else (self._env if self._env is not None else os.environ)
        raw = environ.get(CHAOS_ENV, "")
        self._env_loaded = True
        state_path = environ.get(CHAOS_STATE_ENV, "")
        if state_path:
            # durable counters load before arming so each armed spec
            # resumes mid-schedule instead of replaying from hit zero
            self.set_state_path(state_path)
        if not raw:
            return 0
        try:
            specs = _parse_specs(raw)
        except (OSError, ValueError, TypeError) as e:
            warnings.warn(
                f"ignoring malformed {CHAOS_ENV}: {e}", RuntimeWarning, stacklevel=2
            )
            return 0
        for s in specs:
            self.arm(s)
        return len(specs)

    def _ensure_env_loaded(self) -> None:
        if not self._env_loaded:
            self.load_env()

    # -- firing ------------------------------------------------------------
    def fire(
        self,
        site: str,
        exc: type = InjectedFault,
        data: Any = None,
        **ctx: Any,
    ) -> Outcome:
        """Hit a fault site. Raises / exits / sleeps / corrupts per armed specs.

        Call sites pass their natural exception type via ``exc`` so the
        injected failure flows through the same handling as a real one
        (e.g. ``ObjectStoreError`` for objstore sites). Returns the payload
        (corrupted if a corrupt-mode spec fired) and a ``skipped`` flag.
        """
        self._ensure_env_loaded()
        if not self.enabled:
            if data is None:
                return _NOOP
            return Outcome(data=data)

        to_raise: Optional[BaseException] = None
        out = Outcome(data=data)
        with self._lock:
            self.site_hits[site] = self.site_hits.get(site, 0) + 1
            mutated = False
            for spec in self._specs:
                if not spec.matches(site, ctx):
                    continue
                mutated = True                 # should_fire counts the hit
                if not spec.should_fire():
                    continue
                out.fired += 1
                self.history.append(
                    FiredFault(site=site, mode=spec.mode, t=time.monotonic(), ctx=dict(ctx))
                )
                # ctx keys are renamed where they would shadow the
                # instant()'s own parameters (e.g. chunkstream's "name")
                ttrace.instant("chaos.fault", site=site, mode=spec.mode,
                               **{(k if k not in ("name", "cat", "scope",
                                                  "site", "mode")
                                   else f"ctx_{k}"): v
                                  for k, v in ctx.items()})
                tmetrics.counter("openchk_faults_fired_total",
                                 site=site, mode=spec.mode).inc()
                if spec.mode == "delay":
                    # sleep outside the lock would be nicer, but delays are
                    # short and scenario-scoped; keep firing atomic.
                    time.sleep(spec.delay_s)
                elif spec.mode == "skip":
                    out.skipped = True
                elif spec.mode == "corrupt":
                    out.data = _corrupt_bytes(out.data)
                elif spec.mode == "exit":
                    # the kill must be on disk before the process dies —
                    # a restarted child that reloads stale counters would
                    # be re-killed at the same hit count.  Same for the
                    # trace: os._exit skips atexit, so flush now — the
                    # fault instant above must survive its own kill
                    self._persist_state_locked()
                    ttrace.flush()
                    os._exit(EXIT_CODE)
                else:  # error
                    msg = spec.message or f"[chaos] injected fault at {site}"
                    to_raise = exc(msg)
            if mutated:
                self._persist_state_locked()
        if to_raise is not None:
            raise to_raise
        return out

    def fired_count(self, site: Optional[str] = None) -> int:
        with self._lock:
            if site is None:
                return len(self.history)
            return sum(1 for f in self.history if fnmatch.fnmatchcase(f.site, site))

    def fault_times(self) -> List[float]:
        """Monotonic timestamps of fired faults (MTBF estimator input)."""
        with self._lock:
            return [f.t for f in self.history]


# -- module-level singleton -------------------------------------------------
_REGISTRY = ChaosRegistry()


def registry() -> ChaosRegistry:
    return _REGISTRY


def fire(site: str, exc: type = InjectedFault, data: Any = None, **ctx: Any) -> Outcome:
    return _REGISTRY.fire(site, exc=exc, data=data, **ctx)


def arm(spec_or_site, **kw) -> FaultSpec:
    """``arm(FaultSpec(...))`` or shorthand ``arm("site.name", mode=..., ...)``."""
    if isinstance(spec_or_site, FaultSpec):
        return _REGISTRY.arm(spec_or_site)
    return _REGISTRY.arm(FaultSpec(site=spec_or_site, **kw))


def reset() -> None:
    _REGISTRY.reset()


def _parse_specs(raw: str) -> List[FaultSpec]:
    """Parse an ``OPENCHK_CHAOS`` value (JSON list/dict or ``@file``)."""
    if raw.startswith("@"):
        with open(raw[1:], "r", encoding="utf-8") as f:
            raw = f.read()
    parsed = json.loads(raw)
    if isinstance(parsed, dict):
        parsed = [parsed]
    return [FaultSpec.from_dict(d) for d in parsed]


def env_for_specs(specs: List[FaultSpec],
                  state_path: Optional[str] = None) -> Dict[str, str]:
    """Env fragment arming *specs* in a child process.

    With *state_path*, the child persists per-spec hit/fired counters (and
    RNG state) there, so a restarted child resumes each spec mid-schedule
    instead of replaying it from hit zero."""
    env = {CHAOS_ENV: json.dumps([s.to_dict() for s in specs])}
    if state_path:
        env[CHAOS_STATE_ENV] = state_path
    return env


def restart_env(env: Dict[str, str]) -> Dict[str, str]:
    """Spec-declared rearm semantics for a restarted child's env.

    Replaces the supervisor's old blanket ``env.pop(OPENCHK_CHAOS)``:
    ``rearm=True`` specs (the default) stay armed — the durable state file
    keeps an exhausted spec from re-killing every restarted child at the
    same hit count — while ``rearm=False`` specs are dropped.  The legacy
    one-shot ``OPENCHK_INJECT_AT`` is always dropped.  Malformed values
    warn and are dropped (the load_env contract).  Returns a new dict;
    *env* is not mutated."""
    out = dict(env)
    out.pop(LEGACY_INJECT_ENV, None)
    raw = out.get(CHAOS_ENV, "")
    if not raw:
        return out
    try:
        specs = _parse_specs(raw)
    except (OSError, ValueError, TypeError) as e:
        warnings.warn(
            f"dropping malformed {CHAOS_ENV} on restart: {e}",
            RuntimeWarning, stacklevel=2)
        out.pop(CHAOS_ENV, None)
        return out
    keep = [s for s in specs if s.rearm]
    if not keep:
        out.pop(CHAOS_ENV, None)
        out.pop(CHAOS_STATE_ENV, None)
    elif len(keep) != len(specs):
        out[CHAOS_ENV] = json.dumps([s.to_dict() for s in keep])
    return out


def legacy_inject_at(env: Optional[Dict[str, str]] = None) -> Optional[float]:
    """Back-compat reader for ``OPENCHK_INJECT_AT`` (progress fraction).

    The legacy protocol predates the chaos spec: a single float in [0, 1]
    meaning "one hard fault at this training progress". Malformed values
    warn and return None instead of raising at launcher import time.
    ``ft.failures.should_inject_from_env`` is a shim over this.
    """
    environ = env if env is not None else os.environ
    v = environ.get(LEGACY_INJECT_ENV, "")
    if not v:
        return None
    try:
        return float(v)
    except ValueError:
        warnings.warn(
            f"ignoring malformed {LEGACY_INJECT_ENV}={v!r} (want a float progress "
            "fraction; use OPENCHK_CHAOS for scheduled/probabilistic faults)",
            RuntimeWarning,
            stacklevel=2,
        )
        return None


@dataclass
class SiteNames:
    """Canonical site names, for discoverability (docs + scenario specs)."""

    TIER_PLACE = "tier.place"  # ctx: tier, level, ckpt_id, rank
    TIER_COMMIT = "tier.commit"  # ctx: tier, level, ckpt_id, rank
    OBJSTORE_PUT = "objstore.put"  # ctx: key
    OBJSTORE_GET = "objstore.get"  # ctx: key
    OBJSTORE_DELETE = "objstore.delete"  # ctx: key (GC sweep deletes)
    CHUNK_EMIT = "chunkstream.emit"  # ctx: name, seq
    HEARTBEAT = "heartbeat.beat"  # ctx: step
    DEPLOY_POLL = "deploy.poll"  # ctx: replica
    TRAIN_STEP = "train.step"  # ctx: step


SITES = SiteNames()
