"""whisper-small — encoder-decoder audio transformer [arXiv:2212.04356; unverified].

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865. Conv audio frontend is
a stub per the assignment: ``input_specs`` supplies precomputed frame
embeddings (B, T, d_model).
"""
from repro.configs.base import ArchConfig, register

ARCH = register(ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    encdec=True,
    frontend="audio_stub",
    act="gelu",
    use_rope=False,
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
