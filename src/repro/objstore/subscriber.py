"""Catalog subscriber — the watch side of checkpoint-as-deployment.

A serving fleet follows the training run's object store, not a push
channel: the catalog's CAS epoch counts every publish, so "is there a
new checkpoint?" is one integer comparison per poll
(:meth:`~repro.objstore.catalog.Catalog.read_if_newer`), and "which one
should we serve?" is a :class:`DeploySelector` query over the typed
:class:`~repro.objstore.inspect.CatalogView`.  The subscriber never
parses ``catalog.json`` by hand and never downloads anything — it only
decides *what* to deploy; the chunk-delta pull and the rolling swap live
in ``repro.serve.deploy``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.objstore.catalog import Catalog
from repro.objstore.client import ObjectStore
from repro.objstore.inspect import CatalogView, ChunkDelta, EntryInfo


@dataclass(frozen=True)
class DeploySelector:
    """Which published entries a fleet is willing to serve.  The default
    tracks the newest ``kind=FULL`` checkpoint — diffs are partial trees
    and never deployable on their own."""
    kind: Optional[str] = "FULL"
    level: Optional[int] = None
    min_id: int = 0

    def matches(self, e: EntryInfo) -> bool:
        return ((self.kind is None or e.kind == self.kind)
                and (self.level is None or e.level == self.level)
                and e.id >= self.min_id)

    def resolve(self, view: CatalogView) -> Optional[EntryInfo]:
        return view.latest(kind=self.kind, level=self.level,
                           min_id=self.min_id or None)


class CatalogSubscriber:
    """Polls catalog epochs and surfaces newly published entries that
    match the selector.

    State is two fields: ``last_epoch`` (the newest catalog epoch
    already examined — stale polls return without parsing entries) and
    ``deployed`` (the entry the fleet currently serves — the *base* of
    every chunk delta).  ``deployed`` only advances via
    :meth:`mark_deployed`, i.e. after the fleet actually converged; a
    failed rollout keeps the old base so the retry recomputes the same
    delta.  Object-store outages propagate as ``ObjectStoreError`` from
    :meth:`poll` — backoff policy belongs to the deployer, not here.
    """

    def __init__(self, store: ObjectStore,
                 selector: DeploySelector = DeploySelector()):
        self.catalog = Catalog(store)
        self.selector = selector
        self.last_epoch = -1           # first poll always reads
        self.deployed: Optional[EntryInfo] = None

    def poll(self) -> Optional[EntryInfo]:
        """One watch step: → the entry the fleet *should* be serving, or
        ``None`` when the catalog has nothing newer to offer (no epoch
        movement, no selector match, or the match is already deployed)."""
        got = self.catalog.read_if_newer(self.last_epoch)
        if got is None:
            return None
        cat, epoch = got
        self.last_epoch = epoch
        target = self.selector.resolve(CatalogView.from_json(cat))
        if target is None:
            return None
        if self.deployed is not None and target.id == self.deployed.id:
            return None
        return target

    def delta(self, target: EntryInfo) -> ChunkDelta:
        """The chunk pull moving the fleet from its deployed entry to
        ``target`` costs (the whole entry for a cold fleet)."""
        return CatalogView.diff(self.deployed, target)

    def mark_deployed(self, entry: EntryInfo) -> None:
        """The fleet converged on ``entry`` — it becomes the delta base."""
        self.deployed = entry
