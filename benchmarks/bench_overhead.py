"""Fig. 12 analogue: wall-time overhead of OpenCHK vs native backends.

Methodology reproduced from §6.1: first run with a fault injected at 90 %
progress, then restart to completion; time the whole process. Ratio
OpenCHK/native should be ≈1 (paper: within noise, <2 % worst case).
"""
from __future__ import annotations

import shutil
import time
from typing import Dict

from benchmarks.apps import heat2d_fti, heat2d_openchk, heat2d_scr, heat2d_veloc
from repro.ft.failures import FaultInjector, SimulatedFault

STEPS = 200
N = 768             # 2.25 MB grid → checkpoint I/O is non-trivial
EVERY = 20          # 10 checkpoints per run, like the paper's 1/minute × 10


def timed_run_with_fault(mod, ckpt_dir, backend=None) -> float:
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    # warm the jit cache so compile time isn't charged to the first variant
    from benchmarks.apps.heat2d_common import heat_step, init_grid
    heat_step(init_grid(N)).block_until_ready()
    t0 = time.time()
    inj = FaultInjector(total_steps=STEPS, at_progress=0.9)
    try:
        mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                injector=inj, backend=backend)
    except SimulatedFault:
        # a real abort kills the CP thread with the process; the in-process
        # simulation must drain it so the restart doesn't race an orphan
        from repro.core.async_engine import drain_all
        drain_all()
    out = mod.run(n=N, steps=STEPS, ckpt_every=EVERY, ckpt_dir=ckpt_dir,
                  backend=backend)
    assert out["restarted"], "restart did not engage"
    dt = time.time() - t0
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    return dt


def run(repeats: int = 3) -> Dict[str, float]:
    natives = {"fti": heat2d_fti, "scr": heat2d_scr, "veloc": heat2d_veloc}
    out: Dict[str, float] = {}
    for backend, native_mod in natives.items():
        t_native = min(timed_run_with_fault(
            native_mod, f"/tmp/bo-native-{backend}") for _ in range(repeats))
        t_openchk = min(timed_run_with_fault(
            heat2d_openchk, f"/tmp/bo-openchk-{backend}", backend=backend)
            for _ in range(repeats))
        out[f"native_{backend}_s"] = t_native
        out[f"openchk_{backend}_s"] = t_openchk
        out[f"overhead_ratio_{backend}"] = t_openchk / t_native
    return out


def rows(repeats: int = 2):
    r = run(repeats)
    return [("overhead/" + k, v * 1e6 if k.endswith("_s") else 0.0, v)
            for k, v in sorted(r.items())]


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
