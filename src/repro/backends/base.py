"""Backend base: the "native library" surface each backend exposes.

Each backend mirrors the real library's API shape (names, call protocol,
quirks) — that is what the paper's SLOC/programmability comparison is
about: using these *directly* is verbose; using them through the OpenCHK
directives is five lines (benchmarks/bench_sloc.py reproduces Tables 4–6).
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Optional

import numpy as np

from repro.core.comm import Communicator
from repro.core.storage import StorageConfig, StorageEngine, StoreReport


class Backend(abc.ABC):
    """Capabilities + the uniform entry points TCL drives."""

    name: str = "?"
    supports_diff: bool = False
    supports_dedicated_thread: bool = False
    max_level: int = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator):
        self.cfg = cfg
        self.comm = comm
        self.engine = StorageEngine(cfg, comm)
        self.stats: Dict[str, Any] = {"stores": 0, "loads": 0,
                                      "diff_fallbacks": 0, "bytes": 0}

    # --- uniform surface driven by TCL -------------------------------- #

    @abc.abstractmethod
    def tcl_store(self, named: Dict[str, np.ndarray], ckpt_id: int,
                  level: int, kind: str) -> StoreReport:
        ...

    @abc.abstractmethod
    def tcl_load(self) -> Optional[Dict[str, np.ndarray]]:
        ...

    def tcl_wait(self) -> None:
        """Fence asynchronous work (default: synchronous backend)."""

    def tcl_finalize(self) -> None:
        self.tcl_wait()
