"""TPU v5e hardware constants (the dry-run's compile target)."""

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (one active direction)
HBM_BYTES = 16 * 2**30          # 16 GiB per chip

CHIPS_PER_POD = 256
