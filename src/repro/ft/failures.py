"""Fault injection (evaluation methodology §6.1: deterministic fault at 90 %
of application progress, then restart until successful completion).

This is the *legacy* single-fault injector. The general harness —
scheduled, probabilistic and repeating faults at named sites across the
whole stack — lives in :mod:`repro.chaos.inject`; the env protocol here
(``OPENCHK_INJECT_AT``) is kept as a back-compat shim over it."""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional

from repro.chaos.inject import legacy_inject_at


class SimulatedFault(RuntimeError):
    """Process-abort analogue (the paper injects exceptions that abort)."""


@dataclass
class FaultInjector:
    total_steps: int
    at_progress: float = 0.9          # paper: faults at 90 % progress
    fire_once: bool = True
    hard: bool = False                # True → os._exit (real process abort)
    _fired: bool = False

    @property
    def fault_step(self) -> int:
        return max(1, int(self.total_steps * self.at_progress))

    def maybe_fail(self, step: int) -> None:
        if self._fired and self.fire_once:
            return
        if step == self.fault_step:
            self._fired = True
            if self.hard:
                os._exit(39)          # distinguishable abort code
            raise SimulatedFault(
                f"injected fault at step {step} "
                f"({self.at_progress:.0%} progress)")


def should_inject_from_env() -> Optional[float]:
    """Launcher protocol: OPENCHK_INJECT_AT=0.9 enables injection in child
    training processes (used by launch/train.py --supervise).

    Back-compat shim over :func:`repro.chaos.inject.legacy_inject_at`: a
    malformed value warns and returns None instead of raising ValueError
    at launcher import time (new code should arm ``OPENCHK_CHAOS`` specs
    at site ``train.step`` instead)."""
    return legacy_inject_at(os.environ)
