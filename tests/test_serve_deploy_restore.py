"""Checkpoint-as-deployment across a mesh change, on a forced-16-device
host: a training process stores two FULL level-4 checkpoints from a 4×4
mesh (v2 a fine-tune of v1 touching one small leaf), then a fresh serving
process on a **1×8 mesh** follows the catalog with :class:`FleetDeployer`
— the params are assembled directly onto the serving mesh (shard region
reads, no global host array), the v1→v2 rollout pulls only the chunk
delta (<30% of the full weight bytes, matching ``CatalogView.diff``'s
prediction), and the installed tree is bit-exact with the trained one."""

import subprocess
import sys
import textwrap

SUBPROC_COMMON = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.core.resharding import reshard_tree

    def orig_arrays(tuned=False):
        # w is small and fully retuned between v1 and v2; c is large and
        # untouched — the chunk delta of the publish is w's bytes only
        rng = np.random.default_rng(0)
        w = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
        c = rng.normal(size=(256, 256)).astype(np.float32)
        if tuned:
            w = w * 1.25 + 3.0
        return w, c

    def make_state(mesh, tuned=False):
        w, c = orig_arrays(tuned)
        state = {"params": {"w": jnp.asarray(w), "c": jnp.asarray(c)},
                 "step": jnp.int32(2 if tuned else 1)}
        sh = {"params": {"w": NamedSharding(mesh, P("data", "model")),
                         "c": NamedSharding(mesh, P("data", "model"))},
              "step": NamedSharding(mesh, P())}
        return reshard_tree(state, sh)

    def make_ctx(ckpt_dir):
        return CheckpointContext(CheckpointConfig(
            dir=ckpt_dir, backend="fti", dedicated_thread=False,
            objstore_cdc_min_bytes=512, objstore_cdc_avg_bytes=2048,
            objstore_cdc_max_bytes=8192))
""")

TRAIN_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    ckpt_dir = sys.argv[1]
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    ctx = make_ctx(ckpt_dir)
    ctx.store(make_state(mesh), id=1, level=4)
    ctx.store(make_state(mesh, tuned=True), id=2, level=4)
    ctx.shutdown()

    from repro.objstore.inspect import CatalogView
    view = CatalogView.from_root(os.path.join(ckpt_dir, "objstore"))
    assert view.ids() == [1, 2], view.ids()
    e1, e2 = view.entry(1), view.entry(2)
    assert e1.kind == "FULL" and e2.kind == "FULL"
    assert [f for f in e2.rank_files(0) if ".shard" in f.name], \\
        [f.name for f in e2.files]
    # the catalog already predicts a small publish: only w's chunks moved
    d = CatalogView.diff(e1, e2)
    assert 0 < d.ratio < 0.30, (d.bytes_delta, d.bytes_total)
    print("TRAIN-PUBLISH-OK")
""")

SERVE_SCRIPT = SUBPROC_COMMON + textwrap.dedent("""
    from repro.objstore.client import make_object_store
    from repro.objstore.inspect import CatalogView
    from repro.serve.deploy import FleetDeployer, Replica
    from repro.serve.engine import ServingEngine, WeightsHandle

    ckpt_dir = sys.argv[1]
    store = make_object_store("file:" + os.path.join(ckpt_dir, "objstore"))

    # the serving mesh is a *different* factorization of different size
    # (8 of the 16 devices) — deploy must land the 4x4-trained shards on it
    mesh_b = jax.make_mesh((1, 8), ("data", "model"))
    sh = NamedSharding(mesh_b, P("data", "model"))
    template = {"w": jax.device_put(jnp.zeros((64, 64), jnp.float32), sh),
                "c": jax.device_put(jnp.zeros((256, 256), jnp.float32), sh)}

    class _M:  # the engine only touches .decode_step at construction
        def decode_step(self, params, tok, caches, pos):
            return tok.astype(jnp.float32)[:, :, None], caches

    eng = ServingEngine(_M(), WeightsHandle(params=template),
                        batch=2, max_len=8)
    rep = Replica(name="serve0", engine=eng,
                  cache_root=os.path.join(ckpt_dir, "serve-cache"),
                  prefix="params")

    # the replica previously deployed v1 — its chunk cache is warm
    view = CatalogView.from_store(store)
    e1, e2 = view.entry(1), view.entry(2)
    rep.puller(store).pull(e1)

    dep = FleetDeployer(store, [rep])
    last = dep.run_until_converged()
    assert last == {"action": "converged", "entry": 2}, last
    assert eng.weights.entry_id == 2 and eng.weights.epoch >= 1

    # the v1 -> v2 rollout pulled only the chunk delta, and the measured
    # bytes agree with the catalog-level prediction
    fetched = dep.stats["bytes_fetched"]
    cached = dep.stats["bytes_cached"]
    assert cached > 0 and fetched + cached > 0
    measured = fetched / float(fetched + cached)
    predicted = CatalogView.diff(e1, e2).ratio
    assert measured < 0.30, (fetched, cached)
    assert abs(measured - predicted) < 0.10, (measured, predicted)

    # bit-exact across the mesh change, assembled onto the serve sharding
    w2, c2 = orig_arrays(tuned=True)
    np.testing.assert_array_equal(np.asarray(eng.params["w"]), w2)
    np.testing.assert_array_equal(np.asarray(eng.params["c"]), c2)
    assert eng.params["w"].sharding.is_equivalent_to(sh, 2)
    assert eng.params["c"].sharding.is_equivalent_to(sh, 2)
    print("SERVE-DEPLOY-RESHARD-OK")
""")


def test_serve_deploy_train_4x4_serve_1x8(tmp_path):
    """Forced-16-device lane: 4×4 training store → 1×8 serving fleet
    hot-swap — chunk-delta pull, bit-exact params, serve-mesh sharding."""
    d = str(tmp_path / "ck")
    r = subprocess.run([sys.executable, "-c", TRAIN_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "TRAIN-PUBLISH-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
    r = subprocess.run([sys.executable, "-c", SERVE_SCRIPT, d],
                       capture_output=True, text=True, timeout=540, cwd=".")
    assert "SERVE-DEPLOY-RESHARD-OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
