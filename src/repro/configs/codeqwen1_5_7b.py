"""codeqwen1.5-7b — qwen1.5-arch dense MHA [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.configs.base import ArchConfig, register

ARCH = register(ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13_440,
    vocab_size=92_416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
))
