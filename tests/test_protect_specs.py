"""The clause-carrying protection API: Protect spec validation, selector
resolution semantics (`*` vs `**`, overlap, first-match-wins), path
canonicalization regressions, and the deprecation shim for flat selectors."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import CheckpointConfig, CheckpointContext
from repro.core.protect import (
    CHK_DIFF,
    HDF5_GATE_MSG,
    Protect,
    _path_str,
    flatten_named,
    normalize_protects,
    resolve_specs,
    select,
)


def _ctx(tmp_path, name="p"):
    return CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / name), backend="fti", dedicated_thread=False))


# ------------------------------------------------------------------ #
# path canonicalization
# ------------------------------------------------------------------ #


def test_path_str_keeps_dots_and_quotes_in_keys():
    """Regression: strip("[]'\\".") ate leading/trailing dots and quotes
    from string keys — ".hidden" collided with "hidden", "w.q" lost its
    dot."""
    named, _ = flatten_named({
        "a": {".hidden": jnp.ones(2), "hidden": jnp.zeros(2),
              "w.q": jnp.ones(3), "wq": jnp.zeros(3)},
    })
    assert set(named) == {"a/.hidden", "a/hidden", "a/w.q", "a/wq"}


def test_path_str_mixed_key_types():
    named, _ = flatten_named({"params": {"groups": [
        {"attn": {"wq": jnp.ones(2)}},
        {"attn": {"wq": jnp.zeros(2)}},
    ]}})
    assert set(named) == {"params/groups/0/attn/wq",
                          "params/groups/1/attn/wq"}


def test_flatten_named_rejects_engineered_collision():
    """Two distinct keys that canonicalize identically must raise, not
    silently drop a leaf."""
    with pytest.raises(ValueError, match="duplicate pytree path"):
        flatten_named({"a": {"b": jnp.ones(2)}, "a/b": jnp.zeros(2)})


def test_path_str_quoted_content_preserved():
    from jax.tree_util import tree_flatten_with_path
    leaves, _ = tree_flatten_with_path({"'q'": jnp.ones(1)})
    assert _path_str(leaves[0][0]) == "'q'"


# ------------------------------------------------------------------ #
# selector semantics
# ------------------------------------------------------------------ #


NAMED = {
    "params/wq": 1, "params/attn/wq": 2, "params/attn/wo": 3,
    "opt/m": 4, "step": 5,
}


def test_star_does_not_cross_slashes():
    assert set(select(NAMED, ["params/*"])) == {"params/wq"}
    assert set(select(NAMED, ["params/**"])) == {
        "params/wq", "params/attn/wq", "params/attn/wo"}


def test_overlapping_patterns_select_once_first_spec_governs():
    specs = [Protect("params/attn/wq", compress="int8"),
             Protect("params/**")]
    out = resolve_specs(NAMED, specs)
    assert sorted(out) == ["params/attn/wo", "params/attn/wq", "params/wq"]
    # the leaf matched by both is selected once, governed by the first spec
    assert out["params/attn/wq"].compress == "int8"
    assert out["params/attn/wo"].compress is None


def test_resolve_specs_no_protects_selects_everything_clauseless():
    out = resolve_specs(NAMED, None)
    assert set(out) == set(NAMED)
    assert all(v is None for v in out.values())


def test_unmatched_selector_names_the_offender():
    with pytest.raises(ValueError, match=r"\['nope/\*\*'\] matched no leaves"):
        resolve_specs(NAMED, [Protect("params/**"), Protect("nope/**")])


def test_unmatched_selector_surfaces_through_store_and_load(tmp_path):
    state = {"params": {"w": jnp.arange(4.0)}, "step": jnp.int32(0)}
    ctx = _ctx(tmp_path)
    ctx.protect(Protect("params/**"), Protect("optt/**"))
    with pytest.raises(ValueError, match="optt"):
        ctx.store(state, id=1, level=1)
    with pytest.raises(ValueError, match="optt"):
        ctx.load(state)
    # a corrected protect keeps the context usable
    ctx.protect(Protect("params/**"), Protect("step"))
    assert ctx.store(state, id=1, level=1) is not None
    ctx.shutdown()


# ------------------------------------------------------------------ #
# Protect validation + the deprecation shim
# ------------------------------------------------------------------ #


def test_protect_clause_validation():
    with pytest.raises(ValueError, match="kind"):
        Protect("a/**", kind="SOMETIMES")
    with pytest.raises(ValueError, match="codec"):
        Protect("a/**", compress="zstd")
    with pytest.raises(ValueError, match="precision"):
        Protect("a/**", precision="int3")
    with pytest.raises(ValueError, match="h5py"):
        Protect("a/**", format="hdf5")      # missing dep is gated, not faked
    with pytest.raises(ValueError, match="axis"):
        Protect("a/**", axis={"batch": "one"})
    with pytest.raises(TypeError):
        normalize_protects([42])
    spec = Protect("a/**", kind=CHK_DIFF, compress="int8", precision="bf16")
    assert spec.clauses() == {"kind": CHK_DIFF, "compress": "int8",
                              "precision": "bf16"}


def test_hdf5_gate_raises_at_spec_validation_time():
    """The missing-h5py gate fires when the spec is *constructed* — the
    user's ``ctx.protect(Protect(..., format="hdf5"))`` line — never deep
    inside Pack where the traceback would point at checkpoint internals.
    The message is pinned verbatim (it names the dependency and the
    CHK5 equivalence, the paper's §4.2.4 portability argument)."""
    with pytest.raises(ValueError) as ei:
        Protect("params/**", format="hdf5")
    assert str(ei.value) == HDF5_GATE_MSG
    assert "h5py" in HDF5_GATE_MSG and "chk5" in HDF5_GATE_MSG
    # no store machinery involved: a context is never even constructed,
    # and a valid format clause still passes validation
    assert Protect("params/**", format="chk5").clauses() == {
        "format": "chk5"}


def test_flat_selector_strings_shim_to_clauseless_specs():
    with pytest.warns(DeprecationWarning, match="deprecated"):
        specs = normalize_protects(["params/**", "step"])
    assert [s.selector for s in specs] == ["params/**", "step"]
    assert all(s.clauses() == {} for s in specs)


def test_legacy_string_protect_still_roundtrips(tmp_path):
    state = {"params": {"w": jnp.arange(4.0)}, "opt": {"m": jnp.ones(4)},
             "step": jnp.int32(7)}
    ctx = _ctx(tmp_path, "legacy")
    with pytest.warns(DeprecationWarning):
        ctx.protect("params/**", "step")
    ctx.store(state, id=1, level=1)
    ctx.shutdown()
    ctx2 = _ctx(tmp_path, "legacy")
    with pytest.warns(DeprecationWarning):
        ctx2.protect("params/**", "step")
    got = ctx2.load({"params": {"w": jnp.zeros(4)}, "opt": {"m": jnp.zeros(4)},
                     "step": jnp.int32(0)})
    assert ctx2.restarted
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(4.0))
    assert int(got["step"]) == 7
    assert float(got["opt"]["m"][0]) == 0.0       # unprotected → template
    ctx2.shutdown()


def test_legacy_positional_tcl_protocol_still_works(tmp_path):
    """Backend.tcl_store(named, id, level, kind) — the pre-request call
    protocol — must keep working for native-API callers."""
    from repro.backends.registry import make_backend
    from repro.core.comm import LocalComm
    from repro.core.storage import CHK_FULL, StorageConfig
    b = make_backend(StorageConfig(root=str(tmp_path / "lp")),
                     LocalComm(str(tmp_path / "lp" / "nl")), "fti",
                     dedicated_thread=False)
    b.tcl_store({"x": np.arange(6.0)}, 1, 1, CHK_FULL)
    got = b.tcl_load()
    np.testing.assert_array_equal(got["x"], np.arange(6.0))
    b.tcl_finalize()
