"""Fig. 7 analogue: differential-checkpoint overhead vs dirty-data ratio n_d.

Sweeps n_d ∈ {0, 0.1, …, 1.0} on a protected array, measuring store wall
time and payload bytes for CHK_DIFF vs CHK_FULL. The paper's model predicts
a linear relationship with break-even near n_d ≈ 0.95 (their I/O-to-hash
cost ratio); our break-even lands where this container's hash-rate/IO-rate
ratio puts it — the *shape* (linear in n_d, clear break-even) is the
reproduced claim, and the engine's auto-promote threshold rides on it.
"""
from __future__ import annotations

import os
import shutil
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.backends.fti import FTIBackend
from repro.core.comm import LocalComm
from repro.core.storage import StorageConfig

MB = 32                      # protected size (MiB) — CPU-friendly
BLOCK = 65_536


def _one_ratio(nd: float, root: str) -> Dict[str, float]:
    shutil.rmtree(root, ignore_errors=True)
    n = MB * 2**20 // 4
    rng = np.random.RandomState(0)
    arr = jnp.asarray(rng.randn(n).astype(np.float32))
    fti = FTIBackend(StorageConfig(root=root, block_bytes=BLOCK,
                                   promote_threshold=1.1),   # never promote
                     LocalComm(os.path.join(root, "nl")),
                     dedicated_thread=False)
    fti.protect(0, "arr", arr)
    rep_full0 = fti.checkpoint(1, level=1)               # base full

    # dirty exactly nd of the blocks
    n_blocks = (n * 4 + BLOCK - 1) // BLOCK
    dirty = rng.choice(n_blocks, size=int(round(nd * n_blocks)),
                       replace=False)
    arr2 = np.asarray(arr).copy()
    for b in dirty:
        arr2[b * BLOCK // 4] += 1.0
    fti.protect(0, "arr", jnp.asarray(arr2))

    t0 = time.time()
    rep_diff = fti.checkpoint(2, level=1, differential=True)
    t_diff = time.time() - t0

    t0 = time.time()
    rep_full = fti.checkpoint(3, level=1, differential=False)
    t_full = time.time() - t0
    fti.finalize()
    shutil.rmtree(root, ignore_errors=True)
    return {
        "nd": nd,
        "t_diff_s": t_diff,
        "t_full_s": t_full,
        "overhead_vs_full_s": t_diff - t_full,
        "bytes_diff": rep_diff.bytes_payload,
        "bytes_full": rep_full.bytes_payload,
        "measured_dirty_ratio": rep_diff.dirty_ratio,
    }


def run() -> List[Dict[str, float]]:
    return [_one_ratio(nd, f"/tmp/bd-{int(nd * 100)}")
            for nd in (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 1.0)]


def break_even(results) -> float:
    """First nd where diff stops being cheaper than full."""
    for r in results:
        if r["t_diff_s"] >= r["t_full_s"]:
            return r["nd"]
    return 1.0


def rows():
    res = run()
    out = []
    for r in res:
        out.append((f"differential/nd={r['nd']:.2f}_t_diff",
                    r["t_diff_s"] * 1e6, r["bytes_diff"]))
        out.append((f"differential/nd={r['nd']:.2f}_t_full",
                    r["t_full_s"] * 1e6, r["bytes_full"]))
    out.append(("differential/break_even_nd", 0.0, break_even(res)))
    return out


if __name__ == "__main__":
    for name, us, v in rows():
        print(f"{name},{us},{v}")
