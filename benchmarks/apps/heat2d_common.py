"""2D heat-equation simulation — the paper's Heat benchmark (pure MPI there,
pure JAX here). Shared physics for all four CR variants; the variants differ
ONLY in their checkpoint/restart code, which is what Tables 1/4/5/6 measure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_grid(n: int) -> jnp.ndarray:
    g = jnp.zeros((n, n), jnp.float32)
    g = g.at[0, :].set(100.0)          # hot boundary
    g = g.at[-1, :].set(-25.0)
    return g


@jax.jit
def heat_step(g: jnp.ndarray) -> jnp.ndarray:
    inner = 0.25 * (g[:-2, 1:-1] + g[2:, 1:-1] + g[1:-1, :-2] + g[1:-1, 2:])
    return g.at[1:-1, 1:-1].set(inner)


def checksum(g) -> float:
    return float(jnp.sum(jnp.abs(g)))
