"""Elastic rescale-on-restart: resume a run on a different world size.

Combines the manifest search (storage), elastic resharding (core/resharding)
and the data-cursor semantics (data/synthetic): the restarted job reads each
new rank's slice of the saved global state, so a 16-host job can resume on
12 hosts after losing a rack — the paper's restart semantics generalized to
changing topology (future-work direction made concrete).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import manifest as mf
from repro.core.resharding import elastic_restore

_RANK_FILE_RE = re.compile(r"^rank(\d+)\.")


def _materialize_catalog_ckpt(tier, ckpt_id: int) -> bool:
    """Pull every rank's file set of *ckpt_id* out of a catalog-backed
    tier into its cache dir, so ``elastic_restore`` can assemble slices
    from a run whose directory tiers are gone.  The rank set comes from
    the catalog entry's file names (the old world need not be known)."""
    from repro.objstore.catalog import Catalog
    from repro.objstore.client import ObjectStoreError

    try:
        entry = tier.catalog.entry(ckpt_id)
        if entry is None:
            return False
        ranks = sorted({
            int(m.group(1))
            for name in Catalog.file_entries(entry)
            if (m := _RANK_FILE_RE.match(name))})
        ok = False
        for r in ranks:
            got = tier.recover(ckpt_id, r, tier.root,
                               entry.get("manifest", {}), {})
            ok = ok or got is not None
        return ok
    except (AttributeError, ObjectStoreError, ValueError, KeyError):
        return False


def find_latest_sharded(roots, tiers=()) -> Optional[Tuple[str, int]]:
    """Newest committed checkpoint dir across tier roots → (dir, id).

    ``tiers`` extends discovery to catalog-backed tiers (the objstore L4):
    their ids come from ``tier.list_ids()``, and a winning catalog id is
    materialized into the tier's cache dir before it is returned — a run
    whose directory tiers were wiped still rescales from the bucket."""
    best: Optional[Tuple[int, str, object]] = None
    for root in roots:
        for i in mf.list_committed(root):
            if best is None or i > best[0]:
                best = (i, mf.ckpt_dir(root, i), None)
    for tier in tiers:
        for i, root in tier.list_ids():
            if best is None or i > best[0]:
                best = (i, mf.ckpt_dir(root, i), tier)
    if best is None:
        return None
    ckpt_id, d, tier = best
    if tier is not None and not _materialize_catalog_ckpt(tier, ckpt_id):
        # catalog id unusable (outage / missing files): fall back to the
        # best directory-backed checkpoint
        return find_latest_sharded(roots)
    return d, ckpt_id


def rescale_restore(roots, new_world: int, new_rank: int, tiers=()
                    ) -> Optional[Tuple[Dict[str, np.ndarray], int]]:
    got = find_latest_sharded(roots, tiers)
    if got is None:
        return None
    d, ckpt_id = got
    return elastic_restore(d, new_world, new_rank), ckpt_id
