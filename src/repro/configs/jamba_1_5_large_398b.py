"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Layer pattern: each
group of 8 layers = 1 attention + 7 mamba; MoE MLP every other layer.
Mamba layers use the chunked SSD formulation (TPU adaptation — DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

ARCH = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    hybrid_pattern=("attn",) + ("mamba",) * 7,
    use_rope=False,      # Jamba: no explicit positional encoding
    moe=MoEConfig(n_experts=16, top_k=2, every_k_layers=2),
    ssm=SSMConfig(kind="mamba", d_state=16, head_dim=64, expand=2,
                  conv_width=4, chunk=64),
    source="arXiv:2403.19887; hf",
))
