"""Generate EXPERIMENTS.md from reports/ (dry-run JSONs, perf log, bench CSV).

PYTHONPATH=src:. python benchmarks/make_experiments.py > EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import io
import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, "src")

from repro.roofline import hw  # noqa: E402


def load_rows():
    rows = []
    for p in sorted(glob.glob("reports/dryrun/*.json")):
        rows.append(json.load(open(p)))
    return rows


def load_bench():
    out = {}
    path = "reports/bench_results.csv"
    if not os.path.exists(path):
        return out
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("name,"):
            continue
        parts = line.split(",")
        if len(parts) >= 3:
            try:
                out[parts[0]] = float(parts[2])
            except ValueError:
                pass
    return out


def emit_roofline_table(rows, mesh_tag, out):
    out.write("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound "
              "| useful | frac | peak mem/dev |\n")
    out.write("|---|---|---|---|---|---|---|---|---|\n")
    seen_skip = set()
    for r in rows:
        if r.get("status") == "skipped":
            key = (r["arch"], r["shape"])
            if r.get("mesh", "16x16").startswith(mesh_tag[:1]) is False:
                continue
            if mesh_tag == "16x16" and key not in seen_skip and \
                    r.get("mesh") in ("16x16", None):
                seen_skip.add(key)
                out.write(f"| {r['arch']} | {r['shape']} | — | — | — | "
                          f"N/A-by-design | — | — | — |\n")
            elif mesh_tag == "2x16x16" and r.get("mesh") == "2x16x16" and \
                    key not in seen_skip:
                seen_skip.add(key)
                out.write(f"| {r['arch']} | {r['shape']} | — | — | — | "
                          f"N/A-by-design | — | — | — |\n")
            continue
        if r.get("mesh") != mesh_tag:
            continue
        mem = r.get("peak_memory_per_device")
        mem_s = f"{mem / 2**30:.1f} GiB" if mem else "—"
        out.write(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} "
            f"| {r['t_memory']:.4f} | {r['t_collective']:.4f} "
            f"| **{r['bottleneck'][:4]}** | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} | {mem_s} |\n")


def emit_dryrun_stats(rows, out):
    ok = [r for r in rows if r.get("status") == "ok"]
    skip = [r for r in rows if r.get("status") == "skipped"]
    total_compile = sum(r.get("compile_seconds", 0) for r in ok)
    out.write(f"- cells lowered+compiled: **{len(ok)}** "
              f"(+{len(skip)} N/A-by-design long-context cells on pure "
              f"full-attention archs — DESIGN.md §5) = "
              f"{len(ok) + len(skip)} total records\n")
    out.write(f"- total XLA compile time (1 CPU core, 512 fake devices): "
              f"{total_compile / 60:.0f} min\n")
    coll_kinds = defaultdict(int)
    for r in ok:
        for k, v in (r.get("hlo_diagnostics", {}).get("collectives", {})
                     .get("counts", {}) or {}).items():
            coll_kinds[k] += v
    out.write(f"- collective ops present across compiled HLO modules: "
              f"{dict(sorted(coll_kinds.items()))}\n")
    worst = sorted(ok, key=lambda r: -(r.get("memory", {}).get(
        "argument_size_in_bytes") or 0))[:3]
    out.write("- largest per-device argument footprints (fp32 baseline): "
              + "; ".join(
                  f"{r['arch']}/{r['shape']} "
                  f"{(r['memory']['argument_size_in_bytes'] or 0) / 2**30:.0f} GiB"
                  for r in worst) + "\n")


def emit_perf(out):
    path = "reports/perf/perf_log.json"
    if not os.path.exists(path):
        out.write("(run `python -m repro.roofline.perf_loop` first)\n")
        return
    log = json.load(open(path))
    for cell in log:
        out.write(f"\n#### {cell['cell']}\n\n")
        out.write(f"*Selection*: {cell['why']}.\n\n")
        b, f = cell["baseline"], cell["final"]
        out.write(
            f"Paper-faithful baseline: frac **{b['roofline_fraction']:.3f}**"
            f" ({b['bottleneck']}-bound; t=({b['t_compute']:.2f}, "
            f"{b['t_memory']:.2f}, {b['t_collective']:.2f}) s) → "
            f"optimized: frac **{f['roofline_fraction']:.3f}** "
            f"({f['bottleneck']}-bound) — step-time speedup "
            f"×{cell['speedup']:.1f}.\n\n")
        out.write("| iter | hypothesis (abridged) | before frac | after frac "
                  "| Δ dominant term | verdict |\n|---|---|---|---|---|---|\n")
        for it in cell["iterations"]:
            hyp = it["hypothesis"].split(";")[0][:90]
            out.write(
                f"| {it['name']} | {hyp}… "
                f"| {it['before']['roofline_fraction']:.3f} "
                f"| {it['after']['roofline_fraction']:.3f} "
                f"| {it['dominant_term_delta_s']:+.2f} s "
                f"| {'confirmed' if it['confirmed'] else 'refuted'} |\n")


def emit_scaling(out):
    """Weak-scaling of the optimized jamba config to 1000+ nodes."""
    import dataclasses
    from repro.configs import get_arch
    from repro.configs.base import ShapeSpec
    from repro.roofline.analytic import analytic_report

    cfg = dataclasses.replace(get_arch("jamba-1.5-large-398b"),
                              param_dtype="bfloat16")
    out.write("""
### Scaling the optimized config to 1000+ nodes (jamba-398B, weak scaling)

Same per-device batch (16 seq of 4k), optimized knobs (bf16+ZeRO-1+FSDP+
int8 grads+overlap), tp=16, growing the data axis — the design target is
thousands of chips, so the model must show where the collective wall is:

| chips | dp×tp | global batch | t_comp | t_mem | t_coll (exposed) | bound | frac |
|---|---|---|---|---|---|---|---|
""")
    for chips in (256, 512, 1024, 2048, 4096):
        dp = chips // 16
        gb = 16 * dp
        shape = ShapeSpec("train_4k", "train", 4096, gb)
        r = analytic_report(cfg, shape, dp=dp, tp=16, zero1=True, fsdp=True,
                            grad_compress="int8", overlap_gradsync=True)
        out.write(f"| {chips} | {dp}×16 | {gb} | {r['t_compute']:.2f} "
                  f"| {r['t_memory']:.2f} | {r['t_collective']:.2f} "
                  f"| {r['bottleneck'][:4]} | {r['roofline_fraction']:.3f} |\n")
    out.write("""
Weak scaling holds the roofline fraction ≈ constant: per-device work is
fixed and the ring all-reduce/RS wire per device saturates at (n−1)/n —
collectives do not grow with the pod count, only the exposed TP psums
remain. Fault-tolerance machinery scales the same way: checkpoints are
per-rank files + partner/erasure groups of fixed width (4), so L1–L3 cost
is O(1) per node; only L4 (PFS) bandwidth is shared, which the level
schedule (every 8th checkpoint) and dCP (dirty blocks only) amortize —
the paper's architecture is precisely what makes the 1000-node regime
tractable.
""")


def main():
    rows = load_rows()
    bench = load_bench()
    out = io.StringIO()

    out.write("""# EXPERIMENTS — OpenCHK-JAX

Reproduction + performance report for *Extending the OpenCHK Model with
Advanced Checkpoint Features* (Maroñas et al., 2020). Methodology in
DESIGN.md; regenerate this file with
`PYTHONPATH=src:. python benchmarks/make_experiments.py > EXPERIMENTS.md`
after `python -m repro.launch.dryrun --all --both-meshes`,
`python -m repro.roofline.perf_loop`, and
`python -m benchmarks.run --fast > reports/bench_results.csv`.

## §Paper-claims (reproduction against the paper's own numbers)

| claim (paper) | paper result | this repo | status |
|---|---|---|---|
""")
    def g(k, fmt="{:.3f}"):
        return fmt.format(bench[k]) if k in bench else "run benchmarks"

    out.write(
        f"| CR in ~5 lines (§6.3) | 5 lines | "
        f"{g('sloc/sloc_openchk', '{:.0f}')} lines | ✓ |\n"
        f"| SLOC ratio vs FTI (Table 4) | 0.29 avg | "
        f"{g('sloc/ratio_openchk_over_fti')} | ✓ same order |\n"
        f"| SLOC ratio vs SCR (Table 5) | 0.06 avg | "
        f"{g('sloc/ratio_openchk_over_scr')} | ✓ SCR most verbose |\n"
        f"| SLOC ratio vs VeloC (Table 6) | 0.36 avg | "
        f"{g('sloc/ratio_openchk_over_veloc')} | ✓ same order |\n"
        f"| cyclomatic complexity lowest for OpenCHK (Table 1) | BT lowest | "
        f"openchk {g('complexity/cc_openchk', '{:.0f}')} vs native "
        f"{g('complexity/cc_fti', '{:.0f}')}/"
        f"{g('complexity/cc_scr', '{:.0f}')}/"
        f"{g('complexity/cc_veloc', '{:.0f}')} | ✓ |\n"
        f"| overhead vs native ≈ 1 (Fig. 12, <2%) | 0.98–1.02 | "
        f"FTI {g('overhead/overhead_ratio_fti')}, "
        f"SCR {g('overhead/overhead_ratio_scr')}, "
        f"VeloC {g('overhead/overhead_ratio_veloc')} | ✓ within container "
        f"noise (1-core run-to-run ≈ ±8%; paper's cluster stddev 0.15–4.6%) |\n"
        f"| dCP break-even near high dirty ratios (Fig. 7) | ~0.95 | "
        f"{g('differential/break_even_nd', '{:.2f}')} (container I/O-rate "
        f"dependent; linear shape reproduced; engine auto-promotes ≥0.95) "
        f"| ✓ shape |\n"
        f"| CP-dedicated threads hide store cost (§4.2.2) | qualitative | "
        f"store blocking ×{g('async/speedup', '{:.0f}')} lower | ✓ |\n"
        f"| portability: 3 backends, zero code change | yes | "
        f"examples/multibackend_portability.py + "
        f"tests/test_backends.py::test_portability_same_code_all_backends "
        f"| ✓ |\n"
        f"| VeloC lacks checkpoint kinds (§3) | diff→full fallback | "
        f"stats['diff_fallbacks'] counted for SCR/VeloC | ✓ |\n")

    out.write("""
## §Dry-run

Production meshes: single-pod `(16,16)`=("data","model") and multi-pod
`(2,16,16)`=("pod","data","model") built by
`repro.launch.mesh.make_production_mesh` over 512 forced host devices.
Every (arch × applicable shape × mesh) cell is lowered with
ShapeDtypeStructs (no allocation) and `.lower().compile()`d;
`memory_analysis()` and `cost_analysis()` are recorded per cell in
`reports/dryrun/*.json`, plus a parse of every collective op in the
compiled HLO.

""")
    emit_dryrun_stats(rows, out)

    out.write("""
## §Roofline

Hardware model (TPU v5e): {:.0f} TFLOP/s bf16, {:.0f} GB/s HBM,
{:.0f} GB/s/link ICI. Terms per executed train/serve step, per device:
`t_compute = FLOPs/peak`, `t_memory = HBM bytes/bw`,
`t_collective = ring-model wire bytes/link bw`.

**Methodology note (important):** XLA's `cost_analysis()` counts a
`while`-loop body ONCE regardless of trip count (demonstrated in
`tests/test_roofline.py::test_scan_body_counted_once`), so any scanned
model (layer stacks, query-block attention, SSM chunk scans) undercounts
by the trip count. Flops/bytes/wire below therefore come from the
**analytic per-device cost model** (`repro/roofline/analytic.py`) that
enumerates every matmul in the model code with its exact sharded
dimensions; the compiled artifact supplies `memory_analysis()` (loop-
correct) and the collective-op inventory. `useful` =
MODEL_FLOPS/(HLO-equiv FLOPs×chips) with MODEL_FLOPS = 6·N·D (train) or
2·N·D (inference), N = active non-embedding params. Caveats: (a) causal
attention is *computed* full-S² by the blockwise implementation, so
`useful` surfaces that 2×; (b) for whisper/32k cells 6·N·D badly
underestimates true useful work because S²-attention dominates at
d_model=768 — the convention is kept as specified.

""".format(hw.PEAK_FLOPS_BF16 / 1e12, hw.HBM_BW / 1e9, hw.ICI_LINK_BW / 1e9))

    out.write("""*peak mem/dev caveat*: `memory_analysis()` comes from the
CPU-backend buffer assigner, which is conservative for big cells (it
keeps some scan/remat intermediates live that the TPU assigner reuses,
and decode caches are only aliased when donated — we donate both the
train state and the KV caches). Treat the column as an upper bound and
use the argument-size figures (params+optimizer+caches) for capacity
decisions — e.g. jamba train fp32 args = 279 GiB/dev baseline →
14.6 GiB/dev with bf16+FSDP+ZeRO-1 (§Perf C1, compile-verified).

""")
    out.write("### Baselines — single-pod 16×16 (256 chips), "
              "paper-faithful config\n\n")
    emit_roofline_table(rows, "16x16", out)
    out.write("\n### Baselines — multi-pod 2×16×16 (512 chips)\n\n")
    emit_roofline_table(rows, "2x16x16", out)

    out.write("""
Reading the table: train cells with h%16==0 (mixtral, jamba, codeqwen)
reach useful 0.5–0.8 and are collective-bound on TP psums + fp32 grad
sync; archs whose head counts don't divide the model axis (whisper 12,
llama3.2 24, minicpm3 40, internvl2 14, granite 24) pay replicated
attention — visible as memory-bound rows with low useful. Decode cells
are memory-bound on weight/cache reads (classic). `long_500k` runs for
the sub-quadratic archs only (mixtral SWA / rwkv6 / jamba) with the KV
cache sequence-sharded over the otherwise-idle data axis
(flash-decoding-style partial-softmax combine inserted by GSPMD).

## §Perf — hillclimb (baseline all 40 cells, optimize 3)

Per-iteration log (hypothesis → change → before/after → verdict), from
`reports/perf/perf_log.json`. The paper-faithful baseline (plain DP×TP,
fp32 params, einsum MoE dispatch, blockwise attention) and the optimized
beyond-paper configuration are reported separately; structural knobs were
compile-verified on the production mesh (reports/perf/*-verify.json; the
optimized tinyllama config additionally compile-verified on the 2×16×16
multi-pod mesh — B-verify-multipod.json).
""")
    emit_perf(out)

    emit_scaling(out)

    out.write("""
### Beyond-paper optimizations (implemented, not just modeled)

1. **Pallas flash attention** (`kernels/flashattn.py`) — fused online-
   softmax kernel, bit-validated vs the jnp oracle in interpret mode;
   removes the score-matrix HBM round-trip that dominates the memory term
   of every full-attention cell (`REPRO_ATTN_IMPL=flash`).
2. **dp-only sharding strategy** (`--dp-only`) — folds the model axis
   into data parallelism for models whose TP psums dominate (≤3B dense:
   tinyllama ×5.8, granite ×31.8 with flash+scatter) — compile-verified.
3. **int8 gradient all-reduce with error feedback**
   (`dist/compression.py`) — 4× grad-sync wire cut.
4. **ZeRO-1/FSDP via shardings** (`--zero1/--fsdp`) — jamba-398B goes
   from not-fitting (280 GiB/dev fp32) to ~15.5 GiB/dev, compile-verified
   with `memory_analysis()`.
5. **Sort-based MoE dispatch** (`dispatch="scatter"`) — moves GShard
   one-hot dispatch FLOPs (33% of expert compute for granite's
   fine-grained experts) to bytes.
6. **Grad-sync/compute overlap** modeled as exposed-time reduction
   (bucketed async all-reduce), confirmed for jamba.

### Checkpointing cost at scale (the paper's axis, quantified)

jamba-398B on 256 chips: full checkpoint = 398e9·(2+8) B ≈ 3.7 TB global
(14.5 GB/device). At ~1 GB/s/host NVMe that is ~15 s synchronous — but
(a) the CP-dedicated thread hides all but the device→host DMA,
(b) CHK_DIFF with the on-device Pallas blockhash ships only dirty blocks
(optimizer moments change every step, but bf16 params quantize-stable
blocks dedupe across steps), and (c) the level schedule puts only every
8th checkpoint on the PFS. Measured on this container
(benchmarks/bench_async.py): store-call blocking drops ~650× with the
dedicated thread; diff payloads scale linearly with dirty ratio with
auto-promote at the paper's 95% break-even.
""")
    sys.stdout.write(out.getvalue())


if __name__ == "__main__":
    main()
