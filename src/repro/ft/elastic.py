"""Elastic rescale-on-restart: resume a run on a different world size.

Combines the manifest search (storage), elastic resharding (core/resharding)
and the data-cursor semantics (data/synthetic): the restarted job reads each
new rank's slice of the saved global state, so a 16-host job can resume on
12 hosts after losing a rack — the paper's restart semantics generalized to
changing topology (future-work direction made concrete).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import manifest as mf
from repro.core.resharding import elastic_restore


def find_latest_sharded(roots) -> Optional[Tuple[str, int]]:
    """Newest committed checkpoint dir across tier roots → (dir, id)."""
    best: Optional[Tuple[int, str]] = None
    for root in roots:
        for i in mf.list_committed(root):
            if best is None or i > best[0]:
                best = (i, mf.ckpt_dir(root, i))
    if best is None:
        return None
    return best[1], best[0]


def rescale_restore(roots, new_world: int, new_rank: int
                    ) -> Optional[Tuple[Dict[str, np.ndarray], int]]:
    got = find_latest_sharded(roots)
    if got is None:
        return None
    d, ckpt_id = got
    return elastic_restore(d, new_world, new_rank), ckpt_id
