"""The staged checkpoint pipeline: Plan → Pack → Place → Commit.

Every store — synchronous or CP-dedicated-thread, FULL, DIFF or
incremental, any backend — flows through the same four stages:

    Plan    kind/level resolution, the diff→full promote decision, and the
            only work that must stay on the calling thread: the device→host
            snapshot and (for CHK_DIFF) the on-device blockhash/diffpack
            kernels.  Runs in submission order, so back-to-back asynchronous
            DIFF stores see a consistent digest chain.  FULL stores on
            diff-capable backends owe digest bookkeeping too, but it is
            *deferred* to the tail behind a fence (``_wait_digest_fence``)
            — a DIFF planned after an in-flight FULL waits for that FULL's
            digests instead of the training thread paying a synchronous
            full-tree blockhash it may never need.
    Pack    serialization of the planned payload into the staging dir
            (``ckpt-<id>.tmp``) as a CHK5 container.
    Place   the tier stack for the level applies redundancy
            (partner replica, erasure parity, …) — see core/tiers.py.
    Commit  per-rank status allgather, manifest write, atomic ``.tmp`` →
            final rename, diff-chain-aware retention pruning.

``plan()`` is cheap and synchronous; ``finish()`` (= pack + place + commit)
is the asynchronous tail a CP-dedicated thread runs.  File-mode backends
(SCR ``route_file``) and incremental stores that produced their payload
outside Pack enter at Place via ``finish_external()`` — so *no* caller
re-implements placement or commit.

Restart search order: L1 → L2 (partner) → L3 (erasure reconstruct) → L4,
newest checkpoint id first — exactly FTI's recovery ladder, now expressed
as iteration over the tier ladder (the tier that produced the payload is
reported as ``recovered_via`` in the restored metadata).
"""
from __future__ import annotations

import io
import os
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.chaos import inject as chaos
from repro.core import manifest as mf
from repro.core.comm import Communicator
from repro.core.diff import (
    DiffEngine,
    LeafDelta,
    apply_delta,
    leaf_to_u32_flat,
    u32_flat_to_leaf,
)
from repro.core.formats import CHK5Reader, CHK5Writer
from repro.core.protect import CHK_DIFF, CHK_FULL, Protect, to_host
from repro.core.resharding import (
    ShardedLeafRef,
    ShardSnapshot,
    resolve_shard_refs,
    split_sharded,
    write_shard_files,
)
from repro.core.tiers import (
    PackTier,
    Tier,
    TierContext,
    clause_attrs,
    decode_leaf,
    default_pack_tiers,
    default_tier_stacks,
    pack_named,
    recovery_ladder,
)
from repro.redundancy.groups import Topology
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace


@dataclass
class StorageConfig:
    root: str                                  # base dir for this run
    block_bytes: int = 65_536
    keep_last_full: int = 2
    group_size: int = 4
    erasure_scheme: str = "rs"                 # "rs" | "xor"
    rs_parity: int = 2
    promote_threshold: float = 0.95            # diff→full break-even (Fig. 7)
    ranks_per_node: int = 1
    custom_groups: Optional[dict] = None       # SCR-style group overrides
    sharded_store: bool = True                 # shard-local Plan snapshots
    shard_writers: int = 4                     # parallel shard-file writers
    # --- object-store L4 (repro.objstore) ---------------------------- #
    objstore: bool = True                      # compose ObjectStoreTier at L4
    objstore_url: Optional[str] = None         # None → file:<root>/objstore
    objstore_chunk_bytes: int = 1 << 20        # fixed-mode chunk size
    objstore_chunking: str = "cdc"             # "cdc" | "fixed"
    objstore_cdc_min_bytes: int = 256 << 10    # CDC lower cut bound
    objstore_cdc_avg_bytes: int = 1 << 20      # CDC target average
    objstore_cdc_max_bytes: int = 4 << 20      # CDC forced-cut bound
    objstore_transfers: int = 4                # parallel upload threads
    objstore_keep_last: Optional[int] = None   # retention: newest N entries
    objstore_keep_every: Optional[int] = None  # retention: pin id % K == 0

    @property
    def global_root(self) -> str:
        return os.path.join(self.root, "global")


@dataclass
class StoreReport:
    ckpt_id: int
    level: int
    kind: str
    bytes_payload: int
    seconds: float
    dirty_ratio: Optional[float] = None
    promoted_full: bool = False
    #: id of the ``pipeline.store`` span that produced this report (None
    #: when tracing is disabled) — lets chktrace join goodput accounting
    #: back onto the timeline
    span_id: Optional[int] = None


@dataclass
class StoreRequest:
    """What the caller wants checkpointed — the one object that rides the
    whole stack (directive → TCL → backend → Plan) in place of the old
    positional protocols.

    The directive layer fills ``tree`` + ``protects``; TCL resolves them
    into ``named`` (selected leaves) + ``specs`` (path → governing
    ``Protect``); the backend stamps ``level``/``diff_supported``; Plan
    consumes the result.  Callers below the directive layer may also build
    one directly with ``named`` (host or device arrays)."""
    named: Optional[Dict[str, Any]] = None     # device or host arrays
    ckpt_id: int = 0
    level: int = 1
    kind: str = CHK_FULL
    extra_meta: Optional[Dict[str, Any]] = None
    diff_supported: bool = True
    tree: Any = None                           # unflattened state (directive)
    protects: Optional[List[Protect]] = None   # clause specs (directive)
    specs: Optional[Dict[str, Optional[Protect]]] = None  # resolved by TCL

    @property
    def wants_diff(self) -> bool:
        """Does any part of this request ask for a DIFF checkpoint?  (The
        capability/fallback accounting backends do — paper §3.)"""
        if self.kind == CHK_DIFF:
            return True
        for s in (self.specs or {}).values():
            if s is not None and s.kind == CHK_DIFF:
                return True
        return any(s.kind == CHK_DIFF for s in (self.protects or []))


@dataclass
class LoadRequest:
    """What the caller wants restored (transparent-restart input): the
    template tree plus the protection specs restricting which leaves the
    checkpoint must supply."""
    template: Any = None
    protects: Optional[List[Protect]] = None
    specs: Optional[Dict[str, Optional[Protect]]] = None  # resolved by TCL


@dataclass
class Plan:
    """Resolved store decision (output of Plan, input to Pack/Place/Commit).

    After ``plan()`` returns, the checkpoint content is frozen host-side
    (FULL: host snapshot; DIFF: compacted dirty blocks) — the remaining
    stages touch no device state and may run on a CP-dedicated thread."""
    ckpt_id: int
    level: int
    kind: str
    tiers: List[Tier]
    root: str
    attrs: Dict[str, Any]                      # payload container attrs
    extra: Dict[str, Any]                      # caller meta → manifest
    named_host: Optional[Dict[str, np.ndarray]] = None   # FULL payload
    sharded: Optional[Dict[str, ShardSnapshot]] = None   # shard-local FULL
    deltas: Optional[List[LeafDelta]] = None             # DIFF payload
    specs: Optional[Dict[str, Optional[Protect]]] = None  # clause specs
    dirty_ratio: Optional[float] = None
    promoted_full: bool = False
    #: dataset name → layout-reuse key for the fused Pack → chunk-stream
    #: path (device-digest-derived; set by finish() once digests are
    #: current, consumed by CHK5Writer.region_keys)
    reuse_keys: Optional[Dict[str, str]] = None
    t0: float = field(default_factory=time.time)
    plan_seconds: float = 0.0          # time spent in plan() itself
    digest_epoch: int = -1             # DIFF only: chain epoch at plan time
    pending_digests: Optional["_PendingDigests"] = None   # FULL: deferred


@dataclass
class _PendingDigests:
    """FULL-store digest bookkeeping deferred to the async tail.

    Holds the *device* leaves until the CP thread hashes them; ``done`` is
    the fence a later DIFF plan waits on so it never reads digests that
    describe the state before an in-flight FULL."""
    named: Optional[Dict[str, Any]]
    done: threading.Event = field(default_factory=threading.Event)


@dataclass
class Packed:
    """A serialized payload sitting in the staging dir (output of Pack).
    ``shard_files`` lists the sibling shard files of a sharded store —
    the multi-file set commits atomically with the container."""
    stage_dir: str
    path: str
    nbytes: int
    shard_files: List[str] = field(default_factory=list)


class CheckpointPipeline:
    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 compose=None, pack_compose=None):
        self.cfg = cfg
        self.comm = comm
        self.topo = Topology(
            world=comm.world,
            ranks_per_node=cfg.ranks_per_node,
            group_size=min(cfg.group_size, comm.world),
            custom_groups=cfg.custom_groups,
        )
        self.ctx = TierContext(cfg, comm, self.topo)
        self.diff = DiffEngine(cfg.block_bytes, cfg.promote_threshold)
        self.stacks: Dict[int, List[Tier]] = (
            compose or default_tier_stacks)(self.ctx)
        self.ladder: List[Tier] = recovery_ladder(self.stacks)
        self.pack_tiers: List[PackTier] = (
            pack_compose or default_pack_tiers)()
        # newest FULL store whose digest update is still pending on the CP
        # thread; the CP queue is FIFO, so fencing on the newest fences all
        self._digest_fence: Optional[_PendingDigests] = None
        self._fence_lock = threading.Lock()
        # observer hook: called with every committed StoreReport (the
        # cadence controller's store-cost feed — chaos/cadence.py)
        self.on_report = None
        os.makedirs(self.ctx.local_root, exist_ok=True)
        os.makedirs(cfg.global_root, exist_ok=True)

    # ------------------------------------------------------------------ #

    @property
    def local_root(self) -> str:
        return self.ctx.local_root

    def clamp_level(self, level: int) -> int:
        """Snap to the nearest level a stack exists for (custom composers
        may register non-contiguous levels): the deepest available level
        not above the request, else the shallowest available."""
        if level in self.stacks:
            return level
        below = [k for k in self.stacks if k <= level]
        return max(below) if below else min(self.stacks)

    def tier_stack(self, level: int) -> List[Tier]:
        return self.stacks[self.clamp_level(level)]

    def tier_root(self, level: int) -> str:
        return self.tier_stack(level)[0].root

    # ------------------------------------------------------------------ #
    # stage 1: Plan
    # ------------------------------------------------------------------ #

    def plan(self, req: StoreRequest) -> Plan:
        """Span-wrapped Plan (the only stage on the calling thread — its
        span lands on the training thread's track, not the CP thread's)."""
        with ttrace.span("pipeline.plan", ckpt_id=req.ckpt_id,
                         level=req.level, kind=req.kind):
            return self._plan_impl(req)

    def _plan_impl(self, req: StoreRequest) -> Plan:
        """Resolve kind/level, run the on-device diff kernels, snapshot to
        host.  The only pipeline stage that runs on the calling thread.

        Kind resolution is **per leaf**: a ``Protect(kind=...)`` clause on
        the governing spec overrides the store-level kind, so one store can
        carry DIFF params and a FULL optimizer (mixed-kind).  The container
        kind is DIFF when any delta is present (the restore walk then keeps
        searching for the FULL base of the delta'd leaves)."""
        t_plan = time.time()
        level = self.clamp_level(req.level)
        tiers = self.tier_stack(level)
        extra = dict(req.extra_meta or {})
        attrs: Dict[str, Any] = dict(extra)
        specs = req.specs or {}
        deltas = None
        dirty_ratio = None
        promoted = False

        def eff_kind(path: str) -> str:
            spec = specs.get(path)
            return spec.kind if (spec is not None and spec.kind) else req.kind

        diff_paths = [p for p in req.named if eff_kind(p) == CHK_DIFF]
        if diff_paths and not req.diff_supported:
            diff_paths = []                 # VeloC/SCR: no checkpoint kinds
            attrs["diff_fallback"] = True
        diff_set = set(diff_paths)
        full_paths = [p for p in req.named if p not in diff_set]

        if diff_paths:
            # fence: an in-flight FULL may still owe its digest update to
            # the CP thread — wait for it so this delta diffs against the
            # post-FULL digests, never stale ones
            self._wait_digest_fence()
        # epoch read BEFORE delta computation: an invalidate() racing in
        # from a CP-thread failure mid-plan must make finish() refuse this
        # delta, not slip past the guard
        epoch = self.diff.epoch
        promoted_paths: List[str] = []
        if diff_paths:
            deltas, stats = self.diff.compute_deltas(
                {p: req.named[p] for p in diff_paths})
            dirty_ratio = stats.dirty_ratio
            if deltas is None:              # above break-even: promote
                promoted = True
                promoted_paths = diff_paths
                full_paths = list(req.named)
            else:
                attrs["base_required"] = True
        kind = CHK_DIFF if deltas is not None else CHK_FULL

        named_host = None
        sharded = None
        pending = None
        if full_paths:
            # shard-local snapshot: sharded leaves contribute one host
            # buffer per *owned* shard (D2H started async, completed by
            # Pack) instead of a gathered global-size array — the no-gather
            # store path (ROADMAP: multi-process sharded checkpointing)
            gather, sharded = split_sharded(
                {p: req.named[p] for p in full_paths},
                enabled=self.cfg.sharded_store)
            sharded = sharded or None
            named_host = to_host(gather) if gather else {}
            # digest bookkeeping is skipped when the backend can never
            # consume it (no checkpoint kinds) and for leaves the promote
            # path just hashed; otherwise it is owed — but *deferred* to
            # the async tail (finish), so a FULL store never pays a
            # synchronous full-tree blockhash on the training thread just
            # to keep a digest chain current that a later DIFF may never
            # read.  DIFF plans fence on it (_wait_digest_fence).
            # Registered only after to_host succeeded — nothing between
            # here and finish()/abort_plan() can fail and leak the fence
            promoted_set = set(promoted_paths)
            owed = [p for p in full_paths if p not in promoted_set]
            if req.diff_supported and owed:
                pending = _PendingDigests(
                    named={p: req.named[p] for p in owed})
                with self._fence_lock:
                    self._digest_fence = pending

        return Plan(ckpt_id=req.ckpt_id, level=level, kind=kind, tiers=tiers,
                    root=tiers[0].root, attrs=attrs, extra=extra,
                    named_host=named_host, sharded=sharded, deltas=deltas,
                    specs=dict(specs) if specs else None,
                    dirty_ratio=dirty_ratio, promoted_full=promoted,
                    plan_seconds=time.time() - t_plan,
                    digest_epoch=epoch if kind == CHK_DIFF else -1,
                    pending_digests=pending)

    def _wait_digest_fence(self) -> None:
        """Block until every deferred FULL digest update has run (the CP
        queue is FIFO: the newest pending fence dominates older ones).
        Released even when the FULL's tail fails — failure invalidates the
        touched leaves, which the next DIFF turns into a promote."""
        with self._fence_lock:
            pending = self._digest_fence
        if pending is not None:
            pending.done.wait()

    def _release_digest_fence(self, plan: Plan) -> None:
        pending = plan.pending_digests
        if pending is None:
            return
        pending.named = None            # drop device references
        pending.done.set()
        with self._fence_lock:
            if self._digest_fence is pending:
                self._digest_fence = None

    def abort_plan(self, plan: Plan) -> None:
        """A planned store will never reach finish() (e.g. the CP submit
        itself raised): release its fence so later DIFF plans don't block
        forever. No invalidate needed — the digests still describe the
        last *committed* checkpoint, which is the correct DIFF base when
        this store never happened."""
        self._release_digest_fence(plan)

    def plan_external(self, ckpt_id: int, level: int,
                      extra_meta: Optional[Dict[str, Any]] = None) -> Plan:
        """Plan for a payload produced outside Pack (file-mode backends,
        incremental stores).  Kind is FULL: the container holds a complete
        restorable snapshot of whatever was routed/added."""
        level = self.clamp_level(level)
        tiers = self.tier_stack(level)
        extra = dict(extra_meta or {})
        return Plan(ckpt_id=ckpt_id, level=level, kind=CHK_FULL, tiers=tiers,
                    root=tiers[0].root, attrs=dict(extra), extra=extra)

    # ------------------------------------------------------------------ #
    # stage 2: Pack
    # ------------------------------------------------------------------ #

    def pack(self, plan: Plan) -> Packed:
        """Span-wrapped Pack (runs on the CP thread for async stores)."""
        with ttrace.span("pipeline.pack", ckpt_id=plan.ckpt_id,
                         level=plan.level, kind=plan.kind):
            return self._pack_impl(plan)

    def _pack_impl(self, plan: Plan) -> Packed:
        """Serialize the planned payload into the staging dir: the Pack-tier
        chain encodes FULL leaves per their clauses (compression, format
        attrs, precision); DIFF deltas ship as compacted dirty blocks.  A
        mixed-kind plan writes both sections into one container.

        Sharded leaves write their owned shards as ``shard-<k>``
        sub-datasets spread over sibling ``rank<r>.shard<j>.chk5`` files
        (parallel writers; D2H completes per shard, overlapped against
        packing of already-arrived ones) and the shard index into the main
        container — everything inside the same ``.tmp`` staging dir, so
        the whole multi-file set commits atomically.

        When a tier offers Pack-stage chunk sinks (``tier.pack_sink``,
        the objstore L4), every container byte is teed into a streaming
        chunker as it is produced — chunk digesting and the missing-chunk
        uploads overlap serialization, and Place never re-reads the
        staged files (the zero-stall store path)."""
        d = mf.begin(plan.root, plan.ckpt_id)
        path = os.path.join(d, f"rank{self.comm.rank}.chk5")
        attrs = dict(plan.attrs, level=plan.level, rank=self.comm.rank,
                     world=self.comm.world)
        shard_files: List[str] = []
        sink = self._pack_sink(plan, os.path.basename(path))
        with CHK5Writer(path, sink=sink) as w:
            if plan.reuse_keys:
                w.region_keys = dict(plan.reuse_keys)
            root_attrs = dict(attrs, kind=plan.kind)
            if plan.sharded:
                root_attrs["sharded"] = True
            w.set_attrs("", root_attrs)
            if plan.sharded:
                shard_files = write_shard_files(
                    d, f"rank{self.comm.rank}", w, plan.sharded, plan.specs,
                    default_kind=CHK_FULL,
                    max_writers=self.cfg.shard_writers,
                    sink_factory=lambda bn: self._pack_sink(plan, bn))
            if plan.named_host:
                pack_named(w, plan.named_host, plan.specs, self.pack_tiers)
            if plan.deltas:
                self._serialize_deltas(w, plan.deltas, plan.specs)
        nbytes = os.path.getsize(path) + sum(
            os.path.getsize(p) for p in shard_files)
        return Packed(stage_dir=d, path=path, nbytes=nbytes,
                      shard_files=shard_files)

    def _pack_sink(self, plan: Plan, basename: str):
        """First streaming chunk sink any tier of this plan's stack offers
        for the staged file ``basename`` (None → the tier consumes whole
        staged files and Place falls back to re-reading them)."""
        for tier in plan.tiers:
            s = tier.pack_sink(plan.ckpt_id, basename)
            if s is not None:
                return s
        return None

    def _serialize_deltas(self, w: CHK5Writer, deltas: List[LeafDelta],
                          specs: Optional[Dict[str, Optional[Protect]]]
                          ) -> None:
        specs = specs or {}
        for d in deltas:
            g = f"delta/{d.path}"
            w.write_dataset(f"{g}/idx", d.dirty_idx)
            w.write_dataset(f"{g}/blocks", d.payload)
            # clause attrs ride the digest dataset (kind/selector/…); delta
            # payloads are raw dirty blocks — codecs apply to FULL leaves
            w.write_dataset(
                f"{g}/digest", d.digests,
                dict(clause_attrs(specs.get(d.path), CHK_DIFF),
                     dtype=d.dtype, shape=d.shape, n_blocks=d.n_blocks))

    # ------------------------------------------------------------------ #
    # stage 3: Place
    # ------------------------------------------------------------------ #

    def place(self, plan: Plan, packed: Packed) -> None:
        """Run the tier stack's redundancy over the packed payload (the
        rank container plus any sibling shard files)."""
        for tier in plan.tiers:
            chaos.fire(chaos.SITES.TIER_PLACE, tier=tier.name,
                       level=plan.level, ckpt_id=plan.ckpt_id,
                       rank=self.comm.rank)
            with ttrace.span("pipeline.place", tier=tier.name,
                             level=plan.level, ckpt_id=plan.ckpt_id):
                tier.place(plan.ckpt_id, packed.stage_dir, packed.path,
                           extra_files=packed.shard_files)

    # ------------------------------------------------------------------ #
    # stage 4: Commit
    # ------------------------------------------------------------------ #

    def commit(self, plan: Plan, packed: Packed) -> StoreReport:
        """Span-wrapped Commit; also the single metrics feed point (every
        store path — sync, CP-thread, external — converges here)."""
        with ttrace.span("pipeline.commit", ckpt_id=plan.ckpt_id,
                         level=plan.level, kind=plan.kind,
                         bytes=packed.nbytes):
            return self._commit_impl(plan, packed)

    def _commit_impl(self, plan: Plan, packed: Packed) -> StoreReport:
        """Status allgather + manifest + atomic rename + retention.

        (Rank0-equivalent; every rank writes the same manifest data in the
        single-process container, and commit merges idempotently.)"""
        statuses = self.comm.allgather(
            {"rank": self.comm.rank, "ok": True,
             "file": os.path.basename(packed.path), "nbytes": packed.nbytes,
             # the full multi-file set this rank staged — the manifest
             # covers shard files atomically (a partial set is detectable,
             # and the restore path refuses it)
             "files": [os.path.basename(packed.path)]
             + [os.path.basename(p) for p in packed.shard_files]})
        mf.write_manifest(plan.root, plan.ckpt_id, {
            "kind": plan.kind, "level": plan.level, "world": self.comm.world,
            "group_size": self.topo.group_size,
            "erasure": self.cfg.erasure_scheme,
            "block_bytes": self.cfg.block_bytes,
            "ranks": statuses,
            **plan.extra,
        })
        mf.commit(plan.root, plan.ckpt_id, keep_last=0)  # pruning below
        self.prune_chains(plan.root)
        # post-commit tier hooks, after the atomic rename: the objstore
        # tier joins its chunk uploads and publishes the catalog entry
        # here — a crash before this point leaves the previous catalog
        # entry authoritative (chunks already uploaded are unreferenced
        # garbage the next GC sweeps)
        committed = mf.read_manifest(plan.root, plan.ckpt_id)
        for tier in plan.tiers:
            chaos.fire(chaos.SITES.TIER_COMMIT, tier=tier.name,
                       level=plan.level, ckpt_id=plan.ckpt_id,
                       rank=self.comm.rank)
            with ttrace.span("pipeline.commit.tier", tier=tier.name,
                             level=plan.level, ckpt_id=plan.ckpt_id):
                tier.commit(plan.ckpt_id, committed)
        # seconds = store work only (plan + tail), not CP-queue waiting
        report = StoreReport(plan.ckpt_id, plan.level, plan.kind,
                             packed.nbytes,
                             plan.plan_seconds + (time.time() - plan.t0),
                             plan.dirty_ratio, plan.promoted_full)
        # canonical store metrics fed here, at the single convergence
        # point; the single-slot on_report hook stays free for user
        # observers (the cadence controller's store-cost feed)
        tmetrics.note_store_report(report)
        if self.on_report is not None:
            self.on_report(report)
        return report

    # ------------------------------------------------------------------ #
    # stage composition
    # ------------------------------------------------------------------ #

    def _plan_leaf_paths(self, plan: Plan):
        paths: List[str] = []
        if plan.named_host is not None:
            paths += list(plan.named_host)
        if plan.sharded is not None:
            paths += list(plan.sharded)
        if plan.deltas is not None:
            paths += [d.path for d in plan.deltas]
        return paths or plan.extra.get("parts", [])

    def _compute_reuse_keys(self, plan: Plan) -> None:
        """Derive chunk-layout reuse keys for FULL leaves from the *device*
        digests the diff engine already computed (blockhash at HBM
        bandwidth) — a leaf whose digests and encoding spec are unchanged
        since the last store produces byte-identical container regions, so
        the chunk stream replays its recorded cut layout verbatim and the
        CDC scan is skipped for those bytes.  The key folds in the Protect
        spec because clause changes (compression, precision) alter the
        encoded bytes while the device digests stay equal.  Correctness
        never depends on a key: chunk digests are always computed from the
        actual bytes — a wrong key only costs cut-placement quality."""
        if not plan.named_host:
            return
        specs = plan.specs or {}
        keys: Dict[str, str] = {}
        for path in plan.named_host:
            dk = self.diff.digest_key(path)
            if dk:
                keys[f"data/{path}"] = f"{path}|{specs.get(path)!r}|{dk}"
        plan.reuse_keys = keys or None

    def finish(self, plan: Plan) -> StoreReport:
        """The asynchronous tail: Pack → Place → Commit.

        Plan already advanced the digest chain (it must, so back-to-back
        async DIFF stores see each other); if the tail fails, the chain now
        describes a checkpoint that never committed — invalidate those
        leaves so a later DIFF can't delta against phantom data."""
        with ttrace.span("pipeline.store", ckpt_id=plan.ckpt_id,
                         level=plan.level, kind=plan.kind) as sp:
            report = self._finish_impl(plan)
            report.span_id = sp.id
            return report

    def _finish_impl(self, plan: Plan) -> StoreReport:
        plan.t0 = time.time()       # exclude any CP-queue wait from seconds
        try:
            if plan.pending_digests is not None:
                # the deferred FULL digest bookkeeping (blockhash at HBM
                # bandwidth) — off the training thread, behind the fence.
                # Released as soon as the digests are current: a fenced
                # DIFF plan need not wait for this store's I/O, and the
                # epoch guard below refuses its delta if this tail fails
                # after the release (invalidate bumps the epoch)
                self.diff.update_digests_full(plan.pending_digests.named)
                self._release_digest_fence(plan)
            if plan.kind == CHK_DIFF and plan.digest_epoch != self.diff.epoch:
                # a store that failed AFTER this one was planned invalidated
                # part of the chain — this delta may reference base content
                # that never committed; refuse rather than corrupt restores
                raise RuntimeError(
                    f"DIFF store {plan.ckpt_id}: digest base invalidated by "
                    "a failed store planned before it; retry (it will "
                    "promote to FULL)")
            self._compute_reuse_keys(plan)
            packed = self.pack(plan)
            self.place(plan, packed)
            return self.commit(plan, packed)
        except BaseException:
            self.diff.invalidate(self._plan_leaf_paths(plan))
            raise
        finally:
            self._release_digest_fence(plan)

    def finish_external(self, plan: Plan, payload_path: str,
                        nbytes: int,
                        extra_files: Optional[List[str]] = None
                        ) -> StoreReport:
        """Place + Commit for a payload staged outside Pack (the file was
        already written into ``ckpt-<id>.tmp`` under ``plan.root``;
        ``extra_files`` are its sibling shard files, if any)."""
        plan.t0 = time.time()       # exclude any CP-queue wait from seconds
        packed = Packed(
            stage_dir=mf.ckpt_dir(plan.root, plan.ckpt_id, tmp=True),
            path=payload_path, nbytes=nbytes,
            shard_files=list(extra_files or []))
        with ttrace.span("pipeline.store", ckpt_id=plan.ckpt_id,
                         level=plan.level, kind=plan.kind,
                         external=True) as sp:
            try:
                self.place(plan, packed)
                report = self.commit(plan, packed)
            except BaseException:
                self.diff.invalidate(self._plan_leaf_paths(plan))
                raise
            report.span_id = sp.id
            return report

    def store(self, req: StoreRequest) -> StoreReport:
        """Run all four stages synchronously."""
        return self.finish(self.plan(req))

    # ------------------------------------------------------------------ #
    # retention: keep the last N FULLs plus the diff chain above them
    # ------------------------------------------------------------------ #

    def prune_chains(self, root: str) -> None:
        ids = mf.list_committed(root)
        fulls = [i for i in ids
                 if mf.read_manifest(root, i).get("kind") == CHK_FULL]
        keep_from = fulls[-self.cfg.keep_last_full] if len(
            fulls) >= self.cfg.keep_last_full else (fulls[0] if fulls else None)
        if keep_from is None:
            return
        for i in ids:
            if i < keep_from:
                import shutil
                shutil.rmtree(mf.ckpt_dir(root, i), ignore_errors=True)

    # ------------------------------------------------------------------ #
    # read path: the recovery ladder
    # ------------------------------------------------------------------ #

    def available_ids(self) -> List[Tuple[int, str]]:
        """All committed checkpoint ids across tiers → [(id, tier_root)].
        Includes reachable peers' node-local tiers (a restarted rank on a
        fresh node recovers from partner/parity held by survivors)."""
        roots = [self.ctx.local_root, self.cfg.global_root]
        for r in range(self.comm.world):
            if r == self.comm.rank:
                continue
            peer = self.comm.peer_local_dir(r)
            if peer is not None:
                roots.append(os.path.join(peer, "ckpts"))
        out = []
        for root in roots:
            for i in mf.list_committed(root):
                out.append((i, root))
        # discovery beyond directory scans: the objstore tier answers from
        # its catalog, so a run whose dirs are wiped still finds what the
        # object store holds
        for tier in self.ladder:
            out.extend(tier.list_ids())
        return sorted(set(out))

    def recover_payload(self, root: str, ckpt_id: int, rank: int
                        ) -> Optional[Tuple[bytes, Dict, str]]:
        """Walk the tier ladder L1 → L4 for one rank payload.
        Returns (payload, manifest, tier_name) or None."""
        man = mf.try_read_manifest(root, ckpt_id) or {}
        dirs = self.ctx.recovery_dirs(root, ckpt_id)   # scanned once, shared
        for tier in self.ladder:
            blob = tier.recover(ckpt_id, rank, root, man, dirs)
            if blob is not None:
                if not man:
                    # a catalog-backed tier materializes the checkpoint
                    # dir (manifest included) during recover — re-read so
                    # the restore walk sees kind/level/file coverage
                    man = mf.try_read_manifest(root, ckpt_id) or {}
                return blob, man, tier.name
        return None

    def load_latest(self, rank: Optional[int] = None, *,
                    lazy_sharded: bool = False
                    ) -> Optional[Tuple[Dict[str, np.ndarray], Dict[str, Any]]]:
        """Restore newest restorable checkpoint: FULL base + diff replay.

        Sharded leaves restore from their shard files.  By default they
        are materialized to full host arrays (drop-in for native-API
        callers); ``lazy_sharded=True`` returns
        :class:`~repro.core.resharding.ShardedLeafRef` handles instead, so
        TCL's mesh-aware restore reads only the regions each target
        device needs — the global array never exists on host."""
        rank = self.comm.rank if rank is None else rank
        by_id: Dict[int, List[str]] = {}
        for i, root in self.available_ids():
            by_id.setdefault(i, []).append(root)
        for ckpt_id in sorted(by_id, reverse=True):
            try:
                got = self._try_restore(ckpt_id, by_id, rank)
            except Exception as e:
                # a checkpoint whose container fails to parse/verify (e.g.
                # pre-digest corruption that stored a matching chunk digest)
                # must not abort the walk — fall back to the next-older id
                warnings.warn(
                    f"checkpoint {ckpt_id} unrestorable "
                    f"({type(e).__name__}: {e}); falling back to older id",
                    RuntimeWarning)
                continue
            if got is not None:
                named, meta = got
                if not lazy_sharded:
                    named = {k: (v.materialize()
                                 if isinstance(v, ShardedLeafRef) else v)
                             for k, v in named.items()}
                return named, meta
        return None

    def _root_rank(self, root: str) -> int:
        """Walk order for the roots holding one checkpoint id: own local
        dir, then peers' local dirs, then the global dir, then catalog-
        backed roots (objstore cache) — mirroring the ladder's cost order
        so the object store is the fallback, never the first read."""
        if root == self.ctx.local_root:
            return 0
        if root == self.cfg.global_root:
            return 2
        if root in {t.root for t in self.ladder if t.level > 4}:
            return 3
        return 1                         # a reachable peer's local dir

    def _read_payload_any_tier(self, ckpt_id: int, by_id, rank: int
                               ) -> Optional[Tuple[bytes, Dict, str, str]]:
        for root in sorted(by_id.get(ckpt_id, []), key=self._root_rank):
            got = self.recover_payload(root, ckpt_id, rank)
            if got is not None:
                return got + (root,)
        return None

    def _try_restore(self, ckpt_id: int, by_id, rank: int):
        # walk back to the base FULL
        chain: List[Tuple[bytes, Dict, str]] = []
        via = None
        cur = ckpt_id
        while True:
            got = self._read_payload_any_tier(cur, by_id, rank)
            if got is None:
                return None
            blob, man, tier_name, root = got
            if via is None:
                via = tier_name             # how the newest link was produced
            chain.append((blob, man, root))
            if man.get("kind") == CHK_FULL:
                break
            prev = [i for i in by_id if i < cur]
            if not prev:
                return None
            cur = max(prev)
        chain.reverse()                     # [full, diff, diff, ...]

        named: Dict[str, Any] = {}
        flat_u32: Dict[str, np.ndarray] = {}
        meta_shape: Dict[str, Tuple[str, List[int]]] = {}
        bb = None
        for blob, man, root in chain:
            bb = man.get("block_bytes", self.cfg.block_bytes)
            ckid = man.get("id", ckpt_id)
            rd = CHK5Reader(io.BytesIO(blob))
            # one pass handles FULL, DIFF *and* mixed containers: a full
            # (or sharded) dataset supersedes any older delta replay of the
            # same leaf, a delta replays onto whatever base the chain built
            # so far.  Sharded leaves resolve their chunk files first: an
            # incomplete shard set (crash-lost / pruned file) makes this
            # checkpoint non-restorable and the walk falls back.
            refs = {}
            if any(ds.startswith("shardidx/") for ds in rd.datasets()):
                refs = resolve_shard_refs(
                    rd, self.ctx.recovery_dirs(root, ckid), rank)
                if refs is None:
                    rd.close()
                    return None
            for name, ref in refs.items():
                named[name] = ref
                flat_u32.pop(name, None)
                meta_shape.pop(name, None)
            for ds in rd.datasets():
                if ds.startswith("data/"):
                    name = ds[len("data/"):]
                    named[name] = decode_leaf(rd, ds)
                    flat_u32.pop(name, None)
                    meta_shape.pop(name, None)
                elif ds.startswith("delta/") and ds.endswith("/digest"):
                    name = ds[len("delta/"): -len("/digest")]
                    info = rd.info(ds)["attrs"]
                    idx = rd.read_dataset(f"delta/{name}/idx")
                    blocks = rd.read_dataset(f"delta/{name}/blocks")
                    if name not in flat_u32:
                        if name not in named:
                            return None     # chain broken
                        base = named[name]
                        if isinstance(base, ShardedLeafRef):
                            # delta replay needs the flat base — the one
                            # path that still materializes a sharded leaf
                            base = base.materialize()
                            named[name] = base
                        flat_u32[name] = leaf_to_u32_flat(base, bb)
                    flat_u32[name] = apply_delta(flat_u32[name], idx, blocks, bb)
                    meta_shape[name] = (info["dtype"], info["shape"])
            rd.close()
        for name, buf in flat_u32.items():
            dt, shp = meta_shape[name]
            named[name] = u32_flat_to_leaf(buf, dt, shp)
        final_meta = dict(chain[-1][1], recovered_via=via)
        return named, final_meta

