"""CLI tools."""
