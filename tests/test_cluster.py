"""Simulated multi-rank cluster: partner copies, erasure recovery, quorum."""
import os

import numpy as np

from repro.core.comm import SimulatedCluster
from repro.core.storage import StorageConfig, StorageEngine
from repro.ft.straggler import commit_if_quorum, validate_quorum
from repro.redundancy.groups import Topology


def _named(rank):
    return {"w": np.full(100, float(rank), np.float32),
            "step": np.asarray(np.int32(rank))}


def _engines(tmp_path, world, **kw):
    cluster = SimulatedCluster(str(tmp_path / "cluster"), world)
    cfg = StorageConfig(root=str(tmp_path / "shared"), group_size=4, **kw)
    engines = [StorageEngine(cfg, c) for c in cluster.comms]
    return cluster, engines


def test_l1_per_rank_storage(tmp_path):
    cluster, engines = _engines(tmp_path, 4)
    for r, e in enumerate(engines):
        e.store(_named(r), 1, level=1)
    for r, e in enumerate(engines):
        named, meta = e.load_latest()
        assert named["w"][0] == float(r)


def test_l2_partner_recovers_lost_node(tmp_path):
    """FTI recovery ladder: node dies → restarted rank restores from the
    partner replica held on a surviving node's local storage."""
    cluster, engines = _engines(tmp_path, 4)
    for r, e in enumerate(engines):
        e.store(_named(r), 1, level=2)
    cluster.kill_node(2)                   # wipe node 2's local storage
    named, meta = engines[2].load_latest()
    assert named["w"][0] == 2.0 and named["step"] == 2


def test_l3_erasure_reconstruct_after_node_loss(tmp_path):
    """RS parity across the group reconstructs a dead node's payload."""
    cluster, engines = _engines(tmp_path, 4, erasure_scheme="rs", rs_parity=2)
    for r, e in enumerate(engines):
        e.store(_named(r), 1, level=3)
    cluster.kill_node(1)
    got = engines[1].load_latest()
    assert got is not None, "erasure reconstruction failed"
    named, meta = got
    assert named["w"][0] == 1.0 and named["step"] == 1


def test_l3_xor_reconstruct(tmp_path):
    """XOR parity lives on the *next* group (world > group_size), so any
    single node loss is recoverable."""
    cluster, engines = _engines(tmp_path, 8, erasure_scheme="xor")
    for r, e in enumerate(engines):
        e.store(_named(r), 3, level=3)
    cluster.kill_node(0)
    got = engines[0].load_latest()
    assert got is not None
    assert got[0]["w"][0] == 0.0


def test_l4_global_shared(tmp_path):
    cluster, engines = _engines(tmp_path, 2)
    for r, e in enumerate(engines):
        e.store(_named(r), 7, level=4)
    # both ranks' files live in the shared global dir
    import repro.core.manifest as mf
    d = mf.ckpt_dir(engines[0].cfg.global_root, 7)
    files = sorted(f for f in os.listdir(d) if f.endswith(".chk5"))
    assert files == ["rank0.chk5", "rank1.chk5"]
    # each rank restores its own payload
    for r, e in enumerate(engines):
        named, _ = e.load_latest()
        assert named["step"] == r


def test_quorum_commit_with_straggler(tmp_path):
    """L2 checkpoint restorable when partner copies cover missing writers."""
    import repro.core.manifest as mf
    topo = Topology(world=4)
    root = str(tmp_path / "q")
    d = mf.begin(root, 5)
    # ranks 0,1,3 wrote; rank 2 is a straggler but rank 1 holds its replica
    for r in (0, 1, 3):
        open(os.path.join(d, f"rank{r}.chk5"), "wb").write(b"x" * 10)
    open(os.path.join(d, f"rank{topo.partner_of(2)}.partner2.chk5"),
         "wb").write(b"y")
    rep = validate_quorum(d, topo)
    assert rep.restorable and rep.covered_by_partner == [2]
    assert commit_if_quorum(root, 5, topo)
    assert mf.latest_id(root) == 5


def test_quorum_rejects_uncovered_loss(tmp_path):
    import repro.core.manifest as mf
    topo = Topology(world=4)
    root = str(tmp_path / "q2")
    d = mf.begin(root, 5)
    for r in (0, 1):
        open(os.path.join(d, f"rank{r}.chk5"), "wb").write(b"x")
    rep = validate_quorum(d, topo)
    assert not rep.restorable and set(rep.lost) == {2, 3}
    assert not commit_if_quorum(root, 5, topo)
