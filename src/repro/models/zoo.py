"""Model zoo: uniform entry points over all assigned architectures.

``Model`` bundles init / forward / decode functions per family so the
training loop, serving engine, and dry-run treat every arch identically:

  forward(params, batch)         → (logits, aux)     batch: dict of arrays
  decode_step(params, token, caches, pos) → (logits, caches)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as lm_mod


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]                   # (key) → params
    param_struct: Callable[[], Any]            # () → ShapeDtypeStruct tree
    forward: Callable[..., Any]                # (params, batch, remat=) → (logits, aux)
    init_caches: Callable[..., Any]            # (batch, max_len) → caches
    cache_struct: Callable[..., Any]
    decode_step: Callable[..., Any]            # (params, token, caches, pos)
    cache_protects: Callable[[], Any] = lm_mod.cache_protects
    # () → [Protect]: explicit batch-axis metadata for the cache pytree
    # (both families stack layers in dim 0, batch in dim 1)


def _lm_forward(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
                remat: bool = False):
    return lm_mod.lm_forward(
        params, batch["tokens"], cfg,
        extra_embeds=batch.get("patch_embeds"), remat=remat)


def _encdec_forward(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
                    remat: bool = False):
    return encdec_mod.encdec_forward(
        params, batch["frames"], batch["tokens"], cfg, remat=remat)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.encdec:
        return Model(
            cfg=cfg,
            init=functools.partial(encdec_mod.init_encdec, cfg=cfg),
            param_struct=functools.partial(encdec_mod.encdec_param_struct, cfg),
            forward=functools.partial(_encdec_forward, cfg=cfg),
            init_caches=functools.partial(
                _encdec_caches, cfg=cfg),
            cache_struct=functools.partial(_encdec_cache_struct, cfg=cfg),
            decode_step=functools.partial(_encdec_decode, cfg=cfg),
        )
    return Model(
        cfg=cfg,
        init=functools.partial(lm_mod.init_lm, cfg=cfg),
        param_struct=functools.partial(lm_mod.lm_param_struct, cfg),
        forward=functools.partial(_lm_forward, cfg=cfg),
        init_caches=functools.partial(_lm_caches, cfg=cfg),
        cache_struct=functools.partial(_lm_cache_struct, cfg=cfg),
        decode_step=functools.partial(_lm_decode, cfg=cfg),
    )


def _lm_caches(batch: int, max_len: int, cfg: ArchConfig):
    return lm_mod.init_caches(batch, cfg, max_len)


def _lm_cache_struct(batch: int, max_len: int, cfg: ArchConfig):
    return lm_mod.cache_struct(batch, cfg, max_len)


def _lm_decode(params, token, caches, pos, cfg: ArchConfig):
    return lm_mod.lm_decode_step(params, token, caches, pos, cfg)


def _encdec_caches(batch: int, max_len: int, cfg: ArchConfig,
                   enc_len: Optional[int] = None):
    return encdec_mod.init_encdec_caches(
        batch, cfg, max_len, enc_len if enc_len is not None else max_len)


def _encdec_cache_struct(batch: int, max_len: int, cfg: ArchConfig,
                         enc_len: Optional[int] = None):
    return encdec_mod.encdec_cache_struct(
        batch, cfg, max_len, enc_len if enc_len is not None else max_len)


def _encdec_decode(params, token, caches, pos, cfg: ArchConfig):
    return encdec_mod.encdec_decode_step(params, token, caches, pos, cfg)


# --------------------------------------------------------------------------- #
# batch construction (real + abstract)
# --------------------------------------------------------------------------- #


def batch_struct(cfg: ArchConfig, global_batch: int, seq_len: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for one training batch of this arch (stub frontends
    included — DESIGN.md §5)."""
    f32 = jnp.dtype(cfg.compute_dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.encdec:
        return {
            "frames": sds((global_batch, seq_len, cfg.d_model), f32),
            "tokens": sds((global_batch, seq_len), i32),
            "labels": sds((global_batch, seq_len), i32),
        }
    if cfg.frontend == "vision_stub":
        p = cfg.n_frontend_tokens
        return {
            "patch_embeds": sds((global_batch, p, cfg.d_model), f32),
            "tokens": sds((global_batch, seq_len - p), i32),
            "labels": sds((global_batch, seq_len), i32),
        }
    return {
        "tokens": sds((global_batch, seq_len), i32),
        "labels": sds((global_batch, seq_len), i32),
    }


def make_dummy_batch(cfg: ArchConfig, global_batch: int, seq_len: int,
                     key=None) -> Dict[str, jnp.ndarray]:
    key = key if key is not None else jax.random.PRNGKey(0)
    structs = batch_struct(cfg, global_batch, seq_len)
    out = {}
    for name, s in structs.items():
        k, key = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype) * 0.02
    return out
