"""Elastic restore + shard-local snapshots: store state saved under one
world/mesh layout, restore it onto another (node-count changes after
failures, pod rescale, DP/TP-width change) — without ever materializing a
global array on host.

Write side (the pipeline's Plan/Pack stages call in here):

- :func:`snapshot_shards` snapshots **only the addressable shards** of a
  sharded ``jax.Array`` — one host buffer per *distinct* shard index
  (replicated duplicates are skipped via shard-index ownership), with the
  D2H copies started asynchronously so Pack overlaps packing of
  already-arrived shards against the remaining transfers.  No host buffer
  of the global leaf size is ever allocated.
- :func:`write_shard_files` writes the owned shards as ``shard-<k>``
  sub-datasets spread over ``rank<r>.shard<j>.chk5`` files (one writer
  thread per file, in parallel) and records the index — global shape,
  chunk offsets, chunk file/dataset names — as a ``shardidx/<name>``
  dataset in the rank's main container.

Read side:

- :class:`ShardedLeafRef` is the lazy handle the restore path hands out
  for a sharded leaf: index metadata + resolved chunk files.  It reads
  arbitrary index boxes by touching only the overlapping byte ranges of
  each chunk file (CHK5 partial reads), so a target device pulls exactly
  its slice.  int8-compressed chunks decode transparently
  (:func:`read_chunk_slab` — partial reads dequantize only the touched
  blocks; full-chunk reads verify the recorded dequantized crc32).
- :func:`assemble_onto` builds a sharded ``jax.Array`` for a target
  ``Sharding`` directly from per-device region reads
  (``jax.make_array_from_single_device_arrays``) — store on 4×4, restore
  on 2×8 or 16×1, no global host array in between.
- :class:`ElasticLoader` assembles arbitrary regions of the global arrays
  from any number of chunk files.  It reads both the new multi-dim
  ``shard/<name>/shard-<k>`` chunk layout and the legacy axis-0
  ``shard/<name>`` layout (``save_sharded`` — the DP/ZeRO rank-file path).

Mesh-level helpers (``reshard_tree`` / ``gather_tree``) build restart
templates and bit-exact global views for tests.
"""
from __future__ import annotations

import glob
import os
import re
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import (
    CHK5CorruptionError,
    CHK5Reader,
    CHK5Writer,
    dtype_to_str,
    resolve_precision,
    str_to_dtype,
)


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """Place every leaf of ``tree`` per ``shardings`` (a matching pytree of
    jax ``Sharding``s — e.g. ``repro.dist.sharding.param_shardings`` under
    a target mesh). Works host→mesh and mesh→mesh; this is how a restart
    template declares the layout a checkpoint should restore onto."""
    import jax
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def gather_tree(tree: Any) -> Any:
    """Gather every (possibly sharded) leaf to a host ``np.ndarray`` —
    the bit-exact global view, independent of the mesh it lived on."""
    import jax
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


# -------------------------------------------------------------------------- #
# shard-local snapshots (write side of the no-gather store path)
# -------------------------------------------------------------------------- #


def _normalize_index(index, shape: Sequence[int]) -> Tuple[Tuple[int, int], ...]:
    """A jax shard index (tuple of slices, possibly ``slice(None)``) →
    canonical ((start, stop), ...) per dim."""
    out = []
    for dim, sl in zip(shape, index):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(dim) if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


@dataclass
class ShardChunk:
    """One owned shard of one leaf: its global placement plus the data —
    a single-device ``jax.Array`` until :meth:`materialize` completes the
    (already started) D2H copy, an ``np.ndarray`` afterwards."""
    offset: Tuple[int, ...]
    shape: Tuple[int, ...]
    data: Any

    def materialize(self) -> np.ndarray:
        if not isinstance(self.data, np.ndarray):
            self.data = np.asarray(self.data)
        return self.data

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.data.dtype).itemsize


@dataclass
class ShardSnapshot:
    """The Plan-stage snapshot of one sharded leaf: global metadata plus
    the distinct owned chunks (replicated duplicates already dropped)."""
    dtype: str
    global_shape: Tuple[int, ...]
    chunks: List[ShardChunk]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.chunks)


def shardable(leaf: Any) -> bool:
    """Should Plan snapshot this leaf shard-locally?  True for jax arrays
    that live on more than one device and are not fully replicated (a
    fully-replicated leaf has one distinct shard == the global array, so
    the plain host snapshot is already shard-local).

    Requires ``is_fully_addressable`` for now: on a multi-*process* mesh
    each rank's shard index would cover only its local chunks, and the
    restore walk reads a single rank container per rank — honoring
    cross-process leaves needs the cross-rank index merge a
    jax.distributed-backed Communicator will bring (ROADMAP)."""
    sharding = getattr(leaf, "sharding", None)
    if sharding is None or not hasattr(leaf, "addressable_shards"):
        return False
    try:
        if getattr(sharding, "is_fully_replicated", True):
            return False
        if not getattr(leaf, "is_fully_addressable", False):
            return False
    except Exception:
        return False
    return len(getattr(sharding, "device_set", ())) > 1


def snapshot_shards(leaf: Any) -> ShardSnapshot:
    """Snapshot the distinct addressable shards of ``leaf``.

    Shard-index ownership: a partially-replicated leaf presents the same
    index on several devices — only the first device holding each distinct
    index contributes a chunk.  Every kept shard's D2H copy is started
    asynchronously (``copy_to_host_async``); Pack materializes chunks as
    it writes them, so transfers overlap packing.  The chunks keep
    references to the (immutable) device shards until then — a caller
    that *donates* the leaf's buffer before the async tail ran will fail
    the store loudly, never corrupt it."""
    seen = set()
    chunks: List[ShardChunk] = []
    shape = tuple(int(d) for d in leaf.shape)
    for s in leaf.addressable_shards:
        bounds = _normalize_index(s.index, shape)
        if bounds in seen:
            continue                    # replicated duplicate — not owned
        seen.add(bounds)
        data = s.data
        try:
            data.copy_to_host_async()
        except AttributeError:
            pass
        chunks.append(ShardChunk(
            offset=tuple(b[0] for b in bounds),
            shape=tuple(b[1] - b[0] for b in bounds),
            data=data))
    return ShardSnapshot(dtype=dtype_to_str(leaf.dtype),
                         global_shape=shape, chunks=chunks)


def split_sharded(named: Dict[str, Any], enabled: bool = True
                  ) -> Tuple[Dict[str, Any], Dict[str, ShardSnapshot]]:
    """Partition protected leaves into (gather-snapshot leaves,
    shard-local snapshots) — the Plan-stage split."""
    if not enabled:
        return dict(named), {}
    sharded = {p: snapshot_shards(v) for p, v in named.items()
               if shardable(v)}
    host = {p: v for p, v in named.items() if p not in sharded}
    return host, sharded


# -------------------------------------------------------------------------- #
# sharded CHK5 layout (Pack side)
# -------------------------------------------------------------------------- #

_SHARD_FILE_RE = re.compile(r"^rank(\d+)\.shard(\d+)\.chk5$")

#: side-channel group for shard-chunk codec state (int8 block scales) —
#: same convention as the gathered-leaf ``codecaux/`` group in core/tiers
_CHUNK_AUX = "codecaux"


def shard_file_name(prefix: str, j: int) -> str:
    return f"{prefix}.shard{j}.chk5"


def _chunk_dataset(name: str, k: int) -> str:
    return f"shard/{name}/shard-{k}"


def _chunk_scale_dataset(ds: str) -> str:
    return f"{_CHUNK_AUX}/{ds}/scale"


def _precision_dtype(spec, arr_dtype) -> Optional[np.dtype]:
    """The store-side dtype a ``precision`` clause asks for, or None when
    it does not apply (no clause / non-float leaf)."""
    if spec is None or spec.precision is None:
        return None
    if not np.issubdtype(np.dtype(arr_dtype), np.floating):
        return None
    return resolve_precision(spec.precision)


def write_shard_files(stage_dir: str, prefix: str, index_writer: CHK5Writer,
                      sharded: Dict[str, ShardSnapshot],
                      specs: Optional[Dict[str, Any]] = None,
                      default_kind: str = "FULL",
                      max_writers: int = 4,
                      sink_factory=None) -> List[str]:
    """Write every owned chunk as a ``shard-<k>`` sub-dataset spread over
    ``<prefix>.shard<j>.chk5`` files in ``stage_dir`` — one writer thread
    per file, running in parallel — and record each leaf's shard index in
    ``index_writer`` (the rank's main container) as a ``shardidx/<name>``
    dataset:

    - the dataset itself is an int64 ``(n_chunks, 2·ndim)`` table of
      ``offset ‖ shape`` rows;
    - attributes carry ``global_shape``, the original ``dtype``,
      ``n_chunks`` and the per-chunk ``files``/``datasets`` names, plus
      the governing clause attrs.

    Chunks materialize (completing their async D2H copy) immediately
    before their dataset write, so device→host transfers overlap packing
    of already-arrived shards.  Returns the shard file paths; all files
    land in the staging dir, so the multi-file set commits (or vanishes)
    atomically with the container.

    A governing ``compress="int8"`` clause applies per chunk (float
    leaves only): the chunk ships as a flat quantized payload + block
    scales (``codecaux/…/scale`` in the same shard file) with a
    dequantized crc32 recorded for the load-side verify; a chunk whose
    roundtrip error exceeds ``max_error`` falls back to raw on its own
    (``codec_fallback`` attr).

    ``sink_factory(basename)`` (optional) supplies a streaming chunk sink
    per shard file (the fused Pack → upload path): sinks are created here
    on the caller's thread — registration mutates tier state — and each
    writer thread only feeds its own sink through ``CHK5Writer``.
    """
    from repro.core.tiers import clause_attrs, int8_encode_array
    specs = specs or {}
    work: List[Tuple[str, int, ShardChunk, Optional[np.dtype], Any, bool]] = []
    for name in sorted(sharded):
        snap = sharded[name]
        spec = specs.get(name)
        cast = _precision_dtype(spec, str_to_dtype(snap.dtype))
        # the compress="int8" clause now reaches shard chunks: each chunk
        # quantizes independently (per-chunk max_error fallback), the
        # block scales ride the same shard file, and a dequantized-crc32
        # is recorded per chunk for the load-side verify — closing the
        # ROADMAP "chunks ship raw" gap
        codec = (spec is not None and getattr(spec, "compress", None) == "int8"
                 and np.issubdtype(str_to_dtype(snap.dtype), np.floating))
        for k, chunk in enumerate(snap.chunks):
            work.append((name, k, chunk, cast, spec, codec))

    n_files = max(1, min(int(max_writers), len(work)))
    paths = [os.path.join(stage_dir, shard_file_name(prefix, j))
             for j in range(n_files)]
    assignment: Dict[Tuple[str, int], int] = {
        (name, k): i % n_files for i, (name, k, *_rest) in enumerate(work)}
    sinks = [sink_factory(os.path.basename(p)) if sink_factory else None
             for p in paths]

    def write_one(j: int) -> None:
        # durability is batched below: all shard files fsync back-to-back
        # after every writer finished (one journal settle, not one per
        # file — per-file fsync made a 4-file set pay ~4 journal commits)
        with CHK5Writer(paths[j], fsync=False, sink=sinks[j]) as w:
            w.set_attrs("", {"shard_file": True,
                             "of": f"{prefix}.chk5"})
            for i, (name, k, chunk, cast, spec, codec) in enumerate(work):
                if i % n_files != j:
                    continue
                orig = chunk.materialize()
                arr = orig
                if cast is not None and arr.dtype != cast:
                    arr = arr.astype(cast)
                ds = _chunk_dataset(name, k)
                attrs = {
                    "offset": [int(x) for x in chunk.offset],
                    "global_shape": [int(x) for x in
                                     sharded[name].global_shape],
                    "dtype": sharded[name].dtype,
                }
                if codec:
                    q, scale, cattrs = int8_encode_array(
                        arr, orig, getattr(spec, "max_error", None))
                    attrs.update(cattrs)
                    if q is not None:
                        # flat int8 payload: element e of the chunk is
                        # element e of q, so region reads stay element-
                        # range reads (scales decoded per block)
                        attrs["shape"] = [int(x) for x in chunk.shape]
                        w.write_dataset(ds, q.reshape(-1), attrs)
                        w.write_dataset(_chunk_scale_dataset(ds), scale)
                        continue
                w.write_dataset(ds, arr, attrs)

    # file count (the on-disk layout) is deterministic; only the thread
    # count adapts to the machine — more writer threads than cores just
    # adds GIL/scheduler churn, so a small box writes the same files with
    # fewer threads
    n_workers = max(1, min(n_files, os.cpu_count() or 1))
    if n_workers == 1:
        for j in range(n_files):
            write_one(j)
    else:
        with ThreadPoolExecutor(max_workers=n_workers) as ex:
            for f in [ex.submit(write_one, j) for j in range(n_files)]:
                f.result()              # propagate the first writer failure
    for p in paths:                     # batched durability (see write_one)
        fd = os.open(p, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    for name in sorted(sharded):
        snap = sharded[name]
        spec = specs.get(name)
        ndim = len(snap.global_shape)
        table = np.zeros((len(snap.chunks), 2 * max(ndim, 1)), np.int64)
        for k, chunk in enumerate(snap.chunks):
            table[k, :ndim] = chunk.offset
            table[k, ndim:2 * ndim] = chunk.shape
        attrs = dict(clause_attrs(spec, default_kind),
                     global_shape=[int(x) for x in snap.global_shape],
                     dtype=snap.dtype,
                     n_chunks=len(snap.chunks),
                     files=[os.path.basename(
                         paths[assignment[(name, k)]])
                         for k in range(len(snap.chunks))],
                     datasets=[_chunk_dataset(name, k)
                               for k in range(len(snap.chunks))])
        if spec is not None and getattr(spec, "compress", None):
            if np.issubdtype(str_to_dtype(snap.dtype), np.floating):
                # informational on the index: the per-chunk attrs are
                # authoritative (a chunk may have fallen back on its own
                # max_error check)
                attrs["codec"] = spec.compress
            else:
                attrs["codec_fallback"] = (
                    f"{spec.compress}: non-float leaf ({snap.dtype})")
        if spec is not None and spec.precision is not None and \
                _precision_dtype(spec, str_to_dtype(snap.dtype)) is None:
            attrs.pop("precision", None)
            attrs["precision_fallback"] = (
                f"{spec.precision}: non-float leaf ({snap.dtype})")
        index_writer.write_dataset(f"shardidx/{name}", table, attrs)
    return paths


# -------------------------------------------------------------------------- #
# lazy sharded-leaf restore (read side)
# -------------------------------------------------------------------------- #


@dataclass
class _ChunkRef:
    path: str
    dataset: str
    offset: Tuple[int, ...]
    shape: Tuple[int, ...]


def read_chunk_slab(rd: CHK5Reader, ds: str, chunk_shape: Sequence[int],
                    r_lo: int, r_hi: int) -> np.ndarray:
    """Read leading-dim rows [r_lo, r_hi) of one shard chunk dataset,
    decoding the chunk codec when present — the one slab reader behind
    ``ShardedLeafRef.read_index`` and ``ElasticLoader.read_region``.

    int8 chunks (``compress="int8"`` shard stores) hold a flat quantized
    payload plus per-block scales in a ``codecaux/.../scale`` sibling
    dataset; a partial read touches only the overlapping q elements and
    the covering scale blocks.  A read that covers the whole chunk also
    verifies the recorded dequantized crc32 (partial reads skip crc like
    every ``read_range`` — the region-restore fast path)."""
    chunk_shape = tuple(int(x) for x in chunk_shape)
    row_elems = int(np.prod(chunk_shape[1:])) if len(chunk_shape) > 1 else 1
    e_lo, n = r_lo * row_elems, (r_hi - r_lo) * row_elems
    attrs = rd.info(ds).get("attrs", {})
    if attrs.get("codec") != "int8":
        return rd.read_range(ds, e_lo, n)
    block = int(attrs.get("codec_block", 1024))
    out = rd.read_range(ds, e_lo, n).astype(np.float32)
    if n:
        b_lo = e_lo // block
        b_hi = (e_lo + n - 1) // block + 1
        scale = np.asarray(rd.read_range(_chunk_scale_dataset(ds),
                                         b_lo, b_hi - b_lo), np.float32)
        out *= scale[(e_lo + np.arange(n)) // block - b_lo]
    rows = chunk_shape[0] if chunk_shape else 1
    if r_lo == 0 and r_hi == rows and "roundtrip_crc32" in attrs:
        back = out.reshape(chunk_shape).astype(str_to_dtype(attrs["dtype"]))
        got = zlib.crc32(np.ascontiguousarray(back).tobytes()) & 0xFFFFFFFF
        if got != attrs["roundtrip_crc32"]:
            raise CHK5CorruptionError(
                f"{rd.path}:{ds}: int8 chunk roundtrip mismatch "
                f"(crc {got:#x} != recorded {attrs['roundtrip_crc32']:#x})")
        return back
    return out


def _clip_box(box, offset, shape):
    """Intersect a chunk (offset/shape) with a requested box → (selector
    into the output, selector into the chunk), or None when disjoint."""
    sel_out: List[slice] = []
    sel_chunk: List[slice] = []
    for (lo, hi), off, dim in zip(box, offset, shape):
        t_lo, t_hi = max(lo, off), min(hi, off + dim)
        if t_lo >= t_hi:
            return None
        sel_out.append(slice(t_lo - lo, t_hi - lo))
        sel_chunk.append(slice(t_lo - off, t_hi - off))
    return tuple(sel_out), tuple(sel_chunk)


def _assemble_box(box, dtype, chunks, read_slab, label: str,
                  exc_cls=ValueError) -> np.ndarray:
    """Assemble global box ``box`` from overlapping chunk reads — the one
    implementation behind ``ShardedLeafRef.read_index`` and
    ``ElasticLoader.read_region``.

    ``chunks`` yields ``(offset, shape, handle)``; ``read_slab(handle,
    r_lo, r_hi)`` returns the chunk's leading-dim rows [r_lo, r_hi) as a
    flat array (shards are C-order, so a dim-0 range is one contiguous
    byte range).  Chunks may *overlap* (replicated shards appearing in
    several merged rank files — each copy holds the same values); a fill
    mask verifies complete coverage, so overlaps neither double-count nor
    mask a hole."""
    out_shape = tuple(hi - lo for lo, hi in box)
    out = np.empty(out_shape, dtype)
    filled = np.zeros(out_shape, np.bool_)
    for offset, shape, handle in chunks:
        hit = _clip_box(box, offset, shape)
        if hit is None:
            continue
        sel_out, sel_chunk = hit
        r_lo = sel_chunk[0].start if sel_chunk else 0
        r_hi = sel_chunk[0].stop if sel_chunk else 1
        slab = read_slab(handle, r_lo, r_hi)
        slab = slab.reshape((r_hi - r_lo,) + tuple(shape[1:]))
        piece = slab[(slice(None),) + sel_chunk[1:]]
        if piece.dtype != dtype:
            piece = piece.astype(dtype)       # precision cast-back
        out[sel_out] = piece
        filled[sel_out] = True
    if not filled.all():
        missing = int(filled.size - np.count_nonzero(filled))
        raise exc_cls(
            f"{label}: box {box} not fully covered "
            f"({missing} of {filled.size} elements missing)")
    return out


class ShardedLeafRef:
    """Lazy handle to one sharded leaf of a committed checkpoint: the
    shard index plus resolved chunk files.  ``read_index`` assembles any
    index box touching only the overlapping leading-dim slabs of each
    chunk file; ``materialize`` assembles the full global array (host
    restores / delta replay)."""

    def __init__(self, name: str, dtype: str, shape: Sequence[int],
                 chunks: List[_ChunkRef],
                 precision: Optional[str] = None):
        self.name = name
        self.dtype = str_to_dtype(dtype)          # restore target dtype
        self.shape = tuple(int(x) for x in shape)
        self.chunks = chunks
        self.precision = precision                # stored-cast marker

    def __repr__(self) -> str:
        return (f"ShardedLeafRef({self.name!r}, shape={self.shape}, "
                f"dtype={self.dtype.str}, chunks={len(self.chunks)})")

    # -- reading ------------------------------------------------------- #

    def _box(self, index) -> Tuple[Tuple[int, int], ...]:
        if index is None:
            return tuple((0, d) for d in self.shape)
        return _normalize_index(index, self.shape)

    def read_index(self, index=None,
                   _readers: Optional[Dict[str, CHK5Reader]] = None
                   ) -> np.ndarray:
        """Assemble global box ``index`` (tuple of slices; None → all)
        from the overlapping chunks, reading only overlapping slabs."""
        readers = {} if _readers is None else _readers

        def read_slab(c: _ChunkRef, r_lo: int, r_hi: int) -> np.ndarray:
            rd = readers.get(c.path)
            if rd is None:
                rd = readers[c.path] = CHK5Reader(c.path)
            return read_chunk_slab(rd, c.dataset, c.shape, r_lo, r_hi)

        try:
            return _assemble_box(
                self._box(index), self.dtype,
                ((c.offset, c.shape, c) for c in self.chunks),
                read_slab, self.name, exc_cls=CHK5CorruptionError)
        finally:
            if _readers is None:
                for rd in readers.values():
                    rd.close()

    def materialize(self) -> np.ndarray:
        """The full global array on host (needed for single-device
        restores and DIFF delta replay — the sharded fast path never
        calls this)."""
        return self.read_index(None)


def resolve_shard_refs(rd, dirs: Sequence[str], rank: int
                       ) -> Optional[Dict[str, ShardedLeafRef]]:
    """Resolve every ``shardidx/<name>`` dataset of a rank container into
    a :class:`ShardedLeafRef`, locating each chunk file across the
    candidate checkpoint dirs (the file itself, or a partner replica
    ``rank<h>.partner<rank>.shard<j>.chk5``).  Returns None when any
    chunk file is missing or fails CHK5 validation — an incomplete shard
    set makes the whole checkpoint non-restorable (the caller falls back
    to an older id or another tier)."""
    idx_datasets = [ds for ds in rd.datasets() if ds.startswith("shardidx/")]
    if not idx_datasets:
        return {}
    out: Dict[str, ShardedLeafRef] = {}
    resolved: Dict[str, Optional[str]] = {}
    valid: Dict[str, bool] = {}

    def find(basename: str) -> Optional[str]:
        if basename in resolved:
            return resolved[basename]
        path = None
        m = _SHARD_FILE_RE.match(basename)
        for d in dirs:
            p = os.path.join(d, basename)
            if os.path.exists(p):
                path = p
                break
            if m is not None:
                hits = glob.glob(os.path.join(
                    d, f"rank*.partner{m.group(1)}.shard{m.group(2)}.chk5"))
                if hits:
                    path = sorted(hits)[0]
                    break
        resolved[basename] = path
        return path

    def ok(path: str) -> bool:
        if path not in valid:
            try:
                CHK5Reader(path).close()
                valid[path] = True
            except (OSError, CHK5CorruptionError):
                valid[path] = False
        return valid[path]

    for ds in idx_datasets:
        name = ds[len("shardidx/"):]
        meta = rd.info(ds)["attrs"]
        table = rd.read_dataset(ds)
        gshape = [int(x) for x in meta["global_shape"]]
        ndim = len(gshape)
        chunks: List[_ChunkRef] = []
        for k in range(int(meta["n_chunks"])):
            path = find(meta["files"][k])
            if path is None or not ok(path):
                return None
            row = table[k]
            chunks.append(_ChunkRef(
                path=path, dataset=meta["datasets"][k],
                offset=tuple(int(x) for x in row[:ndim]),
                shape=tuple(int(x) for x in row[ndim:2 * ndim])))
        out[name] = ShardedLeafRef(
            name, meta["dtype"], gshape, chunks,
            precision=meta.get("precision"))
    return out


def assemble_onto(ref: ShardedLeafRef, sharding) -> Any:
    """Build a jax array laid out per ``sharding`` directly from the shard
    files: one region read per *distinct* target index (replicated target
    devices share the host buffer), then
    ``jax.make_array_from_single_device_arrays`` — the global array never
    exists on host."""
    import jax
    shape = tuple(ref.shape)
    imap = sharding.addressable_devices_indices_map(shape)
    readers: Dict[str, CHK5Reader] = {}
    cache: Dict[Tuple, np.ndarray] = {}
    pieces = []
    try:
        for dev, idx in imap.items():
            key = _normalize_index(idx if idx is not None else
                                   (slice(None),) * len(shape), shape)
            host = cache.get(key)
            if host is None:
                host = cache[key] = ref.read_index(idx, _readers=readers)
            pieces.append(jax.device_put(host, dev))
    finally:
        for rd in readers.values():
            rd.close()
    return jax.make_array_from_single_device_arrays(shape, sharding, pieces)


# -------------------------------------------------------------------------- #
# rank-file elastic restore (multi-process DP/ZeRO layout)
# -------------------------------------------------------------------------- #


def shard_bounds(n_rows: int, world: int, rank: int) -> Tuple[int, int]:
    """Even axis-0 partition with remainder spread over the first ranks."""
    base, rem = divmod(n_rows, world)
    lo = rank * base + min(rank, rem)
    hi = lo + base + (1 if rank < rem else 0)
    return lo, hi


def save_sharded(path: str, named_global_slices: Dict[str, np.ndarray],
                 offsets: Dict[str, int], global_shapes: Dict[str, List[int]],
                 meta: Optional[Dict[str, Any]] = None) -> None:
    """Write this rank's axis-0 chunks (+ index metadata) into one CHK5
    file (the legacy per-rank layout; the pipeline's store path now emits
    the multi-dim ``shard-<k>`` layout via :func:`write_shard_files`)."""
    with CHK5Writer(path) as w:
        w.set_attrs("", dict(meta or {}, sharded=True))
        for name, arr in named_global_slices.items():
            w.write_dataset(f"shard/{name}", np.asarray(arr), {
                "row_offset": int(offsets[name]),
                "global_shape": [int(x) for x in global_shapes[name]],
            })


class ElasticLoader:
    """Assemble arbitrary regions of the global arrays from chunk files.

    Understands both shard layouts:

    - ``shard/<name>/shard-<k>`` datasets with an ``offset`` attr (the
      pipeline's multi-dim shard files), and
    - legacy ``shard/<name>`` datasets with a ``row_offset`` attr (axis-0
      chunking from :func:`save_sharded`).
    """

    def __init__(self, files: List[str]):
        self.readers = [CHK5Reader(f) for f in files]
        self._paths = list(files)
        # name → [(reader, dataset, offset tuple, shape tuple, dtype, gshape)]
        self.chunks: Dict[str, List[tuple]] = {}
        for rd, path in zip(self.readers, self._paths):
            for ds in rd.datasets():
                if not ds.startswith("shard/"):
                    continue
                m = rd.info(ds)
                a = m["attrs"]
                if "offset" in a:                   # multi-dim chunk
                    name = ds[len("shard/"):].rsplit("/", 1)[0]
                    offset = tuple(int(x) for x in a["offset"])
                elif "row_offset" in a:             # legacy axis-0 chunk
                    name = ds[len("shard/"):]
                    offset = (int(a["row_offset"]),) + \
                        (0,) * (len(m["shape"]) - 1)
                else:
                    continue
                gshape = [int(x) for x in a["global_shape"]]
                # codec chunks store a flat quantized payload; the logical
                # chunk shape rides the attrs
                shp = tuple(int(x) for x in a.get("shape", m["shape"]))
                self.chunks.setdefault(name, []).append(
                    (rd, ds, offset, shp,
                     a.get("dtype", m["dtype"]), gshape))
        for v in self.chunks.values():
            v.sort(key=lambda c: c[2])

    def names(self) -> List[str]:
        return sorted(self.chunks)

    def global_shape(self, name: str) -> List[int]:
        return self.chunks[name][0][5]

    def dtype(self, name: str) -> np.dtype:
        return str_to_dtype(self.chunks[name][0][4])

    def read_region(self, name: str, index) -> np.ndarray:
        """Assemble global box ``index`` (tuple of slices; None → all) of
        ``name`` from overlapping chunks, reading only overlapping slabs.
        Overlapping chunk files (replicated shards merged from several
        rank files) are handled — coverage is mask-verified."""
        gshape = self.global_shape(name)
        box = tuple((0, int(d)) for d in gshape) if index is None else \
            _normalize_index(index, gshape)

        def read_slab(handle, r_lo: int, r_hi: int) -> np.ndarray:
            rd, ds, shp = handle
            return read_chunk_slab(rd, ds, shp, r_lo, r_hi)

        return _assemble_box(
            box, self.dtype(name),
            ((off, shp, (rd, ds, shp))
             for rd, ds, off, shp, _dt, _gs in self.chunks[name]),
            read_slab, name)

    def read_rows(self, name: str, lo: int, hi: int) -> np.ndarray:
        """Assemble global rows [lo, hi) of ``name`` (axis-0 range)."""
        gshape = self.global_shape(name)
        index = (slice(lo, hi),) + tuple(slice(0, d) for d in gshape[1:])
        return self.read_region(name, index)

    def read_for_rank(self, name: str, world: int, rank: int) -> np.ndarray:
        g = self.global_shape(name)
        lo, hi = shard_bounds(g[0] if g else 1, world, rank)
        return self.read_rows(name, lo, hi)

    def close(self):
        for r in self.readers:
            r.close()


def load_named_onto(container: str, dirs: Sequence[str], rank: int = 0,
                    shardings: Any = None) -> Dict[str, Any]:
    """Load every leaf of a committed rank container **directly onto a
    target mesh** — the serve-side region loader.

    Sharded leaves (``shardidx/``) resolve their chunk files across
    ``dirs`` and assemble straight onto the requested sharding via
    :func:`assemble_onto` (one region read per distinct target index —
    the global array never exists on host), so a checkpoint stored from
    a 4×4 training mesh lands on a 1×8 serving mesh without either mesh
    seeing the full tree.  Plain ``data/`` leaves decode through the
    tier codec dispatch (int8 etc.) and are device_put per the same
    sharding map.

    ``shardings`` is a mapping ``name → jax sharding`` (missing names
    assemble to host numpy), a single sharding applied to every leaf, or
    ``None`` for an all-host load.  Raises :class:`CHK5CorruptionError`
    when the shard set is incomplete — a torn load must fail loudly, the
    deploy path never installs a partial tree."""
    from repro.core.tiers import decode_leaf   # tiers ⇄ resharding layering

    def sharding_for(name: str):
        if shardings is None:
            return None
        if hasattr(shardings, "get"):
            return shardings.get(name)
        return shardings

    named: Dict[str, Any] = {}
    rd = CHK5Reader(container)
    try:
        refs = resolve_shard_refs(rd, dirs, rank)
        if refs is None:
            raise CHK5CorruptionError(
                f"{container}: incomplete shard set across {list(dirs)} — "
                f"refusing a partial load")
        for name, ref in refs.items():
            sh = sharding_for(name)
            named[name] = assemble_onto(ref, sh) if sh is not None \
                else ref.materialize()
        for ds in rd.datasets():
            if not ds.startswith("data/"):
                continue
            name = ds[len("data/"):]
            arr = decode_leaf(rd, ds)
            sh = sharding_for(name)
            if sh is not None:
                import jax
                arr = jax.device_put(arr, sh)
            named[name] = arr
    finally:
        rd.close()
    return named


def elastic_restore(ckpt_dir_path: str, new_world: int, new_rank: int
                    ) -> Dict[str, np.ndarray]:
    """Restore this new rank's slice of every sharded array in a committed
    checkpoint directory (any number of original rank/shard files)."""
    files = [os.path.join(ckpt_dir_path, f) for f in os.listdir(ckpt_dir_path)
             if f.endswith(".chk5") and f.startswith("rank")
             and ".partner" not in f]
    loader = ElasticLoader(sorted(files))
    out = {}
    for name in loader.names():
        out[name] = loader.read_for_rank(name, new_world, new_rank)
    loader.close()
    return out
