"""Whisper-style encoder-decoder transformer.

The audio conv frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, T_enc, d_model). Sinusoidal positions are
added (whisper uses fixed sinusoidal for the encoder, learned for the
decoder — we use sinusoidal for both; stub-equivalent). No RoPE.

Decode: self-attn KV cache grows with generated tokens; cross-attn K/V are
computed once from the encoder output and static thereafter.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.context import DATA, shard_hint
from repro.models import attention as attn
from repro.models.layers import (
    cast_floating,
    embed_init,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
)

Params = Dict[str, Any]


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _init_enc_layer(key, cfg: ArchConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_gqa(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ArchConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "self_attn": attn.init_gqa(k1, cfg, dtype),
        "ln_x": init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": attn.init_cross_attn(k2, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(key, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": enc,
        "dec_layers": dec,
        "ln_enc": init_rmsnorm(cfg.d_model, dtype),
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }


def encdec_param_struct(cfg: ArchConfig) -> Any:
    return jax.eval_shape(functools.partial(init_encdec, cfg=cfg),
                          jax.random.PRNGKey(0))


def encode(params: Params, frames: jnp.ndarray, cfg: ArchConfig,
           remat: bool = False) -> jnp.ndarray:
    """frames (B, T, d) stub frontend output → encoder states (B, T, d)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    t, d = frames.shape[1], cfg.d_model
    h = frames.astype(cdt) + sinusoidal_positions(t, d).astype(cdt)[None]
    h = shard_hint(h, DATA, None, None)

    def body(h, p):
        h = h + attn.gqa_attention(
            p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg,
            causal=False, use_rope=False)
        h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.act)
        return h, None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return rmsnorm(params["ln_enc"], h, cfg.norm_eps)


def decode_train(params: Params, tokens: jnp.ndarray, enc_out: jnp.ndarray,
                 cfg: ArchConfig, remat: bool = False) -> jnp.ndarray:
    """Teacher-forced decoder pass → logits (B, S, V)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    s, d = tokens.shape[1], cfg.d_model
    h = params["embed"][tokens].astype(cdt)
    h = h + sinusoidal_positions(s, d).astype(cdt)[None]
    h = shard_hint(h, DATA, None, None)

    def body(h, p):
        h = h + attn.gqa_attention(
            p["self_attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg,
            causal=True, use_rope=False)
        h = h + attn.gqa_attention(
            p["cross_attn"], rmsnorm(p["ln_x"], h, cfg.norm_eps), cfg,
            kv_override=enc_out)
        h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.act)
        return h, None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h @ params["embed"].T.astype(h.dtype)


def encdec_forward(params: Params, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ArchConfig, remat: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    params = cast_floating(params, jnp.dtype(cfg.compute_dtype))
    enc_out = encode(params, frames, cfg, remat=remat)
    logits = decode_train(params, tokens, enc_out, cfg, remat=remat)
    return logits, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------- #
# incremental decode
# --------------------------------------------------------------------------- #


class EncDecCache(NamedTuple):
    self_kv: Any                 # stacked (L,) KVCache over decoder positions
    cross_k: jnp.ndarray         # (L, B, T_enc, KV, dh) static
    cross_v: jnp.ndarray


def init_encdec_caches(batch: int, cfg: ArchConfig, max_len: int,
                       enc_len: int) -> EncDecCache:
    cdt = jnp.dtype(cfg.compute_dtype)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    self_kv = jax.vmap(
        lambda _: attn.init_kv_cache(batch, cfg, max_len, cdt)
    )(jnp.arange(cfg.n_layers))
    shape = (cfg.n_layers, batch, enc_len, kv, dh)
    return EncDecCache(self_kv, jnp.zeros(shape, cdt), jnp.zeros(shape, cdt))


def encdec_cache_struct(batch: int, cfg: ArchConfig, max_len: int,
                        enc_len: int) -> Any:
    return jax.eval_shape(
        functools.partial(init_encdec_caches, batch, cfg, max_len, enc_len))


def precompute_cross_kv(params: Params, enc_out: jnp.ndarray, cfg: ArchConfig):
    b, t, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim

    def one(p):
        k = (enc_out @ p["cross_attn"]["wk"]).reshape(b, t, kv, dh)
        v = (enc_out @ p["cross_attn"]["wv"]).reshape(b, t, kv, dh)
        return k, v

    ks, vs = jax.vmap(one)(params["dec_layers"])
    return ks, vs


def encdec_decode_step(params: Params, token: jnp.ndarray, caches: EncDecCache,
                       pos, cfg: ArchConfig) -> Tuple[jnp.ndarray, EncDecCache]:
    cdt = jnp.dtype(cfg.compute_dtype)
    params = cast_floating(params, cdt)
    d = cfg.d_model
    h = params["embed"][token].astype(cdt)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.zeros((d,), jnp.float32).at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
    h = h + pe.astype(cdt)[None, None]

    def body(h, xs):
        p, kvc, ck, cv = xs
        y, kvc = attn.gqa_decode(
            p["self_attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), kvc, pos, cfg)
        h = h + y
        y, _ = attn.gqa_decode(
            p["cross_attn"], rmsnorm(p["ln_x"], h, cfg.norm_eps), kvc, pos, cfg,
            kv_override=(ck, cv))
        h = h + y
        h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps), cfg.act)
        return h, kvc

    h, new_self = jax.lax.scan(
        body, h, (params["dec_layers"], caches.self_kv,
                  caches.cross_k, caches.cross_v))
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = h @ params["embed"].T.astype(h.dtype)
    return logits, EncDecCache(new_self, caches.cross_k, caches.cross_v)
