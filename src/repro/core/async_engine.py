"""CP-dedicated threads (paper §4.2.2).

One thread per host runs the checkpoint pipeline's Pack → Place → Commit
tail — serialization, redundancy, I/O — while the accelerator keeps
computing.  The Plan stage always stays on the training thread, in
submission order; that is the only synchronous cost: the device→host
snapshot, plus — for CHK_DIFF — the on-device blockhash/pack at HBM
bandwidth (clean leaves are skipped via the identity cache).  FULL stores
defer their digest bookkeeping to this thread behind a fence (a later
DIFF plan waits for it; backends without checkpoint kinds skip digest
bookkeeping entirely).  FULL, DIFF and incremental stores all go
through the same queue, so they compose and serialize correctly against
each other.

FTI semantics for errors: a failed asynchronous store does not raise at the
original ``store()`` call; it is surfaced at the *next* directive (store /
load / shutdown) — exposed via ``check_errors``/``wait``.
"""
from __future__ import annotations

import queue
import threading
import traceback
import weakref
from dataclasses import dataclass
from typing import Any, Callable, List, Optional

_LIVE: "weakref.WeakSet[CPDedicatedThread]" = weakref.WeakSet()


def drain_all() -> None:
    """Fence every live CP thread. In-process fault *simulation* leaves the
    faulted context's thread alive (a real abort kills it with the process);
    test/bench harnesses call this between attempts so the restarted run
    never races an orphaned writer."""
    for cp in list(_LIVE):
        try:
            cp.wait()
        except Exception:  # noqa: BLE001 — draining best-effort
            pass


@dataclass
class AsyncResult:
    ckpt_id: int
    done: threading.Event
    error: Optional[BaseException] = None
    report: Any = None


class CPDedicatedThread:
    """Single dedicated worker; at most ``max_inflight`` pending stores
    (further submits block — matches FTI's head-of-line checkpoint fence)."""

    def __init__(self, max_inflight: int = 1, name: str = "openchk-cp"):
        self._q: "queue.Queue" = queue.Queue()
        self._results: List[AsyncResult] = []
        self._errors: List[BaseException] = []
        self._lock = threading.Lock()
        self._max_inflight = max_inflight
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._alive = True
        self._thread.start()
        _LIVE.add(self)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            fn, res = item
            try:
                res.report = fn()
            except BaseException as e:   # noqa: BLE001 — surfaced later
                res.error = e
                with self._lock:
                    self._errors.append(e)
                traceback.print_exc()
            finally:
                res.done.set()
                self._q.task_done()

    # ------------------------------------------------------------------ #

    def inflight(self) -> int:
        with self._lock:
            return sum(0 if r.done.is_set() else 1 for r in self._results)

    def submit(self, ckpt_id: int, fn: Callable[[], Any]) -> AsyncResult:
        if not self._alive:
            raise RuntimeError("CP thread already shut down")
        # fence: keep at most max_inflight pending
        while self.inflight() >= self._max_inflight:
            self._wait_one()
        res = AsyncResult(ckpt_id, threading.Event())
        with self._lock:
            self._results.append(res)
        self._q.put((fn, res))
        return res

    def _wait_one(self) -> None:
        with self._lock:
            pending = [r for r in self._results if not r.done.is_set()]
        if pending:
            pending[0].done.wait()

    def wait(self) -> None:
        """Drain all pending stores (pre-shutdown / pre-restart fence)."""
        while self.inflight():
            self._wait_one()

    def check_errors(self) -> None:
        """Raise the first deferred error (FTI-style late surfacing)."""
        with self._lock:
            errs, self._errors = self._errors, []
        if errs:
            raise RuntimeError(
                f"asynchronous checkpoint failed: {errs[0]!r}") from errs[0]

    def shutdown(self) -> None:
        if self._alive:
            self.wait()
            self._q.put(None)
            self._thread.join(timeout=30)
            self._alive = False
