"""Multi-level checkpoint storage engine — a thin facade over the staged
checkpoint pipeline (core/pipeline.py: Plan → Pack → Place → Commit) and
the tier ladder (core/tiers.py: Local/Partner/Erasure/Global).

Levels (paper §4.2.1 / FTI semantics):
  L1  node-local write (RAM-disk / NVMe analogue)
  L2  L1 + partner copy on a different node
  L3  L1 + Reed–Solomon (or XOR) parity across the node group
  L4  parallel-file-system write (global directory)

Restart search order: L1 → L2 (partner) → L3 (erasure reconstruct) → L4,
newest checkpoint id first — exactly FTI's recovery ladder.

All writes go through the manifest commit protocol (atomic rename); payloads
are CHK5 containers, so every checkpoint is also an analyzable dataset
(§4.2.4).

``StorageEngine`` keeps the historical call surface (``store`` /
``load_latest`` / ``available_ids``) for tests, tools and benchmarks;
backends drive the pipeline stages directly (backends/base.py) so that
async, DIFF and incremental stores all compose through the same path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.comm import Communicator
from repro.core.pipeline import (           # re-exported for compatibility
    CHK_DIFF,
    CHK_FULL,
    CheckpointPipeline,
    LoadRequest,
    Packed,
    Plan,
    StorageConfig,
    StoreReport,
    StoreRequest,
)
from repro.core.protect import Protect      # noqa: F401  (re-export)

__all__ = ["CHK_FULL", "CHK_DIFF", "CheckpointPipeline", "LoadRequest",
           "Packed", "Plan", "Protect", "StorageConfig", "StoreReport",
           "StoreRequest", "StorageEngine"]


class StorageEngine:
    """Facade: one object exposing the pipeline's write/read path."""

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 compose=None, pack_compose=None):
        self.cfg = cfg
        self.comm = comm
        self.pipeline = CheckpointPipeline(cfg, comm, compose=compose,
                                           pack_compose=pack_compose)
        self.topo = self.pipeline.topo
        self.diff = self.pipeline.diff

    # ------------------------------------------------------------------ #

    @property
    def local_root(self) -> str:
        return self.pipeline.local_root

    def tier_root(self, level: int) -> str:
        """Root dir of the write stack's primary tier for ``level``."""
        return self.pipeline.tier_root(level)

    # ------------------------------------------------------------------ #
    # write path — Plan → Pack → Place → Commit, run synchronously
    # ------------------------------------------------------------------ #

    def store(self, named_host: Dict[str, np.ndarray], ckpt_id: int,
              level: int, kind: str = CHK_FULL,
              extra_meta: Optional[Dict[str, Any]] = None,
              diff_supported: bool = True) -> StoreReport:
        """Coordinated store of this rank's protected data."""
        return self.pipeline.store(StoreRequest(
            named=named_host, ckpt_id=ckpt_id, level=level, kind=kind,
            extra_meta=extra_meta, diff_supported=diff_supported))

    # ------------------------------------------------------------------ #
    # read path — the tier recovery ladder
    # ------------------------------------------------------------------ #

    def available_ids(self) -> List[Tuple[int, str]]:
        return self.pipeline.available_ids()

    def load_latest(self, rank: Optional[int] = None, *,
                    lazy_sharded: bool = False
                    ) -> Optional[Tuple[Dict[str, np.ndarray], Dict[str, Any]]]:
        return self.pipeline.load_latest(rank, lazy_sharded=lazy_sharded)

    def rank_payload(self, root: str, ckpt_id: int, rank: int
                     ) -> Optional[bytes]:
        """Fetch a rank payload via the recovery ladder (partner / erasure
        fallback included)."""
        got = self.pipeline.recover_payload(root, ckpt_id, rank)
        return got[0] if got is not None else None

    def objstore_tier(self):
        """The composed L4 object-store tier (repro.objstore), or None
        when ``cfg.objstore`` is off — the handle tools/benchmarks use to
        reach the catalog and the upload/dedup stats."""
        return next((t for t in self.pipeline.ladder
                     if t.name == "objstore"), None)
