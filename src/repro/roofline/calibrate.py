"""Calibrate the analytic cost model against compiled cost_analysis.

A mid-size llama-family config is compiled with the layer stack UNROLLED
(loop-free HLO ⇒ cost_analysis is exact) on one device, and the analytic
model is evaluated at dp=tp=1. Agreement of the FLOP counts validates the
analytic model that the §Roofline tables are built from (scanned modules
cannot be counted directly — see tests/test_roofline.py).

Run:  PYTHONPATH=src python -m repro.roofline.calibrate
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as lm
from repro.roofline.analytic import analytic_report

CAL_CFG = ArchConfig(
    name="cal-llama",
    family="dense",
    n_layers=4,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1536,
    vocab_size=8192,
)

BATCH, SEQ = 4, 512


def compiled_flops(train: bool) -> float:
    struct = lm.lm_param_struct(CAL_CFG)
    toks = jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)
    labels = jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32)

    def fwd(params, tokens):
        h = params["embed"][tokens].astype(jnp.bfloat16)
        h, aux = lm.lm_backbone(params, h, CAL_CFG, remat=False, unroll=True)
        return lm.lm_logits(params, h, CAL_CFG)

    if train:
        from repro.models.layers import softmax_cross_entropy

        def loss(params, tokens, labels):
            logits = fwd(params, tokens)
            return jnp.mean(softmax_cross_entropy(logits, labels))

        f = jax.jit(jax.grad(loss))
        lowered = f.lower(struct, toks, labels)
    else:
        lowered = jax.jit(fwd).lower(struct, toks)
    return float(lowered.compile().cost_analysis().get("flops", 0.0))


def main() -> int:
    out = {}
    for train in (False, True):
        shape = ShapeSpec("cal", "train" if train else "prefill", SEQ, BATCH)
        ana = analytic_report(CAL_CFG, shape, dp=1, tp=1, remat=False)
        hlo = compiled_flops(train)
        # analytic counts the optimizer+grad-clip update (~12 flops/param);
        # the calibration graph is grad-only, so compare backbone flops
        ana_f = ana["flops_per_device"]
        if train:
            ana_f -= 12.0 * CAL_CFG.param_count()
        ratio = ana_f / hlo
        out["train" if train else "forward"] = {
            "analytic_flops": ana_f, "hlo_flops": hlo, "ratio": ratio}
        print(f"{'train' if train else 'fwd '}: analytic {ana_f:.3e} vs "
              f"compiled {hlo:.3e} → ratio {ratio:.3f}")
    with open("reports/calibration.json", "w") as f:
        json.dump(out, f, indent=1)
    ok = all(0.8 < v["ratio"] < 1.25 for v in out.values())
    print("calibration", "OK" if ok else "OUT OF BAND")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
