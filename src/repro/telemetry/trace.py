"""Thread-safe span tracer exporting Chrome trace-event JSON.

The whole stack shares one process-wide :class:`Tracer` (the module-level
singleton, like the chaos registry): the training thread's Plan, the CP
thread's Pack/Place/Commit, transfer-pool chunk uploads, the supervisor's
worker lifecycle and every serving replica's pull/swap all record onto one
timeline, separated into per-thread tracks by the trace-event ``tid``.

Disabled cost is near zero by design: :func:`span` and :func:`instant`
read one attribute and return a shared no-op object — no allocation, no
lock, no clock read.  Nothing in the hot path pays for telemetry until it
is switched on.

Event model (the subset of the Chrome trace-event format perfetto loads):

- ``B``/``E`` duration pairs per (pid, tid) — spans nest per thread track
- ``i`` instant events (chaos fault fires, deploy swaps, train resume)
- ``M`` metadata events naming the process and each thread track

Activation:

- in-process: :func:`enable` (optionally with an export path)
- by environment — the multi-process protocol:
  ``OPENCHK_TRACE=/path/trace.json`` writes one file at process exit;
  ``OPENCHK_TRACE_DIR=/dir`` writes ``trace-<pid>.json`` into the shared
  dir, so a supervisor and its (restarted) workers each contribute a file
  and :func:`merge_dir` folds them into one perfetto-loadable timeline.
  The env is read lazily on first use, so launchers may set it from CLI
  flags before the first traced operation.

Hard kills: ``os._exit`` skips atexit, so the chaos registry calls
:func:`flush` immediately before an exit-mode fault — the fault's instant
event (and every span before it) is on disk before the process dies, which
is what lets ``chktrace`` show fault → death → restart → resume end to
end.  :func:`flush` is idempotent and atomic (tmp + replace).

Timestamps are wall-clock microseconds (``time.time_ns``), the one
timebase that lines up across processes when files are merged.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
from typing import Any, Dict, List, Optional

TRACE_ENV = "OPENCHK_TRACE"
TRACE_DIR_ENV = "OPENCHK_TRACE_DIR"

_PRIMITIVES = (str, int, float, bool)


def _now_us() -> int:
    return time.time_ns() // 1000


def _clean_args(args: Dict[str, Any]) -> Dict[str, Any]:
    """Trace args must be JSON-serializable; stringify anything exotic."""
    out = {}
    for k, v in args.items():
        out[k] = v if (v is None or isinstance(v, _PRIMITIVES)) else str(v)
    return out


class _NullSpan:
    """The disabled fast path: one shared, stateless, reusable no-op."""

    __slots__ = ()
    id = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def event(self, name: str, **args: Any) -> None:
        return None


NULL_SPAN = _NullSpan()


class Span:
    """An open ``B`` event; ``__exit__``/``end`` writes the matching ``E``.

    Spans are thread-affine (B/E pairs nest per tid), which is exactly the
    Chrome trace-event contract — cross-thread stages (Plan on the caller,
    the tail on the CP thread) are separate spans correlated by args."""

    __slots__ = ("tracer", "name", "id", "_tid", "_done")

    def __init__(self, tracer: "Tracer", name: str, span_id: int, tid: int):
        self.tracer = tracer
        self.name = name
        self.id = span_id
        self._tid = tid
        self._done = False

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def end(self) -> None:
        if self._done:
            return
        self._done = True
        self.tracer._record({"ph": "E", "ts": _now_us(),
                             "pid": os.getpid(), "tid": self._tid})

    def event(self, name: str, **args: Any) -> None:
        """An instant inside this span's track."""
        self.tracer.instant(name, **args)


class Tracer:
    """Event recorder + exporter.  All mutation is under one lock; the
    disabled path never takes it."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._named_tids: set = set()
        self._next_id = 0
        self._path: Optional[str] = None
        self._atexit_armed = False
        self._env_checked = False
        self.enabled = False

    # -- activation ------------------------------------------------------ #

    def _check_env(self) -> None:
        """Lazy one-shot env activation (subprocess protocol)."""
        with self._lock:
            if self._env_checked:
                return
            self._env_checked = True
        path = os.environ.get(TRACE_ENV, "")
        d = os.environ.get(TRACE_DIR_ENV, "")
        if not path and d:
            path = os.path.join(d, f"trace-{os.getpid()}.json")
        if path:
            self.enable(path)

    def ensure_enabled_checked(self) -> bool:
        """→ whether tracing is on, reading the env protocol on first call."""
        if not self._env_checked:
            self._check_env()
        return self.enabled

    def enable(self, path: Optional[str] = None) -> None:
        """Start recording; with *path*, also flush there at process exit."""
        with self._lock:
            self._env_checked = True
            self._path = path or self._path
            self.enabled = True
            arm = self._path is not None and not self._atexit_armed
            if arm:
                self._atexit_armed = True
        if arm:
            atexit.register(self.flush)
        self._record({"ph": "M", "name": "process_name",
                      "ts": _now_us(), "pid": os.getpid(), "tid": 0,
                      "args": {"name": " ".join(sys.argv[:3]) or "python"}})

    def disable(self) -> None:
        with self._lock:
            self.enabled = False

    def reset(self) -> None:
        """Drop recorded events (and named-thread memory); keep settings."""
        with self._lock:
            self._events = []
            self._named_tids = set()

    # -- recording ------------------------------------------------------- #

    def _record(self, ev: Dict[str, Any]) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._events.append(ev)

    def _track(self) -> int:
        """Current thread's tid, emitting its name metadata once."""
        t = threading.current_thread()
        tid = t.ident or 0
        if tid not in self._named_tids:
            with self._lock:
                first = tid not in self._named_tids
                self._named_tids.add(tid)
            if first:
                self._record({"ph": "M", "name": "thread_name",
                              "ts": _now_us(), "pid": os.getpid(),
                              "tid": tid, "args": {"name": t.name}})
        return tid

    def span(self, name: str, cat: str = "openchk", **args: Any):
        """Open a span (context manager).  Disabled → shared no-op."""
        if not self.ensure_enabled_checked():
            return NULL_SPAN
        tid = self._track()
        with self._lock:
            self._next_id += 1
            sid = self._next_id
        ev: Dict[str, Any] = {"ph": "B", "name": name, "cat": cat,
                              "ts": _now_us(), "pid": os.getpid(),
                              "tid": tid}
        if args:
            ev["args"] = dict(_clean_args(args), span_id=sid)
        else:
            ev["args"] = {"span_id": sid}
        self._record(ev)
        return Span(self, name, sid, tid)

    def instant(self, name: str, cat: str = "openchk", scope: str = "t",
                **args: Any) -> None:
        """A zero-duration marker on the current thread's track."""
        if not self.ensure_enabled_checked():
            return
        self._record({"ph": "i", "name": name, "cat": cat, "s": scope,
                      "ts": _now_us(), "pid": os.getpid(),
                      "tid": self._track(),
                      "args": _clean_args(args)})

    # -- export ---------------------------------------------------------- #

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str, clear: bool = False) -> str:
        """Atomically write the trace to *path* (tmp + replace)."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = f"{path}.tmp{os.getpid()}"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(self.to_json(), f)
        os.replace(tmp, path)
        if clear:
            self.reset()
        return path

    def flush(self) -> Optional[str]:
        """Write to the configured path, if any.  Safe pre-``os._exit``:
        never raises (a dying process must die, not hang on telemetry)."""
        with self._lock:
            path = self._path
        if path is None:
            return None
        try:
            return self.export(path)
        except OSError:
            return None

    def trace_dir(self) -> Optional[str]:
        """The shared multi-process dir, when env-activated with one."""
        self.ensure_enabled_checked()
        return os.environ.get(TRACE_DIR_ENV) or None


# -- module-level singleton + conveniences ---------------------------------
_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER.ensure_enabled_checked()


def enable(path: Optional[str] = None) -> None:
    _TRACER.enable(path)


def disable() -> None:
    _TRACER.disable()


def span(name: str, cat: str = "openchk", **args: Any):
    if not _TRACER.enabled and _TRACER._env_checked:
        return NULL_SPAN                     # the hot no-op path
    return _TRACER.span(name, cat=cat, **args)


def instant(name: str, cat: str = "openchk", **args: Any) -> None:
    if not _TRACER.enabled and _TRACER._env_checked:
        return
    _TRACER.instant(name, cat=cat, **args)


def export(path: str, clear: bool = False) -> str:
    return _TRACER.export(path, clear=clear)


def flush() -> Optional[str]:
    return _TRACER.flush()


def reset() -> None:
    _TRACER.reset()


def merge_dir(trace_dir: str, out_path: Optional[str] = None) -> Optional[str]:
    """Fold every ``trace-*.json`` under *trace_dir* into one file.

    Chrome trace events carry their pid, so merging is concatenation —
    perfetto renders each contributing process as its own track group.
    Unreadable files are skipped (a worker killed mid-write must not
    break the supervisor's merge).  → the merged path, or None if the
    dir held no readable events."""
    events: List[Dict[str, Any]] = []
    try:
        names = sorted(os.listdir(trace_dir))
    except OSError:
        return None
    for fn in names:
        if not (fn.startswith("trace-") and fn.endswith(".json")):
            continue
        try:
            with open(os.path.join(trace_dir, fn), encoding="utf-8") as f:
                events.extend(json.load(f).get("traceEvents", []))
        except (OSError, ValueError):
            continue
    if not events:
        return None
    out_path = out_path or os.path.join(trace_dir, "trace.json")
    tmp = f"{out_path}.tmp{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    os.replace(tmp, out_path)
    return out_path
