"""Pallas kernels vs jnp oracles — shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.blockhash import BE, BR, blockhash2_pallas, blockhash_pallas
from repro.kernels.diffpack import diffpack_pallas, diffunpack_pallas


@pytest.mark.parametrize("rows_mult,elems_mult", [(1, 1), (2, 1), (1, 3), (4, 2)])
def test_blockhash_matches_ref(rows_mult, elems_mult):
    rng = np.random.RandomState(rows_mult * 10 + elems_mult)
    x = rng.randint(0, 2**32, size=(BR * rows_mult, BE * elems_mult),
                    dtype=np.uint64).astype(np.uint32)
    got = np.asarray(blockhash_pallas(jnp.asarray(x), interpret=True))
    want = np.asarray(ref.blockhash_ref(jnp.asarray(x)))
    assert np.array_equal(got, want)


def test_blockhash2_two_lanes_differ():
    rng = np.random.RandomState(0)
    x = rng.randint(0, 2**32, size=(BR, BE), dtype=np.uint64).astype(np.uint32)
    h = np.asarray(blockhash2_pallas(jnp.asarray(x), interpret=True))
    assert h.shape == (BR, 2)
    assert not np.array_equal(h[:, 0], h[:, 1])
    assert np.array_equal(h, np.asarray(ref.blockhash2_ref(jnp.asarray(x))))


@pytest.mark.parametrize("n_blocks,elems,n_dirty",
                         [(8, 128, 3), (16, 256, 16), (4, 512, 1)])
def test_diffpack_matches_ref(n_blocks, elems, n_dirty):
    rng = np.random.RandomState(n_blocks)
    blocks = rng.randn(n_blocks, elems).astype(np.float32)
    idx = rng.choice(n_blocks, size=n_dirty, replace=False).astype(np.int32)
    got = np.asarray(diffpack_pallas(jnp.asarray(blocks), jnp.asarray(idx),
                                     interpret=True))
    want = np.asarray(ref.diffpack_ref(jnp.asarray(blocks), jnp.asarray(idx)))
    assert np.array_equal(got, want)


def test_diffunpack_matches_ref():
    rng = np.random.RandomState(3)
    base = rng.randn(16, 128).astype(np.float32)
    idx = np.array([1, 7, 13], np.int32)
    packed = rng.randn(3, 128).astype(np.float32)
    got = np.asarray(diffunpack_pallas(
        jnp.asarray(base), jnp.asarray(packed), jnp.asarray(idx),
        interpret=True))
    want = np.asarray(ref.diffunpack_ref(
        jnp.asarray(base), jnp.asarray(packed), jnp.asarray(idx)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32,
                                   jnp.float64, jnp.uint8])
def test_ops_blockhash_dtypes(dtype):
    if dtype == jnp.float64:
        x = jnp.arange(1000).astype(jnp.float32).astype(dtype)
    else:
        x = jnp.arange(1000).astype(dtype)
    h = ops.blockhash(x, 256)
    assert h.dtype == jnp.uint32 and h.shape[1] == 2
    # deterministic
    assert np.array_equal(np.asarray(h), np.asarray(ops.blockhash(x, 256)))
    # sensitive to any element change (use a value exactly representable in
    # every tested dtype — bf16 rounds 999+1 back to 1000 == original)
    x2 = x.at[999].set(jnp.asarray(-5).astype(dtype))
    assert not np.array_equal(np.asarray(h),
                              np.asarray(ops.blockhash(x2, 256)))


def test_ops_dirty_indices():
    h1 = np.zeros((10, 2), np.uint32)
    h2 = h1.copy()
    h2[3, 0] = 1
    h2[7, 1] = 9
    assert ops.dirty_indices(h2, h1).tolist() == [3, 7]
    assert ops.dirty_indices(h2, None).tolist() == list(range(10))


def test_ops_pack_dirty_roundtrip():
    x = jnp.arange(4096, dtype=jnp.float32)
    idx = jnp.asarray([0, 5], dtype=jnp.int32)
    packed = ops.pack_dirty(x, idx, 2, 256)
    blocks, _ = ops.as_u32_blocks(x, 256)
    assert np.array_equal(np.asarray(packed),
                          np.asarray(blocks)[np.asarray(idx)])
