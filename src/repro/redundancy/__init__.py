"""Redundancy schemes: partner copies, XOR/Reed-Solomon erasure groups."""
