"""Erasure coding for L3 checkpoints: XOR parity and GF(2^8) Reed–Solomon.

FTI's L3 applies Reed–Solomon across a node group so any m node losses are
recoverable from the surviving payloads + parity. We implement:

- ``xor``: single parity block (RAID-5-like) — tolerates 1 loss per group;
- ``rs``: systematic Reed–Solomon over GF(256) with a Vandermonde-derived
  encoding matrix — tolerates up to ``m`` losses per group.

Payloads are byte strings of (possibly) different lengths; they are
zero-padded to the group max internally and lengths recorded by the caller.
numpy table-driven GF math: fast enough for checkpoint-sized payloads.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

# ---------------------------------------------------------------------- #
# GF(256) tables (AES polynomial 0x11d, generator 2)
# ---------------------------------------------------------------------- #

_EXP = np.zeros(512, np.uint8)
_LOG = np.zeros(256, np.int32)
_x = 1
for _i in range(255):
    _EXP[_i] = _x
    _LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= 0x11D
for _i in range(255, 512):
    _EXP[_i] = _EXP[_i - 255]


def gf_mul(a: np.ndarray, b: int) -> np.ndarray:
    """Multiply byte array by scalar in GF(256)."""
    if b == 0:
        return np.zeros_like(a)
    if b == 1:
        return a.copy()
    lb = int(_LOG[b])
    out = np.zeros_like(a)
    nz = a != 0
    out[nz] = _EXP[_LOG[a[nz]] + lb]
    return out


def _gf_mul_scalar(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(_EXP[int(_LOG[a]) + int(_LOG[b])])


def _gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError
    return int(_EXP[255 - int(_LOG[a])])


def _gf_matinv(m: np.ndarray) -> np.ndarray:
    """Invert a small GF(256) matrix (Gauss-Jordan)."""
    n = m.shape[0]
    a = m.astype(np.int32).copy()
    inv = np.eye(n, dtype=np.int32)
    for col in range(n):
        piv = None
        for r in range(col, n):
            if a[r, col] != 0:
                piv = r
                break
        if piv is None:
            raise np.linalg.LinAlgError("singular GF matrix")
        a[[col, piv]] = a[[piv, col]]
        inv[[col, piv]] = inv[[piv, col]]
        s = _gf_inv(int(a[col, col]))
        for c in range(n):
            a[col, c] = _gf_mul_scalar(int(a[col, c]), s)
            inv[col, c] = _gf_mul_scalar(int(inv[col, c]), s)
        for r in range(n):
            if r != col and a[r, col] != 0:
                f = int(a[r, col])
                for c in range(n):
                    a[r, c] ^= _gf_mul_scalar(f, int(a[col, c]))
                    inv[r, c] ^= _gf_mul_scalar(f, int(inv[col, c]))
    return inv.astype(np.uint8)


def _vandermonde(m: int, k: int) -> np.ndarray:
    """m×k encoding rows: row i = [alpha^(i·j)] — any k rows of [I; V] are
    independent (classic systematic RS construction)."""
    v = np.zeros((m, k), np.uint8)
    for i in range(m):
        for j in range(k):
            v[i, j] = _EXP[(i + 1) * j % 255]
    return v


def _pad_stack(payloads: Sequence[bytes]) -> np.ndarray:
    n = max(len(p) for p in payloads)
    out = np.zeros((len(payloads), n), np.uint8)
    for i, p in enumerate(payloads):
        out[i, : len(p)] = np.frombuffer(p, np.uint8)
    return out


# ---------------------------------------------------------------------- #
# public API
# ---------------------------------------------------------------------- #


def encode_xor(payloads: Sequence[bytes]) -> bytes:
    """Single XOR parity over the group."""
    stack = _pad_stack(payloads)
    return np.bitwise_xor.reduce(stack, axis=0).tobytes()


def decode_xor(payloads: Dict[int, bytes], parity: bytes, k: int,
               lengths: Sequence[int]) -> List[bytes]:
    """Recover the (single) missing payload from k-1 survivors + parity."""
    missing = [i for i in range(k) if i not in payloads]
    if len(missing) > 1:
        raise ValueError(f"xor parity recovers 1 loss, got {len(missing)}")
    if not missing:
        return [payloads[i][: lengths[i]] for i in range(k)]
    n = len(parity)
    acc = np.frombuffer(parity, np.uint8).copy()
    for i, p in payloads.items():
        buf = np.zeros(n, np.uint8)
        buf[: len(p)] = np.frombuffer(p, np.uint8)
        acc ^= buf
    out = []
    for i in range(k):
        if i in payloads:
            out.append(payloads[i][: lengths[i]])
        else:
            out.append(acc.tobytes()[: lengths[i]])
    return out


def encode_rs(payloads: Sequence[bytes], m: int) -> List[bytes]:
    """m parity blocks over k payloads; tolerates any ≤m losses."""
    k = len(payloads)
    data = _pad_stack(payloads)                       # (k, n)
    v = _vandermonde(m, k)
    out = []
    for i in range(m):
        acc = np.zeros(data.shape[1], np.uint8)
        for j in range(k):
            acc ^= gf_mul(data[j], int(v[i, j]))
        out.append(acc.tobytes())
    return out


def decode_rs(payloads: Dict[int, bytes], parities: Dict[int, bytes], k: int,
              lengths: Sequence[int]) -> List[bytes]:
    """Recover all k payloads from any k of (payloads ∪ parities)."""
    missing = [i for i in range(k) if i not in payloads]
    if not missing:
        return [payloads[i][: lengths[i]] for i in range(k)]
    if len(payloads) + len(parities) < k:
        raise ValueError("not enough survivors for RS decode")
    n = max(
        [len(p) for p in payloads.values()] + [len(p) for p in parities.values()])
    m_all = max(parities) + 1 if parities else 0
    v = _vandermonde(m_all, k) if m_all else np.zeros((0, k), np.uint8)

    rows, rhs = [], []
    for i in sorted(payloads):
        r = np.zeros(k, np.uint8)
        r[i] = 1
        rows.append(r)
        buf = np.zeros(n, np.uint8)
        b = payloads[i]
        buf[: len(b)] = np.frombuffer(b, np.uint8)
        rhs.append(buf)
    for i in sorted(parities):
        rows.append(v[i])
        buf = np.zeros(n, np.uint8)
        b = parities[i]
        buf[: len(b)] = np.frombuffer(b, np.uint8)
        rhs.append(buf)
    # pick k independent equations (identity rows first; try parity subsets
    # if a Vandermonde subset happens to be dependent with the survivors)
    import itertools

    base = list(range(len(payloads)))
    extra = list(range(len(payloads), len(rows)))
    need = k - len(base)
    ainv = None
    chosen = None
    for combo in itertools.combinations(extra, need):
        idx = base + list(combo)
        try:
            ainv = _gf_matinv(np.stack([rows[i] for i in idx]))
            chosen = idx
            break
        except np.linalg.LinAlgError:
            continue
    if ainv is None:
        raise np.linalg.LinAlgError("no independent equation subset")
    b = np.stack([rhs[i] for i in chosen])
    data = np.zeros((k, n), np.uint8)
    for i in range(k):
        acc = np.zeros(n, np.uint8)
        for j in range(k):
            acc ^= gf_mul(b[j], int(ainv[i, j]))
        data[i] = acc
    return [data[i, : lengths[i]].tobytes() for i in range(k)]
