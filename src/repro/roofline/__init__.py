"""Roofline analysis from compiled dry-run artifacts."""
