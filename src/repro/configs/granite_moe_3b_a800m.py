"""granite-moe-3b-a800m — fine-grained MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

Assignment spec: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
MoE 40 experts top-8 (narrow experts, high top-k).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

ARCH = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    moe=MoEConfig(n_experts=40, top_k=8, capacity_factor=1.5),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
