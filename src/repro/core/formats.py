"""CHK5 — an HDF5-inspired self-describing hierarchical checkpoint container.

The paper's §4.2.4 stores checkpoints in HDF5 so resilience data doubles as
analyzable scientific data. h5py is not available in this container, so we
implement the format from scratch with the same semantics:

- hierarchical **groups** ("/data/params/...", "/delta/...", ...)
- typed **datasets** (dtype, shape, crc32, byte offset) supporting partial
  (byte-range) reads — required for elastic resharding restores
- **attributes** on groups and datasets (JSON-serializable)
- a msgpack **index** at the tail, so a file is readable without scanning

Sharded stores spread one rank payload over a *set* of containers
(core/resharding.py): the main ``rank<r>.chk5`` holds a ``shardidx/<name>``
dataset per sharded leaf (chunk offsets/shapes as data; global shape,
dtype and chunk file/dataset names as attributes) while the shard payloads
live as ``shard/<name>/shard-<k>`` datasets in sibling
``rank<r>.shard<j>.chk5`` files, written in parallel.  Restores read only
the byte ranges overlapping the regions a target device needs
(``read_range``).

Layout::

    [8B magic "CHK5\\x00\\x01\\x00\\x00"]
    [dataset payloads ... raw C-order bytes]
    [msgpack index]
    [8B u64 index length][4B crc32(index)][8B magic tail "5KHC...."]

Writers are append-only; readers are mmap-free (seek+read) so partial
restores touch only the bytes they need. ``python -m repro.tools.chkls``
pretty-prints any CHK5 file (the "use any HDF5 tool" analogue).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Any, Dict, List, Optional

import msgpack
import numpy as np

MAGIC = b"CHK5\x00\x01\x00\x00"
TAIL = b"5KHC\x00\x01\x00\x00"

try:  # numpy has no native bfloat16; jax ships ml_dtypes
    import ml_dtypes
    _EXTRA_DTYPES = {
        "bfloat16": np.dtype(ml_dtypes.bfloat16),
        "float8_e4m3fn": np.dtype(ml_dtypes.float8_e4m3fn),
        "float8_e5m2": np.dtype(ml_dtypes.float8_e5m2),
    }
except ImportError:  # pragma: no cover
    _EXTRA_DTYPES = {}


def dtype_to_str(dt) -> str:
    dt = np.dtype(dt)
    for name, cand in _EXTRA_DTYPES.items():
        if dt == cand:
            return name
    return dt.str


def str_to_dtype(s: str) -> np.dtype:
    if s in _EXTRA_DTYPES:
        return _EXTRA_DTYPES[s]
    return np.dtype(s)


def resolve_precision(name: str) -> np.dtype:
    """``Protect(precision=...)`` clause value → numpy dtype.

    Accepts the clause aliases ("bf16", "fp16", "f32", …) and canonical
    dtype strings.  bf16/fp8 need ml_dtypes (jax ships it); a missing
    dependency surfaces as a clear error rather than a silent fallback."""
    from repro.core.protect import PRECISIONS
    canonical = PRECISIONS.get(name, name)
    if canonical == "bfloat16" and "bfloat16" not in _EXTRA_DTYPES:
        raise ValueError(
            "precision='bf16' needs ml_dtypes for a numpy bfloat16; "
            "it is not importable in this environment")
    return str_to_dtype(canonical)


class CHK5Writer:
    def __init__(self, path: str, fsync: bool = True, sink=None):
        """``fsync=False`` defers durability to the caller (multi-file
        shard sets fsync the whole batch once all writers finished — one
        journal settle instead of one per file).

        ``sink`` is an optional streaming byte sink (the fused Pack →
        chunk-stream path, ``repro.objstore.chunks.ChunkStream``): every
        byte written to the file is teed into it in order, dataset starts
        are signaled as boundary hints (``cut``), datasets with an entry
        in :attr:`region_keys` are bracketed as digest-keyed regions
        (``begin_region``/``end_region``), and ``close`` finishes the
        sink — so by the time the staged file is durable, its chunk
        uploads are already in flight and nothing re-reads it."""
        self.path = path
        self._fsync = fsync
        self._sink = sink
        #: dataset name → layout-reuse key (set by the pipeline for FULL
        #: leaves whose device-side digests identify their bytes)
        self.region_keys: Dict[str, str] = {}
        self._f = open(path, "wb")
        self._write(MAGIC)
        self._index: Dict[str, Any] = {"groups": {}, "datasets": {}, "attrs": {}}
        self._closed = False

    def _write(self, payload) -> None:
        self._f.write(payload)
        if self._sink is not None:
            self._sink.write(payload)

    # ------------------------------------------------------------------ #

    def set_attrs(self, group: str, attrs: Dict[str, Any]) -> None:
        self._index["attrs"].setdefault(group, {}).update(attrs)

    def write_dataset(self, name: str, arr: np.ndarray,
                      attrs: Optional[Dict[str, Any]] = None) -> None:
        """``name`` is a slash path, e.g. "data/params/embed"."""
        arr = np.asarray(arr)
        shape = list(arr.shape)              # ascontiguousarray promotes 0-d
        arr = np.ascontiguousarray(arr)
        off = self._f.tell()
        try:
            # zero-copy write path: the array's own buffer feeds both the
            # file write and the crc (a tobytes() copy of a large leaf is
            # pure overhead on the Pack path)
            payload = memoryview(arr).cast("B")
        except (TypeError, ValueError):
            # non-buffer dtypes (ml_dtypes bf16/fp8) fall back to a copy
            payload = arr.tobytes()
        region = self._sink is not None and \
            self.region_keys.get(name.strip("/"))
        if region:
            self._sink.begin_region(region)
        elif self._sink is not None:
            self._sink.cut()
        self._write(payload)
        if region:
            self._sink.end_region()
        parts = name.strip("/").split("/")
        for i in range(1, len(parts)):
            self._index["groups"].setdefault("/".join(parts[:i]), {})
        self._index["datasets"][name.strip("/")] = {
            "offset": off,
            "nbytes": arr.nbytes,
            "dtype": dtype_to_str(arr.dtype),
            "shape": shape,
            "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
            "attrs": attrs or {},
        }

    def write_bytes(self, name: str, payload: bytes,
                    attrs: Optional[Dict[str, Any]] = None) -> None:
        off = self._f.tell()
        if self._sink is not None:
            self._sink.cut()
        self._write(payload)
        self._index["datasets"][name.strip("/")] = {
            "offset": off,
            "nbytes": len(payload),
            "dtype": "bytes",
            "shape": [len(payload)],
            "crc32": zlib.crc32(payload) & 0xFFFFFFFF,
            "attrs": attrs or {},
        }

    def close(self) -> None:
        if self._closed:
            return
        idx = msgpack.packb(self._index, use_bin_type=True)
        self._write(idx)
        self._write(struct.pack("<Q", len(idx)))
        self._write(struct.pack("<I", zlib.crc32(idx) & 0xFFFFFFFF))
        self._write(TAIL)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
        self._f.close()
        self._closed = True
        if self._sink is not None:
            # the file is complete: freeze the stream's chunk metadata
            # (uploads keep draining; Place/Commit collect and join them)
            self._sink.finish()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()


class CHK5CorruptionError(RuntimeError):
    pass


class CHK5Reader:
    def __init__(self, path, verify: bool = False):
        """``path``: filesystem path or a seekable binary file object."""
        if hasattr(path, "seek"):
            self.path = "<memory>"
            self._f = path
            self._f.seek(0)
        else:
            self.path = path
            self._f = open(path, "rb")
        head = self._f.read(8)
        if head != MAGIC:
            raise CHK5CorruptionError(f"{path}: bad magic {head!r}")
        self._f.seek(-20, os.SEEK_END)
        tail = self._f.read(20)
        idx_len = struct.unpack("<Q", tail[:8])[0]
        idx_crc = struct.unpack("<I", tail[8:12])[0]
        if tail[12:] != TAIL:
            raise CHK5CorruptionError(f"{path}: bad tail magic")
        self._f.seek(-(20 + idx_len), os.SEEK_END)
        idx_raw = self._f.read(idx_len)
        if (zlib.crc32(idx_raw) & 0xFFFFFFFF) != idx_crc:
            raise CHK5CorruptionError(f"{path}: index crc mismatch")
        self._index = msgpack.unpackb(idx_raw, raw=False)
        if verify:
            self.verify_all()

    # ------------------------------------------------------------------ #

    def datasets(self) -> List[str]:
        return sorted(self._index["datasets"])

    def groups(self) -> List[str]:
        return sorted(self._index["groups"])

    def attrs(self, group: str = "") -> Dict[str, Any]:
        return self._index["attrs"].get(group, {})

    def info(self, name: str) -> Dict[str, Any]:
        return self._index["datasets"][name.strip("/")]

    def read_dataset(self, name: str, verify: bool = True) -> np.ndarray:
        m = self.info(name)
        self._f.seek(m["offset"])
        raw = self._f.read(m["nbytes"])
        if verify and (zlib.crc32(raw) & 0xFFFFFFFF) != m["crc32"]:
            raise CHK5CorruptionError(f"{self.path}:{name}: payload crc mismatch")
        if m["dtype"] == "bytes":
            raise TypeError(f"{name} is a raw-bytes dataset; use read_bytes")
        return np.frombuffer(raw, dtype=str_to_dtype(m["dtype"])).reshape(m["shape"])

    def read_bytes(self, name: str, verify: bool = True) -> bytes:
        m = self.info(name)
        self._f.seek(m["offset"])
        raw = self._f.read(m["nbytes"])
        if verify and (zlib.crc32(raw) & 0xFFFFFFFF) != m["crc32"]:
            raise CHK5CorruptionError(f"{self.path}:{name}: payload crc mismatch")
        return raw

    def read_range(self, name: str, start_elem: int, n_elems: int) -> np.ndarray:
        """Partial read of a flattened C-order element range (no crc check —
        used by elastic resharding to touch only required bytes)."""
        m = self.info(name)
        dt = str_to_dtype(m["dtype"])
        self._f.seek(m["offset"] + start_elem * dt.itemsize)
        raw = self._f.read(n_elems * dt.itemsize)
        return np.frombuffer(raw, dtype=dt)

    def verify_all(self) -> None:
        for name, m in self._index["datasets"].items():
            self._f.seek(m["offset"])
            raw = self._f.read(m["nbytes"])
            if (zlib.crc32(raw) & 0xFFFFFFFF) != m["crc32"]:
                raise CHK5CorruptionError(f"{self.path}:{name}: crc mismatch")

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
