"""Heat-2D against the native FTI-style API: manual protect registration,
explicit status/recover flow modification, manual re-protect before every
checkpoint, error handling — everything OpenCHK hides (paper Fig. 14)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.apps.heat2d_common import checksum, heat_step, init_grid
from repro.backends.fti import FTIBackend                                  # [CR]
from repro.core.comm import LocalComm                                      # [CR]
from repro.core.storage import StorageConfig                               # [CR]


def run(n=128, steps=200, ckpt_every=20, ckpt_dir="/tmp/heat-fti",
        injector=None, backend=None):
    grid = init_grid(n)
    t = 0
    fti = FTIBackend(StorageConfig(root=ckpt_dir),                         # [CR]
                     LocalComm(ckpt_dir + "/node-local"),                  # [CR]
                     dedicated_thread=True)    # FTI has CP threads too      [CR]
    fti.protect(0, "t", np.int32(t))                                       # [CR]
    fti.protect(1, "grid", np.asarray(grid))                               # [CR]
    if fti.status():                                # modified program flow   [CR]
        recovered = fti.recover()                                          # [CR]
        t = int(recovered[0])                       # manual deserialization [CR]
        grid = jnp.asarray(recovered[1])                                   # [CR]
    restarted = t > 0                                                      # [CR]
    for step in range(t, steps):
        grid = heat_step(grid)
        if injector is not None:
            injector.maybe_fail(step + 1)
        if (step + 1) % ckpt_every == 0:                                   # [CR]
            fti.protect(0, "t", np.int32(step + 1))  # manual re-serialize   [CR]
            fti.protect(1, "grid", np.asarray(grid))                       # [CR]
            try:                                                           # [CR]
                fti.checkpoint(step + 1, level=1)   # async; errors surface  [CR]
            except RuntimeError as e:               # at the NEXT call       [CR]
                raise RuntimeError("FTI internal error") from e            # [CR]
    fti.checkpoint_wait()                                                  # [CR]
    fti.finalize()                                                         # [CR]
    return {"checksum": checksum(grid), "restarted": restarted}
