"""Production mesh construction (multi-pod dry-run deliverable).

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (examples/tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
