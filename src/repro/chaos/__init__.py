"""repro.chaos — fault-scenario harness + Daly-optimal checkpoint cadence.

Three layers (ROADMAP "adaptive cadence + fault-scenario harness"):

    inject.py     the injection plane: named fault sites at the seams the
                  stack already has (tier place/commit, objstore put/get,
                  chunk-stream boundaries, heartbeat, deploy polls, train
                  steps), armed in-process or via the ``OPENCHK_CHAOS``
                  env/JSON spec so subprocess children arm the same faults
                  without code changes.
    scenarios.py  declarative end-to-end fault scenarios (node loss
                  mid-store, straggler demotion, mesh shrink, objstore
                  outage, corrupt chunk), each run as
                  store → inject → restart → verify-bit-exact.
    runner.py     drives the scenario matrix across backends and emits a
                  machine-readable report (faults fired, recovery path,
                  recovery wall time, data loss).
    cadence.py    Daly's optimum-interval equations: per-tier checkpoint
                  intervals from measured store cost, recovery cost and an
                  online MTBF estimate — frequent L1, Daly-optimal L4 —
                  plus progress-rate / checkpoint-efficiency datapoints.
"""
from repro.chaos.inject import (  # noqa: F401
    ChaosRegistry,
    FaultSpec,
    InjectedFault,
    arm,
    fire,
    registry,
    reset,
)
