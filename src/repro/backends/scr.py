"""SCR-like backend: file-mode with ``route_file`` semantics and SCR's
start/complete checkpoint-phase protocol + custom redundancy groups.

The user (or TCL) is handed a *path* to write; SCR decides where that path
lives (which tier), and on ``complete_checkpoint`` enters the shared
pipeline at the Place stage — redundancy and the manifest commit are
pipeline code, not SCR code.  Restart discovery (`have_restart` →
`start_restart` → route → complete) reads through the same recovery ladder.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from repro.backends.base import Backend
from repro.core import manifest as mf
from repro.core.comm import Communicator
from repro.core.formats import CHK5Writer
from repro.core.protect import to_host
from repro.core.resharding import split_sharded, write_shard_files
from repro.core.storage import CHK_FULL, StorageConfig, StoreReport
from repro.core.tiers import pack_named


class SCRBackend(Backend):
    name = "scr"
    supports_diff = False            # SCR has no checkpoint kinds
    supports_dedicated_thread = False
    supports_incremental = True
    max_level = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 checkpoint_interval: int = 1,
                 dedicated_thread: Optional[bool] = None):
        # dedicated_thread accepted for a uniform construction surface;
        # SCR declares no CP-thread support, so it can only stay False
        super().__init__(cfg, comm, dedicated_thread=dedicated_thread)
        self._phase: Optional[str] = None
        self._cur_id: Optional[int] = None
        self._cur_level: int = 2
        self._routed: Dict[str, str] = {}
        self._since_ckpt = 0
        self._interval = checkpoint_interval

    # ----------------------- native SCR-style API ---------------------- #

    def need_checkpoint(self) -> bool:
        self._since_ckpt += 1
        return self._since_ckpt >= self._interval

    def start_checkpoint(self, ckpt_id: int, level: int = 2) -> None:
        assert self._phase is None, "nested SCR checkpoint phase"
        self._phase = "ckpt"
        self._cur_id = ckpt_id
        self._cur_level = level
        mf.begin(self.pipeline.tier_root(level), ckpt_id)
        self._routed.clear()
        self._since_ckpt = 0

    def route_file(self, name: str) -> str:
        """SCR_Route_file: where should this rank write ``name``?"""
        assert self._phase in ("ckpt", "restart"), "route_file outside phase"
        if self._phase == "ckpt":
            root = self.pipeline.tier_root(self._cur_level)
            d = mf.ckpt_dir(root, self._cur_id, tmp=True)
            path = os.path.join(d, f"rank{self.comm.rank}.chk5")
            self._routed[name] = path
            return path
        root, cid = self._restart_src
        return os.path.join(mf.ckpt_dir(root, cid), f"rank{self.comm.rank}.chk5")

    def complete_checkpoint(self, valid: bool,
                            extra_files: Optional[list] = None
                            ) -> Optional[StoreReport]:
        assert self._phase == "ckpt"
        self._phase = None
        ckpt_id, level = self._cur_id, self._cur_level
        plan = self.pipeline.plan_external(ckpt_id, level,
                                           extra_meta={"file_mode": True})
        if not valid:
            mf.abort(plan.root, ckpt_id)
            return None
        d = mf.ckpt_dir(plan.root, ckpt_id, tmp=True)
        nbytes = sum(os.path.getsize(p) for p in
                     (os.path.join(d, f) for f in os.listdir(d))
                     if os.path.isfile(p))
        payload = next(iter(self._routed.values()), os.path.join(
            d, f"rank{self.comm.rank}.chk5"))
        rep = self.pipeline.finish_external(plan, payload, nbytes,
                                            extra_files=extra_files)
        self.stats["stores"] += 1
        self.stats["bytes"] += nbytes
        return rep

    def have_restart(self) -> Optional[int]:
        ids = self.engine.available_ids()
        return ids[-1][0] if ids else None

    def start_restart(self) -> Optional[int]:
        ids = self.engine.available_ids()
        if not ids:
            return None
        cid, root = ids[-1]
        self._phase = "restart"
        self._restart_src = (root, cid)
        return cid

    def complete_restart(self, ok: bool) -> None:
        assert self._phase == "restart"
        self._phase = None
        if ok:
            self.stats["loads"] += 1

    # ----------------------- TCL uniform surface ----------------------- #

    def tcl_store(self, req, ckpt_id=None, level=None,
                  kind=None) -> Optional[StoreReport]:
        """File-mode store: SCR routes the path and writes the container
        itself, but leaf encoding still runs the shared Pack-tier chain —
        clause specs (compression codec, format attrs, precision) apply
        identically on all backends.  Kind clauses fall back to FULL (SCR
        has no checkpoint kinds)."""
        req = self.as_request(req, ckpt_id, level, kind)
        if req.wants_diff:
            self.stats["diff_fallbacks"] += 1      # SCR: kinds unsupported
        self.start_checkpoint(req.ckpt_id, min(req.level, self.max_level))
        path = self.route_file("openchk.chk5")
        # sharded leaves snapshot shard-locally here too — the shard files
        # land next to the routed container (same .tmp staging dir), so
        # file-mode stores keep the atomic multi-file commit
        gather, sharded = split_sharded(req.named,
                                        enabled=self.cfg.sharded_store)
        named_host = {k: np.asarray(v)
                      for k, v in to_host(gather).items()}
        shard_files: list = []
        with CHK5Writer(path) as w:
            attrs = {"kind": CHK_FULL, "id": req.ckpt_id}
            if sharded:
                attrs["sharded"] = True
            w.set_attrs("", attrs)
            if sharded:
                shard_files = write_shard_files(
                    os.path.dirname(path), f"rank{self.comm.rank}", w,
                    sharded, req.specs, default_kind=CHK_FULL,
                    max_writers=self.cfg.shard_writers)
            pack_named(w, named_host, req.specs,
                       self.pipeline.pack_tiers)
        return self.complete_checkpoint(valid=True, extra_files=shard_files)

    def tcl_load(self, req=None):
        cid = self.start_restart()
        if cid is None:
            return None
        self.route_file("openchk.chk5")
        # read through the shared recovery ladder: codec datasets decode
        # roundtrip-verified, sharded leaves come back as lazy refs for
        # TCL's mesh-aware assembly (the native route-file restart path
        # is unchanged)
        got = self.engine.load_latest(lazy_sharded=True)
        if got is None:
            self.complete_restart(False)
            return None
        self.complete_restart(True)
        return got[0]
    # tcl_wait / tcl_finalize: inherited no-op fence (no CP thread)
