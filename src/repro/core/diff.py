"""Differential checkpointing engine (paper §4.2.3, FTI dCP semantics).

Per protected leaf, a 64-bit digest per ``block_bytes`` block is kept from
the previous checkpoint. On a CHK_DIFF store the new digests are computed
*on device* (Pallas blockhash on TPU; jnp oracle on CPU), the dirty map is
diffed on host (tiny), dirty blocks are compacted on device by the diffpack
kernel and only those cross to the host.

Digest cache across stores: jax arrays are immutable, so a leaf that is the
*same object* as at the previous store cannot have changed — its digests
are reused and the blockhash kernel is skipped entirely.  Back-to-back
differential (or full) checkpoints therefore pay hashing only for leaves
that were actually replaced.  Identity is tracked with weakrefs (no device
memory is pinned); mutable ``np.ndarray`` leaves are never skipped.

All digest-state mutation happens in the pipeline's Plan stage, on the
calling thread in submission order — which is what lets DIFF stores run on
a CP-dedicated thread without racing the digest chain.

Break-even guard: the paper measures differential checkpointing to pay off
below a ~95 % dirty ratio (Fig. 7). When the observed ratio exceeds
``promote_threshold`` the engine *promotes* the store to a FULL checkpoint
(cheaper, and it shortens the restore chain).

Restore: FULL base + ordered DIFF deltas are replayed into flat uint32
buffers, then bit-cast back to the leaf dtype/shape.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import dtype_to_str as dtype_str
from repro.core.formats import str_to_dtype as str_dtype
from repro.kernels import ops


@dataclass
class LeafDelta:
    path: str
    dtype: str
    shape: List[int]
    n_blocks: int
    dirty_idx: np.ndarray        # (n_dirty,) int32
    payload: np.ndarray          # (n_dirty, block_elems) uint32
    digests: np.ndarray          # (n_blocks, 2) uint32 — post-store state


@dataclass
class DiffStats:
    total_blocks: int = 0
    dirty_blocks: int = 0
    bytes_written: int = 0
    skipped_leaves: int = 0      # clean by identity — hash kernel not run
    promoted_full: bool = False

    @property
    def dirty_ratio(self) -> float:
        return self.dirty_blocks / max(1, self.total_blocks)


def _pack_dirty_blocks(leaf: Any, dirty: np.ndarray,
                       block_bytes: int) -> np.ndarray:
    """Compact the dirty blocks on device via the diffpack kernel.

    ``pack_dirty`` jits on a static dirty count, so the index vector is
    padded to the next power of two (bounded number of compiled variants)
    and the result sliced host-side."""
    n_dirty = int(dirty.shape[0])
    n_pad = 1
    while n_pad < n_dirty:
        n_pad *= 2
    idx = np.zeros(n_pad, np.int32)
    idx[:n_dirty] = dirty
    packed = ops.pack_dirty(leaf, jnp.asarray(idx), n_pad, block_bytes)
    return np.asarray(packed)[:n_dirty]


class DiffEngine:
    def __init__(self, block_bytes: int = ops.DEFAULT_BLOCK_BYTES,
                 promote_threshold: float = 0.95):
        self.block_bytes = block_bytes
        self.promote_threshold = promote_threshold
        self._digests: Dict[str, np.ndarray] = {}
        self._clean_refs: Dict[str, "weakref.ref"] = {}
        self.epoch = 0       # bumped on invalidate(); DIFF plans check it

    # ------------------------------------------------------------------ #

    def reset(self) -> None:
        self._digests.clear()
        self._clean_refs.clear()

    def invalidate(self, paths) -> None:
        """Drop digest state for ``paths`` (a store of them failed after the
        chain advanced).  Conservative and safe: the next DIFF sees no base
        for these leaves, marks every block dirty, and the promote guard
        turns that into a FULL — never a delta against phantom data."""
        paths = list(paths)
        for p in paths:
            self._digests.pop(p, None)
            self._clean_refs.pop(p, None)
        if paths:
            self.epoch += 1

    def _is_clean(self, path: str, leaf: Any) -> bool:
        """Same immutable array object as the previous store → unchanged."""
        ref = self._clean_refs.get(path)
        return (ref is not None and ref() is leaf
                and path in self._digests)

    def _remember(self, path: str, leaf: Any) -> None:
        # only immutable arrays make identity a valid clean signal
        if isinstance(leaf, jax.Array) and not isinstance(leaf, np.ndarray):
            try:
                self._clean_refs[path] = weakref.ref(leaf)
                return
            except TypeError:
                pass
        self._clean_refs.pop(path, None)

    def digest_key(self, path: str) -> Optional[str]:
        """Compact fingerprint of ``path``'s current device-side block
        digests, or None when no digest chain exists for it (diff-unaware
        backends, never-stored leaves).  Used as a chunk-layout reuse key
        on the fused Pack → upload path: equal fingerprints mean the
        leaf's bytes are unchanged since the digests were recorded, so
        the chunk stream can replay its previous CDC cut layout instead
        of re-scanning — DIFF-clean leaves never touch host hashing."""
        d = self._digests.get(path)
        if d is None:
            return None
        return ops.digest_fingerprint(d)

    def update_digests_full(self, named: Dict[str, Any]) -> None:
        """After a FULL store: record digests so the next DIFF has a base."""
        for path, leaf in named.items():
            if self._is_clean(path, leaf):
                continue
            self._digests[path] = np.asarray(
                ops.blockhash(leaf, self.block_bytes))
            self._remember(path, leaf)

    def compute_deltas(self, named: Dict[str, Any]
                       ) -> Tuple[Optional[List[LeafDelta]], DiffStats]:
        """→ (deltas, stats); deltas=None means "promote to FULL"."""
        stats = DiffStats()
        pending: List[Tuple[str, Any, np.ndarray, np.ndarray]] = []
        for path, leaf in named.items():
            if self._is_clean(path, leaf):
                h_new = self._digests[path]
                dirty = np.zeros(0, np.int32)
                stats.skipped_leaves += 1
            else:
                h_new = np.asarray(ops.blockhash(leaf, self.block_bytes))
                dirty = ops.dirty_indices(h_new, self._digests.get(path))
            stats.total_blocks += h_new.shape[0]
            stats.dirty_blocks += int(dirty.shape[0])
            pending.append((path, leaf, h_new, dirty))

        if stats.dirty_ratio > self.promote_threshold:
            stats.promoted_full = True
            # the promoted FULL store persists exactly these leaves — commit
            # the already-computed digests so the caller need not re-hash
            for path, leaf, h_new, _dirty in pending:
                self._digests[path] = h_new
                self._remember(path, leaf)
            return None, stats

        deltas = []
        for path, leaf, h_new, dirty in pending:
            if dirty.shape[0] == 0:
                payload = np.zeros((0, self.block_bytes // 4), np.uint32)
            else:
                payload = _pack_dirty_blocks(leaf, dirty, self.block_bytes)
            stats.bytes_written += payload.nbytes
            deltas.append(LeafDelta(
                path=path,
                dtype=dtype_str(leaf.dtype),
                shape=list(leaf.shape),
                n_blocks=int(h_new.shape[0]),
                dirty_idx=dirty,
                payload=payload,
                digests=h_new,
            ))
        for d in deltas:
            self._digests[d.path] = d.digests
        for path, leaf, _h, _d in pending:
            self._remember(path, leaf)
        return deltas, stats


# -------------------------------------------------------------------------- #
# restore-side replay
# -------------------------------------------------------------------------- #


def leaf_to_u32_flat(arr: np.ndarray, block_bytes: int) -> np.ndarray:
    be = block_bytes // 4
    raw = np.ascontiguousarray(arr).tobytes()
    pad = (-len(raw)) % 4
    buf = np.frombuffer(raw + b"\x00" * pad, np.uint32)
    n_blocks = max(1, -(-buf.shape[0] // be))
    out = np.zeros(n_blocks * be, np.uint32)
    out[: buf.shape[0]] = buf
    return out


def u32_flat_to_leaf(buf: np.ndarray, dtype: str, shape: List[int]) -> np.ndarray:
    dt = str_dtype(dtype)
    n_bytes = int(np.prod(shape)) * dt.itemsize
    return np.frombuffer(buf.tobytes()[:n_bytes], dtype=dt).reshape(shape).copy()


def apply_delta(buf: np.ndarray, dirty_idx: np.ndarray, payload: np.ndarray,
                block_bytes: int) -> np.ndarray:
    be = block_bytes // 4
    blocks = buf.reshape(-1, be)
    if dirty_idx.shape[0]:
        blocks[dirty_idx] = payload
    return blocks.reshape(-1)
