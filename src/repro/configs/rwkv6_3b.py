"""rwkv6-3b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=2560 d_ff=8960 vocab=65536; head size 64 (40 wkv heads).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

ARCH = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # wkv heads = d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    attn_kind="none",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=64),
    source="arXiv:2404.05892; hf",
))
