"""Kill/restart supervision with a live MTBF feed.

Extracted from ``launch/train.py``'s inline ``supervise()`` loop so the
policy is unit-testable (injectable clock/wall/sleep/popen) and so the two
blind spots the inline loop shipped with are fixed here once:

* **startup grace** — the old loop only consulted the heartbeat monitor
  once ``hb.last()`` was non-None, so a worker that wedged *before its
  first beat* was never killed.  A beat-less worker now dies at
  ``startup_grace_s`` (default 2x the heartbeat timeout).
* **backoff reset** — the old loop called ``backoff.failed()`` on every
  death and never reset, so one early crash taxed every later restart.
  The backoff now forgets its failure count once the current worker has
  stayed healthy (fresh beats) past ``healthy_reset_s``.

Liveness is judged against *this attempt's* beats only: a heartbeat file
left behind by the previous (dead) worker carries a wall timestamp older
than the current spawn, so it can neither mask a wedged restart nor trip
the staleness kill early.

Every worker death and heartbeat-gap kill feeds a **real** failure
observation into an :class:`~repro.chaos.cadence.MTBFEstimator` (one
timebase — the supervisor's monotonic clock at observation time), and the
estimator plus the MTTR record persist to ``mtbf_feed_path``
(:class:`~repro.chaos.cadence.MTBFFeed`) so a restarted worker's cadence
controller starts from observed failure reality instead of its prior.

Chaos rearm: instead of the old blanket ``env.pop("OPENCHK_CHAOS")``, the
restart env is rewritten by :func:`repro.chaos.inject.restart_env` —
``rearm=True`` specs stay armed with their durable counters
(``OPENCHK_CHAOS_STATE``), so an exhausted kill spec does not re-kill the
restarted child at the same hit count, while ``rearm=False`` specs drop.
"""
from __future__ import annotations

import subprocess
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.chaos import inject
from repro.chaos.cadence import MTBFEstimator, MTBFFeed
from repro.ft.backoff import ExponentialBackoff
from repro.ft.detector import Heartbeat
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace
from repro.telemetry.health import HealthServer, HealthState


@dataclass
class SupervisorConfig:
    heartbeat_path: str
    heartbeat_timeout_s: float = 120.0
    startup_grace_s: Optional[float] = None  # None -> 2x heartbeat timeout
    healthy_reset_s: Optional[float] = None  # None -> heartbeat timeout
    max_restarts: int = 3
    backoff_base_s: float = 1.0
    backoff_max_s: float = 30.0
    poll_s: float = 1.0
    mtbf_feed_path: Optional[str] = None
    prior_mtbf_s: float = 3600.0
    #: serve /healthz, /readyz, /metrics for this supervisor (0 →
    #: ephemeral port, None → no endpoint).  Readiness = the *current*
    #: worker has beaten (flips False across a death/restart window).
    health_port: Optional[int] = None

    def startup_grace(self) -> float:
        return (self.startup_grace_s if self.startup_grace_s is not None
                else 2.0 * self.heartbeat_timeout_s)

    def healthy_reset(self) -> float:
        return (self.healthy_reset_s if self.healthy_reset_s is not None
                else self.heartbeat_timeout_s)


class Supervisor:
    """Run a worker command until success, restarting on death.

    ``clock`` (monotonic), ``wall`` (heartbeat timebase), ``sleep`` and
    ``popen`` are injectable so the whole kill/backoff/MTTR policy runs
    under a simulated clock in unit tests.
    """

    def __init__(
        self,
        cmd: Sequence[str],
        env: Dict[str, str],
        cfg: SupervisorConfig,
        *,
        clock: Callable[[], float] = time.monotonic,
        wall: Callable[[], float] = time.time,
        sleep: Callable[[float], None] = time.sleep,
        popen=subprocess.Popen,
        log: Callable[[str], None] = print,
    ) -> None:
        self.cmd = list(cmd)
        self.env = dict(env)
        self.cfg = cfg
        self.clock = clock
        self.wall = wall
        self.sleep = sleep
        self.popen = popen
        self.log = log
        self.hb = Heartbeat(cfg.heartbeat_path)
        self.backoff = ExponentialBackoff(base_s=cfg.backoff_base_s,
                                          max_s=cfg.backoff_max_s)
        # gap_failure_s stays None here: hangs are detected (and killed)
        # by the watch loop itself, which notes the failure exactly once —
        # a gap threshold on top would double-count every hang
        self.estimator = MTBFEstimator(prior_mtbf_s=cfg.prior_mtbf_s)
        self.feed = (MTBFFeed(cfg.mtbf_feed_path)
                     if cfg.mtbf_feed_path else None)
        self.attempts = 0
        self.deaths = 0
        self.gap_kills = 0
        self.mttr_s: List[float] = []
        self.health = HealthState(name="supervisor")
        self.health_server: Optional[HealthServer] = None
        if cfg.health_port is not None:
            self.health_server = HealthServer(
                self.health, port=cfg.health_port).start()
            self.log(f"[supervisor] health endpoint on "
                     f"{self.health_server.url}")

    # -- the restart loop --------------------------------------------------
    def run(self) -> int:
        try:
            return self._run()
        finally:
            if self.health_server is not None:
                self.health_server.stop()
                self.health_server = None
            self._merge_trace()

    def _run(self) -> int:
        death_t: Optional[float] = None
        while self.attempts < self.cfg.max_restarts + 1:
            self.attempts += 1
            self.log(f"[supervisor] attempt {self.attempts}")
            if self.attempts > 1:
                ttrace.instant("supervisor.restart", attempt=self.attempts)
                tmetrics.counter("openchk_worker_restarts_total").inc()
            spawn_wall = self.wall()
            spawn_t = self.clock()
            with ttrace.span("supervisor.attempt", attempt=self.attempts):
                p = self.popen(self.cmd, env=self.env)
                rc, why = self._watch(p, spawn_wall, spawn_t, death_t)
            if rc == 0:
                self.log(f"[supervisor] success after {self.attempts} "
                         f"attempt(s); deaths={self.deaths} "
                         f"mtbf_estimate={self.estimator.estimate():.1f}s")
                self._write_feed()
                return 0
            death_t = self.clock()
            self.deaths += 1
            self.estimator.note_failure(death_t)
            self.health.set_ready(False, reason=f"worker died ({why})",
                                  attempt=self.attempts)
            ttrace.instant("worker.death", rc=rc, why=why,
                           last_step=self.hb.last_step(),
                           attempt=self.attempts)
            tmetrics.counter("openchk_worker_deaths_total").inc()
            self.log(f"[supervisor] worker died rc={rc} via {why} "
                     f"(last step {self.hb.last_step()}); restarting "
                     f"from checkpoint")
            # spec-declared rearm semantics instead of the old blanket
            # env.pop: exhausted kill specs stay exhausted via the durable
            # state file; rearm=False specs drop for the next child
            self.env = inject.restart_env(self.env)
            self._write_feed()
            delay = self.backoff.failed()
            if delay > 0:
                self.log(f"[supervisor] backing off {delay:.1f}s "
                         f"before restart")
                self.sleep(delay)
        self.log("[supervisor] giving up")
        self._write_feed()
        return 1

    def _merge_trace(self) -> None:
        """Dir-mode runs end with one perfetto-loadable ``trace.json``:
        flush this process's events, then fold in every per-process file
        the (possibly killed and restarted) workers left behind."""
        d = ttrace.tracer().trace_dir()
        if d is not None:
            ttrace.flush()
            merged = ttrace.merge_dir(d)
            if merged:
                self.log(f"[supervisor] merged trace → {merged}")

    def _watch(self, p, spawn_wall: float, spawn_t: float,
               death_t: Optional[float]):
        """Poll one worker until it exits or is declared dead.

        Returns ``(rc, why)`` with ``why`` one of ``exit`` /
        ``startup-grace`` / ``heartbeat-gap``."""
        grace = self.cfg.startup_grace()
        reset_after = self.cfg.healthy_reset()
        recovered = death_t is None  # nothing to recover from on attempt 1
        last_beat_wall: Optional[float] = None
        while True:
            rc = p.poll()
            if rc is not None:
                return rc, "exit"
            self.sleep(self.cfg.poll_s)
            now = self.clock()
            bw = self.hb.last()
            fresh = bw is not None and bw >= spawn_wall
            if not fresh:
                # no beat from THIS worker yet (a leftover file from the
                # dead predecessor is not liveness): the pre-first-beat
                # wedge dies at the grace deadline
                if now - spawn_t >= grace:
                    self.gap_kills += 1
                    self.log(f"[supervisor] no heartbeat within startup "
                             f"grace ({grace:.1f}s) → killing worker")
                    return self._kill(p), "startup-grace"
                continue
            if last_beat_wall is None:
                # first beat from THIS worker: it is making progress
                self.health.set_ready(True, step=self.hb.last_step(),
                                      attempt=self.attempts)
            if not recovered:
                recovered = True
                mttr = now - death_t
                self.mttr_s.append(mttr)
                tmetrics.histogram("openchk_mttr_seconds").observe(mttr)
                ttrace.instant("supervisor.recovered", mttr_s=round(mttr, 3),
                               attempt=self.attempts)
                self.log(f"[supervisor] recovery complete: "
                         f"mttr {mttr:.2f}s")
            if bw != last_beat_wall:
                last_beat_wall = bw
                self.estimator.note_progress(now)
            if self.wall() - bw >= self.cfg.heartbeat_timeout_s:
                self.gap_kills += 1
                self.log("[supervisor] heartbeat timeout → killing worker")
                return self._kill(p), "heartbeat-gap"
            self.backoff.note_healthy_span(now - spawn_t, reset_after)

    @staticmethod
    def _kill(p) -> int:
        p.kill()
        return p.wait()

    def _write_feed(self) -> None:
        tmetrics.gauge("openchk_mtbf_estimate_seconds").set(
            self.estimator.estimate())
        if self.feed is not None:
            self.feed.write(self.estimator, deaths=self.deaths,
                            mttr_s=self.mttr_s)
