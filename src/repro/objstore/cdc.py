"""Content-defined chunking (CDC) — gear-style rolling-hash boundaries.

Fixed-size chunking keys every chunk to its absolute file offset: insert
one byte and every boundary after it shifts, so the whole container tail
re-uploads.  CDC cuts where the *content* says to cut — a short window
hash over the trailing bytes, boundary wherever its low bits are zero —
so boundaries re-synchronize within one chunk of an insertion and
unchanged data keeps producing the same chunks at new offsets.

The chunker is incremental (:class:`Chunker` — ``push`` bytes as a
writer produces them, completed chunks come back immediately) because
the store path fuses chunking into Pack: CHK5 writers tee every write
into a :class:`~repro.objstore.chunks.ChunkStream`, which uploads chunks
the moment a boundary lands.  Determinism contract: the cut sequence
depends only on the byte sequence (plus any explicit :meth:`flush`
positions), never on push granularity — tested against single-shot
splits in tests/test_cdc.py.

Boundary rule, for cut position ``c`` (1-based byte count):

    hash(bytes[c-4:c]) & mask == 0,   min_bytes <= c <= max_bytes

with ``mask`` carrying ``log2(avg_bytes)`` low bits, so boundaries land
every ``avg_bytes`` on average.  No candidate by ``max_bytes`` forces a
cut there; degenerate data (e.g. all zeros hashes to 0 everywhere) cuts
at ``min_bytes`` each time — uniform chunks that dedup to one stored
object.  The scan is vectorized numpy with an argmax-stepping search
(never materializing the full candidate index set — all-zero regions
have a candidate at every byte).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

#: defaults match the old fixed chunk size on average (1 MiB) while
#: bounding the variance a pathological byte stream could produce
DEFAULT_MIN_BYTES = 256 << 10
DEFAULT_AVG_BYTES = 1 << 20
DEFAULT_MAX_BYTES = 4 << 20

_WINDOW = 4                      # boundary hash window (bytes)


@dataclass(frozen=True)
class CDCParams:
    """min/avg/max chunk-size bounds; ``avg`` sets the boundary mask."""
    min_bytes: int = DEFAULT_MIN_BYTES
    avg_bytes: int = DEFAULT_AVG_BYTES
    max_bytes: int = DEFAULT_MAX_BYTES

    def __post_init__(self):
        if self.min_bytes < _WINDOW:
            raise ValueError(f"min_bytes {self.min_bytes} < window {_WINDOW}")
        if not (self.min_bytes <= self.avg_bytes <= self.max_bytes):
            raise ValueError(
                f"need min <= avg <= max, got {self.min_bytes}/"
                f"{self.avg_bytes}/{self.max_bytes}")

    @property
    def mask(self) -> int:
        """Low-bit mask sized so candidates land every ~avg_bytes."""
        bits = max(1, int(self.avg_bytes).bit_length() - 1)
        return (1 << bits) - 1


def _window_hashes(buf: np.ndarray) -> np.ndarray:
    """uint8 buffer → uint32 hash per 4-byte window (entry ``i`` hashes
    ``buf[i:i+4]``).  A little-endian word load plus an avalanche mix —
    position-independent, which is what makes boundaries re-synchronize
    after an insertion."""
    b = buf.astype(np.uint32)
    w = b[:-3] | (b[1:-2] << np.uint32(8)) | (b[2:-1] << np.uint32(16)) \
        | (b[3:] << np.uint32(24))
    with np.errstate(over="ignore"):
        h = w * np.uint32(0x9E3779B1)
        h ^= h >> np.uint32(15)
        h = h * np.uint32(0x85EBCA77)
        h ^= h >> np.uint32(13)
    return h


class Chunker:
    """Incremental CDC splitter: ``push`` returns completed chunks,
    ``flush`` force-cuts the pending bytes (region boundaries — dataset
    edges the caller wants layout-aligned), ``finish`` emits the final
    partial chunk.  ``_scanned`` tracks the no-boundary prefix of the
    pending buffer so repeated small pushes never re-hash bytes."""

    def __init__(self, params: CDCParams):
        self.params = params
        self._buf = bytearray()
        self._scanned = 0        # cut positions < this were checked: no hit

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def push(self, data) -> List[bytes]:
        if not len(data):
            return []
        self._buf += data
        return self._drain(final=False)

    def flush(self) -> List[bytes]:
        """Force a cut at the current position (CDC cuts still apply
        inside the flushed span).  The resulting layout for the span is
        self-contained — it depends only on the span's own bytes."""
        return self._drain(final=True)

    def finish(self) -> List[bytes]:
        return self._drain(final=True)

    # ------------------------------------------------------------------ #

    def _drain(self, final: bool) -> List[bytes]:
        out: List[bytes] = []
        while True:
            cut = self._find_cut(final)
            if cut is None:
                break
            out.append(bytes(self._buf[:cut]))
            del self._buf[:cut]
            self._scanned = 0
        return out

    def _find_cut(self, final: bool) -> Optional[int]:
        p = self.params
        n = len(self._buf)
        if n == 0 or (n < p.min_bytes and not final):
            return None
        hi = min(n, p.max_bytes)          # candidate cuts in [min, hi]
        lo = max(p.min_bytes, self._scanned, _WINDOW)
        if hi >= lo:
            view = np.frombuffer(self._buf, np.uint8,
                                 count=hi - (lo - _WINDOW),
                                 offset=lo - _WINDOW)
            h = _window_hashes(view)      # h[j] → cut at lo + j
            hit = (h & np.uint32(p.mask)) == 0
            j = int(np.argmax(hit))       # no index-set materialization
            if hit[j]:
                return lo + j
            self._scanned = hi + 1
        if n >= p.max_bytes:
            return p.max_bytes            # no boundary: force the max cut
        return n if final else None       # final partial chunk


def split(data, params: CDCParams) -> List[bytes]:
    """One-shot split (tests, file-based uploads): the same cuts an
    incremental :class:`Chunker` produces for the same bytes."""
    c = Chunker(params)
    out = c.push(data)
    out += c.finish()
    return out
