"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / peak_FLOP/s            (per-device program)
    memory     = HLO_bytes / HBM_bw
    collective = wire_bytes / link_bw

``cost_analysis()`` provides FLOPs/bytes of the per-device SPMD module.
Collective bytes are not in cost_analysis: we parse the compiled HLO text,
classify every collective op, and apply a ring-algorithm wire-cost model
per participating device:

    all-gather      out·(n−1)/n         reduce-scatter  in·(n−1)/n
    all-reduce      2·out·(n−1)/n       all-to-all      out·(n−1)/n
    collective-permute  out

where n = replica-group size parsed from the op. This is the bytes each
device puts on its ICI link(s); one active link direction is assumed
(conservative — a 2D torus overlaps axes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    out_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0
    details: List[Dict[str, Any]] = field(default_factory=list)

    def add(self, op: str, nbytes: int, group: int, wire: float, line_no: int):
        self.counts[op] = self.counts.get(op, 0) + 1
        self.out_bytes[op] = self.out_bytes.get(op, 0) + nbytes
        self.wire_bytes += wire
        if len(self.details) < 400:
            self.details.append({"op": op, "bytes": nbytes, "group": group,
                                 "wire": wire, "line": line_no})


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan compiled HLO for collective ops; sum modeled wire bytes."""
    stats = CollectiveStats()
    for ln, line in enumerate(hlo_text.splitlines()):
        s = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", s)
        if not m:
            continue
        type_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":        # counted at -start
            continue
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        g = _GROUPS_RE.search(s)
        if g:
            group = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(s)
            group = int(gi.group(2)) if gi else 2
        if group <= 1:
            continue
        frac = (group - 1) / group
        if op == "all-reduce":
            wire = 2.0 * nbytes * frac
        elif op == "collective-permute":
            wire = float(nbytes)
        elif op == "all-gather":
            wire = nbytes * frac           # nbytes is the gathered output
        elif op == "reduce-scatter":
            wire = nbytes * (group - 1)    # nbytes is the scattered output
        else:                              # all-to-all
            wire = nbytes * frac
        stats.add(op, nbytes, group, wire, ln)
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_total: float
    peak_memory_per_device: Optional[float] = None
    collectives: Optional[Dict[str, Any]] = None

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips) — remat/dispatch/padding waste."""
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / bound time — the score the perf loop drives up."""
        t_useful = self.model_flops_total / (self.chips * hw.PEAK_FLOPS_BF16)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in (
            "arch", "shape", "mesh", "chips", "flops_per_device",
            "bytes_per_device", "wire_bytes_per_device", "t_compute",
            "t_memory", "t_collective", "model_flops_total",
            "peak_memory_per_device")}
        d.update(bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction,
                 collectives=self.collectives)
        return d


def build_report(arch: str, shape: str, mesh_name: str, chips: int,
                 cost: Dict[str, float], hlo_text: str,
                 model_flops_total: float,
                 peak_memory: Optional[float] = None) -> RooflineReport:
    coll = parse_collectives(hlo_text)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        wire_bytes_per_device=coll.wire_bytes,
        t_compute=flops / hw.PEAK_FLOPS_BF16,
        t_memory=nbytes / hw.HBM_BW,
        t_collective=coll.wire_bytes / hw.ICI_LINK_BW,
        model_flops_total=model_flops_total,
        peak_memory_per_device=peak_memory,
        collectives={"counts": coll.counts, "out_bytes": coll.out_bytes},
    )
