"""Protection registry: pytree ⇄ named arrays + path selectors.

This is the layer that replaces the paper's compiler work (DESIGN.md §2):
Mercurium extracts base address / size / bounds from program symbols; here
pytree flattening extracts (path, dtype, shape, sharding) from the state the
user names. The user writes ``ctx.store(state, ...)`` — nothing is
hand-serialized.

Selectors are the analogue of *self-iterative data expressions* (§5.2):
``"params/groups/*/attn/**"`` expands over the tree exactly like
``{data[i], i=0;4}`` expands over an array.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.tree_util import (
    tree_flatten_with_path,
    tree_unflatten,
    keystr,
)


def _path_str(path) -> str:
    """KeyPath → canonical slash path: ('params','groups',0,'attn','wq') →
    "params/groups/0/attn/wq"."""
    parts = []
    for k in path:
        s = keystr((k,))
        s = s.strip("[]'\".")
        parts.append(s)
    return "/".join(parts)


def flatten_named(tree: Any) -> Tuple[Dict[str, Any], Any]:
    """→ ({path: leaf}, treedef). Paths are stable across runs (dict order
    canonicalized by jax pytree registry)."""
    leaves, treedef = tree_flatten_with_path(tree)
    named = {}
    for path, leaf in leaves:
        p = _path_str(path)
        if p in named:
            raise ValueError(f"duplicate pytree path {p!r}")
        named[p] = leaf
    return named, treedef


def unflatten_named(treedef, named: Dict[str, Any], template: Any) -> Any:
    """Rebuild a tree shaped like ``template`` from named leaves (match by
    path; order-free — unlike the paper's order-critical load/store lists)."""
    t_leaves, t_def = tree_flatten_with_path(template)
    out = []
    for path, leaf in t_leaves:
        p = _path_str(path)
        if p not in named:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        out.append(named[p])
    return tree_unflatten(t_def, out)


def select(named: Dict[str, Any], patterns: Optional[List[str]]) -> Dict[str, Any]:
    """Glob-select protected leaves. ``None`` → everything. ``**`` crosses
    slashes; ``*`` does not."""
    if not patterns:
        return dict(named)
    out: Dict[str, Any] = {}
    regexes = []
    for pat in patterns:
        esc = re.escape(pat)
        esc = esc.replace(r"\*\*", ".*").replace(r"\*", "[^/]*")
        regexes.append(re.compile("^" + esc + "$"))
    for path, leaf in named.items():
        if any(r.match(path) for r in regexes):
            out[path] = leaf
    if not out:
        raise ValueError(f"selectors {patterns} matched no leaves")
    return out


def to_host(named: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Device→host snapshot of every protected leaf (one fused transfer)."""
    arrs = jax.device_get(list(named.values()))
    return {k: np.asarray(v) for k, v in zip(named.keys(), arrs)}


def leaf_meta(named: Dict[str, Any]) -> Dict[str, Dict]:
    out = {}
    for k, v in named.items():
        out[k] = {"dtype": np.dtype(v.dtype).str, "shape": list(v.shape)}
    return out
