"""Erasure coding properties: XOR (1 loss) and Reed–Solomon (≤m losses)."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.redundancy import erasure
from repro.redundancy.groups import Topology


def _payloads(rng, k):
    return [rng.bytes(rng.randint(1, 200)) for _ in range(k)]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 6),
       lost=st.integers(0, 5))
def test_xor_single_loss(seed, k, lost):
    rng = np.random.RandomState(seed)
    payloads = _payloads(rng, k)
    lens = [len(p) for p in payloads]
    parity = erasure.encode_xor(payloads)
    lost = lost % k
    surv = {i: payloads[i] for i in range(k) if i != lost}
    rec = erasure.decode_xor(surv, parity, k, lens)
    assert all(rec[i] == payloads[i] for i in range(k))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 6), m=st.integers(1, 3))
def test_rs_all_loss_patterns(seed, k, m):
    rng = np.random.RandomState(seed)
    payloads = _payloads(rng, k)
    lens = [len(p) for p in payloads]
    pars = erasure.encode_rs(payloads, m)
    for lost in itertools.combinations(range(k), min(m, k)):
        surv = {i: payloads[i] for i in range(k) if i not in lost}
        rec = erasure.decode_rs(surv, dict(enumerate(pars)), k, lens)
        assert all(rec[i] == payloads[i] for i in range(k)), lost


def test_rs_insufficient_survivors():
    rng = np.random.RandomState(0)
    payloads = _payloads(rng, 4)
    pars = erasure.encode_rs(payloads, 1)
    with pytest.raises(ValueError):
        erasure.decode_rs({0: payloads[0]}, {0: pars[0]}, 4,
                          [len(p) for p in payloads])


def test_topology_partners_distinct_nodes():
    topo = Topology(world=8, ranks_per_node=2, group_size=4)
    for r in range(8):
        p = topo.partner_of(r)
        assert p != r
        assert topo.node_of(p) != topo.node_of(r)


def test_topology_groups():
    topo = Topology(world=10, group_size=4)
    assert topo.erasure_group(0) == [0, 1, 2, 3]
    assert topo.erasure_group(9) == [8, 9]
    custom = Topology(world=4, group_size=2,
                      custom_groups={"erasure": [[0, 3], [1, 2]]})
    assert custom.erasure_group(3) == [0, 3]
