"""The OpenCHK programming model — directives as a JAX API (paper §4).

The four directives and their clauses map 1:1::

    #pragma chk init comm(C)          ctx = CheckpointContext(comm=C, cfg=...)
                                      (or: with CheckpointContext(...) as ctx)
    #pragma chk load(data) if(c)      state = ctx.load(state, if_=c)
    #pragma chk store(data) id(i)     ctx.store(state, id=i, level=l,
            level(l) kind(k) if(c)              kind=k, if_=c)
    #pragma chk shutdown              ctx.shutdown()

Semantics preserved from the paper:
- **transparent restart**: ``load`` returns the restored state if any
  checkpoint is recoverable, else the input unchanged — the program flow is
  never modified to test for restarts;
- ``id`` is mandatory on store (progress identification; the training step
  number is the natural id), ``level`` is mandatory, ``kind`` defaults FULL;
- ``if_`` is the switch-off clause (checkpoint frequency lives here);
- serialization/deserialization is entirely the model's job (TCL + pytree
  flattening);
- the backend is selected by config/env — the same program runs on FTI,
  SCR, or VeloC (portability).

Self-iterative data expressions (§5.2) appear as ``protect`` specs — each
a selector **plus the paper's per-data clauses**::

    ctx.protect(Protect("params/**", kind=CHK_DIFF, compress="int8"),
                Protect("opt/**", format="chk5", precision="bf16"),
                Protect("step"))

``kind`` maps the paper's ``kind(DIFF)`` clause per subtree (mixed-kind
stores fall out: DIFF params + FULL optimizer in one checkpoint),
``compress``/``format``/``precision`` drive the Pack-side tiers
(core/tiers.py), ``axis`` carries explicit sharding-axis metadata
(dist/sharding.py).  Plain selector strings remain accepted (deprecated)
and convert to clause-less specs.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, List, Optional, Union

from repro.core.comm import Communicator, LocalComm
from repro.core.pipeline import LoadRequest, StoreRequest
from repro.core.protect import Protect, normalize_protects
from repro.core.storage import CHK_DIFF, CHK_FULL, StorageConfig, StoreReport
from repro.core.tcl import TCL

__all__ = ["CheckpointContext", "CheckpointConfig", "CHK_FULL", "CHK_DIFF",
           "Protect"]


@dataclass
class CheckpointConfig:
    """User-facing config (the paper's per-system configuration file)."""

    dir: str                                   # checkpoint root
    backend: Optional[str] = None              # None → $OPENCHK_BACKEND → fti
    block_bytes: int = 65_536                  # dCP block granularity
    keep_last_full: int = 2
    group_size: int = 4
    erasure_scheme: str = "rs"
    rs_parity: int = 2
    promote_threshold: float = 0.95
    dedicated_thread: bool = True              # CP-dedicated threads (§4.2.2)
    sharded_snapshot: bool = True              # shard-local Plan snapshots
    shard_writers: int = 4                     # parallel shard-file writers
    # object-store L4 (repro.objstore): content-addressed uploads + catalog
    objstore: bool = True
    objstore_url: Optional[str] = None         # None → file:<dir>/objstore
    objstore_chunk_bytes: int = 1 << 20        # fixed-mode chunk size
    objstore_chunking: str = "cdc"             # "cdc" | "fixed"
    objstore_cdc_min_bytes: int = 256 << 10    # CDC lower cut bound
    objstore_cdc_avg_bytes: int = 1 << 20      # CDC target average
    objstore_cdc_max_bytes: int = 4 << 20      # CDC forced-cut bound
    objstore_transfers: int = 4                # parallel transfer threads
    # retention clauses over the objstore catalog: keep the newest
    # ``keep_last`` checkpoints plus every ``keep_every``-th id; GC sweeps
    # the chunks nothing references (both None → keep everything)
    keep_last: Optional[int] = None
    keep_every: Optional[int] = None

    def storage(self) -> StorageConfig:
        return StorageConfig(
            root=self.dir,
            block_bytes=self.block_bytes,
            keep_last_full=self.keep_last_full,
            group_size=self.group_size,
            erasure_scheme=self.erasure_scheme,
            rs_parity=self.rs_parity,
            promote_threshold=self.promote_threshold,
            sharded_store=self.sharded_snapshot,
            shard_writers=self.shard_writers,
            objstore=self.objstore,
            objstore_url=self.objstore_url,
            objstore_chunk_bytes=self.objstore_chunk_bytes,
            objstore_chunking=self.objstore_chunking,
            objstore_cdc_min_bytes=self.objstore_cdc_min_bytes,
            objstore_cdc_avg_bytes=self.objstore_cdc_avg_bytes,
            objstore_cdc_max_bytes=self.objstore_cdc_max_bytes,
            objstore_transfers=self.objstore_transfers,
            objstore_keep_last=self.keep_last,
            objstore_keep_every=self.keep_every,
        )


class CheckpointContext:
    """``chk init`` … ``chk shutdown`` — a checkpoint context."""

    def __init__(self, cfg: CheckpointConfig,
                 comm: Optional[Communicator] = None):
        # the comm clause is mandatory in the paper; default to the
        # single-process communicator with node-local storage under cfg.dir
        self.comm = comm if comm is not None else LocalComm(
            os.path.join(cfg.dir, "node-local"))
        backend_kw = {}
        # every backend accepts the CP-thread switch (base Backend ANDs it
        # with the declared capability, so it is a no-op where unsupported)
        if not cfg.dedicated_thread:
            backend_kw["dedicated_thread"] = False
        self.tcl = TCL(cfg.storage(), self.comm, cfg.backend, **backend_kw)
        self.cfg = cfg
        self._protects: Optional[List[Protect]] = None
        self._open = True
        self.last_report: Optional[StoreReport] = None
        self.restarted: bool = False

    # ------------------------------------------------------------------ #
    # directives
    # ------------------------------------------------------------------ #

    def observe_store_reports(self, cb) -> "CheckpointContext":
        """Register *cb* to receive every committed
        :class:`~repro.core.pipeline.StoreReport` (async tails included) —
        the cadence controller's store-cost feed
        (``repro.chaos.cadence.CadenceController.note_report``)."""
        self.tcl.backend.pipeline.on_report = cb
        return self

    def protect(self, *specs: Union[str, Protect]) -> "CheckpointContext":
        """Declare the protected subtrees with their per-subtree clauses
        (self-iterative data expressions + the paper's data clauses):
        ``Protect(selector, kind=..., compress=..., format=...,
        precision=..., axis=...)``.  Plain selector strings are the
        deprecated clause-less form.  No arguments → protect everything."""
        self._protects = normalize_protects(specs)
        return self

    def load(self, state: Any, if_: bool = True) -> Any:
        """``chk load`` — transparent restart. Never changes program flow:
        returns the restored state, or ``state`` unchanged."""
        self._check_open()
        if not if_:
            return state
        restored = self.tcl.load(LoadRequest(
            template=state, protects=self._protects))
        if restored is None:
            return state
        self.restarted = True
        return restored

    def store(self, state: Any, *, id: int, level: int,
              kind: str = CHK_FULL, if_: bool = True) -> Optional[StoreReport]:
        """``chk store`` — id and level are mandatory clauses (paper §4.1).
        ``kind`` is the store-level default; a ``Protect(kind=...)`` clause
        overrides it per subtree (mixed-kind stores)."""
        self._check_open()
        if not if_:
            return None
        self.last_report = self.tcl.store(StoreRequest(
            tree=state, ckpt_id=int(id), level=int(level), kind=kind,
            protects=self._protects))
        return self.last_report

    def store_begin(self, *, id: int, level: int,
                    if_: bool = True):
        """Incremental checkpointing (paper §8 Future Work): open a
        checkpoint and ``add`` parts as they become ready; ``commit``
        finalizes (manifest + redundancy) through the pipeline's
        Place → Commit stages — asynchronously when the backend has a
        CP-dedicated thread (no fence against in-flight stores: the CP
        queue serializes commits, and parts stage into a private ``.tmp``
        dir). Returns None when ``if_`` is false (switch-off clause)."""
        self._check_open()
        if not if_:
            return None
        return self.tcl.store_begin(int(id), int(level))

    def wait(self) -> None:
        """Fence any CP-dedicated-thread work (surfaces deferred errors)."""
        self.tcl.wait()

    def shutdown(self) -> None:
        """``chk shutdown``."""
        if self._open:
            self.tcl.finalize()
            self._open = False

    # ------------------------------------------------------------------ #

    @property
    def stats(self):
        return self.tcl.backend.stats

    def _check_open(self) -> None:
        if not self._open:
            raise RuntimeError("checkpoint context is shut down")

    def __enter__(self) -> "CheckpointContext":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
