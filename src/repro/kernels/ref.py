"""Pure-jnp oracles for the checkpoint kernels (and the CPU execution path).

The hash is a position-salted multiply–xorshift mix (murmur3-finalizer
family) folded with wrapping uint32 addition — commutative, so the Pallas
kernel can tree-reduce/tile-accumulate in any order and still match this
oracle bit-exactly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

HASH_SALT_A = np.uint32(0x9E3779B9)   # golden-ratio odd constants
HASH_SALT_B = np.uint32(0x85EBCA6B)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 — bijective avalanche over uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * np.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * np.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def blockhash_ref(blocks_u32: jnp.ndarray, salt: np.uint32 = HASH_SALT_A
                  ) -> jnp.ndarray:
    """(n_blocks, elems) uint32 → (n_blocks,) uint32 per-block hash.

    hash(b) = Σ_i mix32(x[b,i] ⊕ (i·salt))  (wrapping add — commutative).
    """
    n, e = blocks_u32.shape
    idx = (jnp.arange(e, dtype=jnp.uint32) * salt)[None, :]
    return jnp.sum(mix32(blocks_u32.astype(jnp.uint32) ^ idx),
                   axis=1, dtype=jnp.uint32)


def blockhash2_ref(blocks_u32: jnp.ndarray) -> jnp.ndarray:
    """Two independent 32-bit lanes → (n_blocks, 2) uint32 (64-bit digest)."""
    return jnp.stack(
        [blockhash_ref(blocks_u32, HASH_SALT_A),
         blockhash_ref(blocks_u32, HASH_SALT_B)], axis=1)


def diffpack_ref(blocks: jnp.ndarray, dirty_idx: jnp.ndarray) -> jnp.ndarray:
    """Gather dirty blocks: (n_blocks, e), (n_dirty,) → (n_dirty, e)."""
    return jnp.take(blocks, dirty_idx, axis=0)


def diffunpack_ref(base: jnp.ndarray, packed: jnp.ndarray,
                   dirty_idx: jnp.ndarray) -> jnp.ndarray:
    """Scatter packed blocks into base: inverse of diffpack."""
    return base.at[dirty_idx].set(packed)
