"""Node groups for redundancy schemes.

FTI/VeloC detect topology automatically and pick partners; SCR additionally
lets users define custom groups (e.g. all nodes on one power supply). Both
models are supported: ``auto_groups`` (ring partners + contiguous erasure
groups) and explicit group maps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


@dataclass(frozen=True)
class Topology:
    world: int
    ranks_per_node: int = 1
    group_size: int = 4            # erasure-group width (FTI default 4)
    custom_groups: Optional[Dict[str, List[List[int]]]] = None  # SCR-style

    @property
    def n_nodes(self) -> int:
        return self.world // self.ranks_per_node

    def node_of(self, rank: int) -> int:
        return rank // self.ranks_per_node

    def partner_of(self, rank: int) -> int:
        """Ring partner on a *different node* where possible (FTI L2)."""
        step = self.ranks_per_node
        if self.world <= step:      # single node: fall back to ring
            step = 1
        return (rank + step) % self.world

    def erasure_group(self, rank: int) -> List[int]:
        """Contiguous group of ``group_size`` ranks containing ``rank``."""
        g = self.group_size
        if self.custom_groups and "erasure" in self.custom_groups:
            for grp in self.custom_groups["erasure"]:
                if rank in grp:
                    return list(grp)
        start = (rank // g) * g
        return [r for r in range(start, min(start + g, self.world))]

    def group_index(self, rank: int) -> int:
        return rank // self.group_size
