"""Clause-carrying protection: per-subtree checkpoint behavior.

The paper's clause system (``store(data) kind(DIFF)``, HDF5 format, dCP
granularity) as per-subtree ``Protect`` specs: params go differential and
int8-compressed, optimizer moments store FULL at bf16, the step scalar
rides along clause-less — all in ONE store call, one container.

Run:  PYTHONPATH=src python examples/clause_protection.py
      (run it twice — the second run restarts from the checkpoint;
       inspect with: python -m repro.tools.chkls --json \
           /tmp/openchk-clauses/node-local/ckpts/ckpt-*/rank0.chk5)
"""
import jax.numpy as jnp

from repro.core.context import (
    CHK_DIFF,
    CheckpointConfig,
    CheckpointContext,
    Protect,
)

state = {
    "params": {"w": jnp.zeros(4096)},
    "opt": {"m": jnp.zeros(4096), "v": jnp.zeros(4096)},
    "step": jnp.int32(0),
}


def update(s):
    # touch only a slice of the params so the dCP dirty ratio stays low
    # (a fully-dirty tree promotes the delta back to FULL — Fig. 7)
    return {
        "params": {"w": s["params"]["w"].at[:256].add(0.1)},
        "opt": {"m": s["opt"]["m"] * 0.9, "v": s["opt"]["v"] * 0.99},
        "step": s["step"] + 1,
    }


# synchronous stores so each StoreReport is returned inline (with a
# CP-dedicated thread the report is deferred and store() returns None);
# 1 KiB dCP blocks so the sliced update above is a genuinely sparse delta
ctx = CheckpointContext(CheckpointConfig(dir="/tmp/openchk-clauses",
                                         dedicated_thread=False,
                                         block_bytes=1024))
ctx.protect(
    Protect("params/**", kind=CHK_DIFF, compress="int8", max_error=0.05),
    Protect("opt/**", format="chk5", precision="bf16"),
    Protect("step"),
)
state = ctx.load(state)

start = int(state["step"])
if ctx.restarted:
    print(f"transparent restart: resuming from step {start}")

for t in range(start, 30):
    state = update(state)
    # params delta-encode against the previous store; opt is FULL bf16;
    # a mixed-kind container is written when the params delta is small
    ctx.store(state, id=t + 1, level=1, if_=(t + 1) % 10 == 0)

rep = ctx.last_report
if rep is not None:
    print(f"last store: kind={rep.kind} bytes={rep.bytes_payload:,} "
          f"dirty_ratio={rep.dirty_ratio}")
ctx.shutdown()
print(f"done at step {int(state['step'])}")
print("inspect the container: python -m repro.tools.chkls --json "
      "/tmp/openchk-clauses/node-local/ckpts/ckpt-*/rank0.chk5")
