"""CLI tools and end-to-end drivers (chkls, launch.train, heat2d parity)."""
import os
import subprocess
import sys

import numpy as np
import pytest


def test_chkls_cli(tmp_path, capsys):
    from repro.core.formats import CHK5Writer
    from repro.tools.chkls import main as chkls_main
    p = str(tmp_path / "x.chk5")
    with CHK5Writer(p) as w:
        w.write_dataset("data/a", np.arange(6.0).reshape(2, 3))
        w.set_attrs("", {"id": 1})
    assert chkls_main([p, "--verify", "--stats"]) == 0
    out = capsys.readouterr().out
    assert "data/a" in out and "crc OK" in out and "μ=" in out


def test_chkls_json_and_clause_attrs(tmp_path, capsys):
    """--json emits a machine-readable inventory (attrs included) and the
    human listing shows clause attrs — what CI asserts container contents
    with."""
    import json
    from repro.core.formats import CHK5Writer
    from repro.core.protect import Protect
    from repro.core.tiers import pack_named
    from repro.tools.chkls import main as chkls_main
    p = str(tmp_path / "c.chk5")
    with CHK5Writer(p) as w:
        w.set_attrs("", {"kind": "FULL", "id": 4})
        pack_named(w, {"params/w": np.linspace(-1, 1, 2048, dtype=np.float32),
                       "step": np.int32(7)},
                   {"params/w": Protect("params/**", compress="int8"),
                    "step": None})
    assert chkls_main([p, "--json", "--verify"]) == 0
    inv = json.loads(capsys.readouterr().out)
    assert inv["attrs"] == {"kind": "FULL", "id": 4}
    by = {d["name"]: d for d in inv["datasets"]}
    assert by["data/params/w"]["attrs"]["codec"] == "int8"
    assert by["data/params/w"]["dtype"] == "|i1"
    assert "codec" not in by["data/step"]["attrs"]
    assert inv["verified"] is True
    assert inv["total_bytes"] == sum(d["nbytes"] for d in inv["datasets"])
    # human listing shows the clause column
    assert chkls_main([p]) == 0
    out = capsys.readouterr().out
    assert "codec=int8" in out and "kind=FULL" in out


def test_launch_train_worker_restart(tmp_path):
    """launch.train direct mode: fault → rerun → resume (subprocess)."""
    env = dict(os.environ, PYTHONPATH="src")
    d = str(tmp_path / "t")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "tinyllama-1.1b", "--steps", "20", "--batch", "2", "--seq", "32",
            "--ckpt-every", "5", "--ckpt-dir", d, "--no-dedicated-thread"]
    r1 = subprocess.run(base + ["--inject-at", "0.8"], env=env,
                        capture_output=True, text=True, timeout=420)
    assert r1.returncode != 0
    assert "injected fault" in (r1.stderr + r1.stdout)
    r2 = subprocess.run(base, env=env, capture_output=True, text=True,
                        timeout=420)
    assert r2.returncode == 0, r2.stderr[-1000:]
    assert "restart detected" in r2.stdout
    assert "'final_step': 20" in r2.stdout


@pytest.mark.parametrize("variant", ["openchk", "fti", "scr", "veloc"])
def test_heat2d_variants_restart_parity(tmp_path, variant):
    """All four CR variants converge to the same physics after a fault."""
    sys.path.insert(0, ".")
    from benchmarks.apps import (
        heat2d_fti, heat2d_openchk, heat2d_scr, heat2d_veloc)
    from repro.ft.failures import FaultInjector, SimulatedFault
    mod = {"openchk": heat2d_openchk, "fti": heat2d_fti,
           "scr": heat2d_scr, "veloc": heat2d_veloc}[variant]
    from benchmarks.apps.heat2d_common import heat_step, init_grid, checksum
    g = init_grid(32)
    for _ in range(40):
        g = heat_step(g)
    want = checksum(g)
    d = str(tmp_path / variant)
    inj = FaultInjector(total_steps=40, at_progress=0.9)
    with pytest.raises(SimulatedFault):
        mod.run(n=32, steps=40, ckpt_every=10, ckpt_dir=d, injector=inj)
    # a real abort kills the CP thread with the process; the in-process
    # simulation must drain it so the restart doesn't race an orphan
    # (same pattern as benchmarks/bench_overhead.py)
    from repro.core.async_engine import drain_all
    drain_all()
    out = mod.run(n=32, steps=40, ckpt_every=10, ckpt_dir=d)
    assert out["restarted"]
    assert abs(out["checksum"] - want) < 1e-3
