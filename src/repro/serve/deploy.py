"""Checkpoint-as-deployment: rolling chunk-delta hot-swap for a serving
fleet.

The training run publishes checkpoints into the content-addressed object
store; a serving fleet *follows the catalog* instead of receiving pushed
weight files.  :class:`FleetDeployer` composes three pieces:

- the **subscriber** (``repro.objstore.subscriber``): one epoch-integer
  poll decides "anything new?", the typed
  :class:`~repro.objstore.inspect.CatalogView` decides "which entry";
- the **puller** (:class:`EntryPuller`): materializes the entry's rank
  file set into the replica's node-local ``objstore-cache`` with
  chunk-level delta fetches — only digests absent from the replica's
  :class:`~repro.objstore.chunks.ChunkCache` hit the store, every chunk
  digest-verified (a fine-tune publish ships ~3% of the weight bytes,
  the CI-gated ``serve_swap_delta_ratio``);
- the **loader** (:func:`repro.core.resharding.load_named_onto`): the
  param tree is assembled *directly onto each replica's serving mesh*
  via shard region reads — a checkpoint stored from a 4×4 training mesh
  lands on a 1×8 serving mesh with no global host array.

Rolling-swap invariants (the "libraries must become more fault
tolerant" discipline applied to deployment):

1. **One replica at a time.**  A replica must pull, assemble, flip and
   report readiness before the next replica starts — a bad publish
   stops at the canary with the rest of the fleet untouched.
2. **The flip is atomic and late.**  The new tree is fully assembled
   and validated *before* ``set_weights`` — a replica never serves a
   torn tree; in-flight ``generate()`` batches finish on the handle
   they captured.
3. **Failure pins, never tears.**  A failed pull (missing chunk, digest
   mismatch, objstore outage, killed replica) leaves that replica
   serving its current epoch; the deployer backs off and retries, and
   the rollout does not advance past the failure.

Failure matrix (exercised in tests/test_serve_deploy.py):

====================  =============================================
fault                 observable behaviour
====================  =============================================
replica dies mid-pull fleet keeps serving the old epoch; the revived
                      replica re-pulls (cache survives) and converges
corrupt cached chunk  ChunkCache digest-verify evicts + refetches;
                      the swap completes with one extra chunk pulled
objstore outage       subscriber/puller raise ObjectStoreError; the
                      replica pins its epoch and retries with backoff
partial shard set     load_named_onto raises — no flip happens
====================  =============================================
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.chaos import inject as chaos
from repro.core import manifest as mf
from repro.ft.backoff import backoff_delay
from repro.core.formats import CHK5CorruptionError, CHK5Reader
from repro.core.protect import flatten_named, unflatten_named
from repro.core.resharding import load_named_onto
from repro.objstore.chunks import ChunkCache, fetch_file_delta
from repro.objstore.client import ObjectStore, ObjectStoreError
from repro.objstore.inspect import EntryInfo
from repro.objstore.subscriber import CatalogSubscriber, DeploySelector
from repro.serve.engine import ServingEngine, WeightsHandle
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace
from repro.telemetry.health import HealthState


class EntryPuller:
    """Materializes one catalog entry's rank file set into a node-local
    cache directory with chunk-delta fetches.

    The chunk cache persists across entries — pulling entry N+1 after N
    only fetches the digests the two do not share.  Every file lands via
    staged ``.part`` + rename and every container is CHK5-validated, so
    a crash mid-pull leaves no half-written file a later pull would
    trust."""

    def __init__(self, store: ObjectStore, cache_root: str, rank: int = 0):
        self.store = store
        self.cache_root = cache_root
        self.rank = rank
        self.cache = ChunkCache(os.path.join(cache_root, "chunks"))

    def pull(self, entry: EntryInfo) -> Dict[str, Any]:
        """Fetch ``entry``'s files for this rank → ``{"dir", "container",
        "bytes_fetched", "bytes_cached", "chunks_corrupt"}``.  Raises
        ``ObjectStoreError`` on any missing/corrupt chunk — the caller
        treats the pull as failed, nothing was installed."""
        files = entry.rank_files(self.rank)
        container = f"rank{self.rank}.chk5"
        if not any(f.name == container for f in files):
            raise ObjectStoreError(
                f"entry {entry.id} has no {container} — not deployable "
                f"for rank {self.rank}")
        d = mf.ckpt_dir(self.cache_root, entry.id)
        os.makedirs(d, exist_ok=True)
        stats = {"dir": d, "container": os.path.join(d, container),
                 "bytes_fetched": 0, "bytes_cached": 0, "chunks_corrupt": 0}
        for f in files:
            got = fetch_file_delta(self.store, f.file_entry(),
                                   os.path.join(d, f.name), self.cache)
            for k in ("bytes_fetched", "bytes_cached", "chunks_corrupt"):
                stats[k] += got[k]
        # the manifest rides the catalog entry; materializing it makes
        # the cache dir a normal committed checkpoint dir
        man_path = os.path.join(d, mf.MANIFEST)
        tmp = man_path + ".part"
        with open(tmp, "w") as fh:
            json.dump(dict(entry.manifest), fh, indent=1, sort_keys=True)
        os.replace(tmp, man_path)
        try:
            CHK5Reader(stats["container"]).close()
        except (OSError, CHK5CorruptionError) as e:
            raise ObjectStoreError(
                f"entry {entry.id}: pulled container failed CHK5 "
                f"validation: {e}") from e
        return stats


@dataclass
class Replica:
    """One serving engine the deployer manages, plus its pull-side state.
    ``cache_root`` is the replica's node-local objstore-cache (each
    replica pulls independently — a dead replica never blocks a peer's
    chunks)."""
    name: str
    engine: ServingEngine
    cache_root: str
    rank: int = 0
    prefix: Optional[str] = None      # checkpoint namespace of the params
    failures: int = 0
    next_retry_t: float = 0.0
    last_error: Optional[str] = None
    #: optional live readiness for this replica (telemetry/health.py):
    #: the deployer drops it for the pull window and re-asserts it after
    #: the flip (or after a failed pull — the old epoch is still serving),
    #: so a rolling swap is observable from /readyz outside the process
    health: Optional[HealthState] = None
    _puller: Optional[EntryPuller] = field(default=None, repr=False)

    def puller(self, store: ObjectStore) -> EntryPuller:
        if self._puller is None:
            self._puller = EntryPuller(store, self.cache_root, self.rank)
        return self._puller


class FleetDeployer:
    """Drives the rolling hot-swap of a replica fleet off the catalog.

    ``poll()`` is the whole control loop, designed to be called from a
    timer/serve loop: it advances the rollout by **at most one replica
    swap** per call (invariant 1), so readiness between swaps is exactly
    "the previous poll returned with the replica converged".  Failures
    never raise out of ``poll()`` — they pin the failing replica
    (invariant 3), stamp a backoff deadline, and the rollout resumes
    from that replica on a later poll.  ``time_fn`` is injectable so
    tests drive backoff deterministically."""

    def __init__(self, store: ObjectStore, replicas: List[Replica],
                 selector: DeploySelector = DeploySelector(),
                 backoff_s: float = 1.0, max_backoff_s: float = 30.0,
                 time_fn=time.monotonic):
        self.store = store
        self.replicas = list(replicas)
        self.subscriber = CatalogSubscriber(store, selector)
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.time_fn = time_fn
        self.target: Optional[EntryInfo] = None
        self._next = 0                 # rollout cursor into self.replicas
        self._watch_retry_t = 0.0      # backoff for catalog-poll outages
        self.stats = {"swaps": 0, "rollouts": 0, "pulls_failed": 0,
                      "bytes_fetched": 0, "bytes_cached": 0}
        # the fleet's epoch/entry view lives on the telemetry gauges from
        # here on (fleet_epochs() is a shim over them): stamp each
        # engine's telemetry label with its fleet name and seed the
        # gauges from the weights it currently serves
        for r in self.replicas:
            r.engine.name = r.name
            h = r.engine.weights
            tmetrics.gauge("openchk_serve_epoch",
                           replica=r.name).set(h.epoch)
            tmetrics.gauge("openchk_fleet_entry_id", replica=r.name).set(
                -1 if h.entry_id is None else h.entry_id)

    # -- one control-loop step ------------------------------------------ #

    def poll(self) -> Dict[str, Any]:
        """One deploy step → a status dict: ``action`` is one of
        ``idle`` / ``watching`` (outage backoff) / ``started`` /
        ``swapped`` / ``pinned`` (replica failed, epoch kept) /
        ``waiting`` (backoff not yet elapsed) / ``converged``."""
        now = self.time_fn()
        if self.target is None:
            if now < self._watch_retry_t:
                return {"action": "watching", "retry_at": self._watch_retry_t}
            try:
                target = self.subscriber.poll()
            except ObjectStoreError as e:
                # catalog unreachable: the fleet keeps serving what it
                # serves; watch again after backoff
                self._watch_retry_t = now + self.backoff_s
                return {"action": "watching", "error": str(e),
                        "retry_at": self._watch_retry_t}
            if target is None:
                return {"action": "idle", "epoch": self.subscriber.last_epoch}
            self.target = target
            self._next = 0
            self.stats["rollouts"] += 1
            for r in self.replicas:
                r.failures = 0
                r.next_retry_t = 0.0
            return {"action": "started", "entry": target.id,
                    "delta": self.subscriber.delta(target)}
        if self._next >= len(self.replicas):
            done = self.target
            self.subscriber.mark_deployed(done)
            self.target = None
            return {"action": "converged", "entry": done.id}
        r = self.replicas[self._next]
        if now < r.next_retry_t:
            return {"action": "waiting", "replica": r.name,
                    "retry_at": r.next_retry_t}
        try:
            swap = self._swap_one(r, self.target)
        except (ObjectStoreError, CHK5CorruptionError, OSError,
                KeyError) as e:
            # invariant 3: the replica keeps its current epoch — nothing
            # was installed — and the rollout holds at this replica
            r.failures += 1
            r.last_error = f"{type(e).__name__}: {e}"
            r.next_retry_t = now + backoff_delay(
                r.failures, self.backoff_s, self.max_backoff_s)
            self.stats["pulls_failed"] += 1
            tmetrics.counter("openchk_deploy_pulls_failed_total",
                             replica=r.name).inc()
            ttrace.instant("deploy.pinned", replica=r.name,
                           entry=self.target.id, error=r.last_error)
            return {"action": "pinned", "replica": r.name,
                    "epoch": r.engine.weights.epoch,
                    "error": r.last_error, "retry_at": r.next_retry_t}
        self._next += 1
        self.stats["swaps"] += 1
        tmetrics.counter("openchk_deploy_swaps_total", replica=r.name).inc()
        r.failures = 0
        r.last_error = None
        return dict(swap, action="swapped", replica=r.name,
                    remaining=len(self.replicas) - self._next)

    def run_until_converged(self, max_polls: int = 10_000,
                            sleep_fn=None) -> Dict[str, Any]:
        """Poll until the fleet converges on the current target (tests /
        one-shot deploys).  Honors backoff via ``sleep_fn`` (defaults to
        busy-advancing an injectable clock is the caller's job)."""
        last: Dict[str, Any] = {"action": "idle"}
        for _ in range(max_polls):
            last = self.poll()
            if last["action"] in ("converged", "idle"):
                return last
            if last["action"] in ("waiting", "watching", "pinned") \
                    and sleep_fn is not None:
                sleep_fn(self.backoff_s)
        return last

    # -- the swap ------------------------------------------------------- #

    def _swap_one(self, r: Replica, entry: EntryInfo) -> Dict[str, Any]:
        """Pull + assemble + atomic flip for one replica.  Everything up
        to ``set_weights`` is side-effect-free for the serving path —
        any exception leaves the old handle serving.

        Readiness (when the replica carries a HealthState) drops for the
        pull/assemble window and is re-asserted on both exits: after the
        flip via the engine's swap hook, and after a failure because the
        old epoch never stopped serving."""
        # chaos site: an error-mode spec here exercises invariant 3 end to
        # end — poll() must pin the replica, never tear the fleet
        chaos.fire(chaos.SITES.DEPLOY_POLL, exc=ObjectStoreError,
                   replica=r.name, entry=entry.id)
        if r.health is not None:
            r.health.set_ready(False, reason="pulling",
                               target_entry=entry.id)
        try:
            with ttrace.span("deploy.swap", replica=r.name, entry=entry.id):
                return self._pull_and_flip(r, entry)
        except BaseException:
            if r.health is not None:
                r.health.set_ready(True, reason="pull failed; "
                                   "serving previous epoch")
            raise

    def _pull_and_flip(self, r: Replica, entry: EntryInfo) -> Dict[str, Any]:
        with ttrace.span("deploy.pull", replica=r.name, entry=entry.id):
            pulled = r.puller(self.store).pull(entry)
        self.stats["bytes_fetched"] += pulled["bytes_fetched"]
        self.stats["bytes_cached"] += pulled["bytes_cached"]
        tmetrics.counter("openchk_deploy_bytes_fetched_total").inc(
            pulled["bytes_fetched"])

        cur_named, treedef = flatten_named(r.engine.params)
        prefix = (r.prefix + "/") if r.prefix else ""
        shardings = {prefix + name: getattr(leaf, "sharding", None)
                     for name, leaf in cur_named.items()}
        named = load_named_onto(pulled["container"], [pulled["dir"]],
                                rank=r.rank, shardings=shardings)
        # select this engine's namespace; a missing leaf fails the swap
        # (KeyError → pinned) before any mutation
        new_named = {}
        for name in cur_named:
            key = prefix + name
            if key not in named:
                raise KeyError(
                    f"entry {entry.id} is missing leaf {key!r} — not a "
                    f"deployable params tree for replica {r.name}")
            new_named[name] = named[key]
        new_params = unflatten_named(treedef, new_named, r.engine.params)
        handle = r.engine.set_weights(WeightsHandle(
            params=new_params, entry_id=entry.id))
        if r.health is not None:
            # idempotent with attach_engine's swap hook — covers health
            # states not chained onto the engine
            r.health.set_ready(True, epoch=int(handle.epoch),
                               entry_id=handle.entry_id, reason="swapped")
        return {"entry": entry.id, "epoch": handle.epoch,
                "bytes_fetched": pulled["bytes_fetched"],
                "bytes_cached": pulled["bytes_cached"],
                "chunks_corrupt": pulled["chunks_corrupt"]}

    # -- observability --------------------------------------------------- #

    def fleet_epochs(self) -> Dict[str, Optional[int]]:
        """replica name → catalog entry id currently served (the torn-
        fleet check: mid-rollout at most two distinct values, old and
        new).  Thin shim over the ``openchk_fleet_entry_id`` telemetry
        gauges every swap maintains (-1 encodes "local params, no
        catalog entry")."""
        out: Dict[str, Optional[int]] = {}
        for r in self.replicas:
            v = tmetrics.gauge("openchk_fleet_entry_id",
                               replica=r.name).value
            out[r.name] = None if v < 0 else int(v)
        return out
