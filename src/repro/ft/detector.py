"""Heartbeat failure detector for the restart launcher.

The training process touches a heartbeat file every step; the launcher
watches mtime and declares the worker dead after ``timeout`` seconds —
covering hangs, not just aborts (aborts are caught by exit status).
At 1000+ nodes the same protocol runs per-host with the launcher feeding a
cluster-level scheduler; the file-based local form keeps the logic testable.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Optional

from repro.chaos import inject as chaos


@dataclass
class Heartbeat:
    path: str

    def beat(self, step: Optional[int] = None) -> None:
        # chaos site: a "skip"-mode spec models a worker whose heartbeat
        # writes stop landing (hung I/O) while the process is still alive
        if chaos.fire(chaos.SITES.HEARTBEAT, step=step).skipped:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{time.time()} {step if step is not None else -1}")
            # fsync before the rename: a host crash must not leave a
            # fresh-mtime/empty-content heartbeat that masks a dead worker
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)

    def last(self) -> Optional[float]:
        try:
            return float(open(self.path).read().split()[0])
        except (OSError, ValueError, IndexError):
            return None

    def last_step(self) -> Optional[int]:
        try:
            return int(open(self.path).read().split()[1])
        except (OSError, ValueError, IndexError):
            return None

    def stale_s(self) -> Optional[float]:
        """Seconds since the last beat landed (None if none ever did)."""
        t = self.last()
        return None if t is None else time.time() - t


@dataclass
class HeartbeatMonitor:
    hb: Heartbeat
    timeout: float = 60.0

    def alive(self) -> bool:
        s = self.hb.stale_s()
        return s is not None and s < self.timeout
