"""Differential checkpointing (paper §4.2.3): only dirty blocks are written;
past the ~95 % dirty break-even the engine auto-promotes to FULL. Inspect
the resulting CHK5 files with ``python -m repro.tools.chkls <file>``.

Run:  PYTHONPATH=src python examples/differential_demo.py
"""
import glob
import os
import shutil

import jax.numpy as jnp
import numpy as np

from repro.core.context import CHK_DIFF, CheckpointConfig, CheckpointContext

CKPT = "/tmp/openchk-diff-example"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    rng = np.random.RandomState(0)
    state = {"params": jnp.asarray(rng.randn(1 << 20).astype(np.float32)),
             "step": jnp.int32(0)}

    ctx = CheckpointContext(CheckpointConfig(
        dir=CKPT, backend="fti", block_bytes=16_384, dedicated_thread=False))

    rep = ctx.store(state, id=1, level=1)                    # base FULL
    print(f"id=1 FULL   {rep.bytes_payload:>10,d} B")

    # touch 1 % of the data → tiny delta
    state["params"] = state["params"].at[:10_000].add(1.0)
    state["step"] = jnp.int32(1)
    rep = ctx.store(state, id=2, level=1, kind=CHK_DIFF)
    print(f"id=2 {rep.kind:5s}  {rep.bytes_payload:>10,d} B "
          f"(dirty ratio {rep.dirty_ratio:.3f})")

    # touch everything → engine promotes to FULL (paper's 95 % break-even)
    state["params"] = state["params"] + 1.0
    state["step"] = jnp.int32(2)
    rep = ctx.store(state, id=3, level=1, kind=CHK_DIFF)
    print(f"id=3 {rep.kind:5s}  {rep.bytes_payload:>10,d} B "
          f"(dirty ratio {rep.dirty_ratio:.3f}, promoted={rep.promoted_full})")

    ctx.shutdown()

    # restore replays base + deltas exactly
    ctx2 = CheckpointContext(CheckpointConfig(dir=CKPT, backend="fti"))
    got = ctx2.load({"params": jnp.zeros(1 << 20), "step": jnp.int32(0)})
    assert int(got["step"]) == 2
    assert bool(jnp.all(got["params"] == state["params"]))
    print("replayed restore exact ✓")
    ctx2.shutdown()

    files = glob.glob(os.path.join(CKPT, "**", "*.chk5"), recursive=True)
    print(f"\ninspect the checkpoint files (HDF5-analogue containers):")
    for f in sorted(files)[:3]:
        print(f"  python -m repro.tools.chkls {f}")


if __name__ == "__main__":
    main()
