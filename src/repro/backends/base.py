"""Backend base: the "native library" surface each backend exposes, plus
the *uniform* pipeline driving that all of them share.

Each backend mirrors the real library's API shape (names, call protocol,
quirks) — that is what the paper's SLOC/programmability comparison is
about: using these *directly* is verbose; using them through the OpenCHK
directives is five lines (benchmarks/bench_sloc.py reproduces Tables 4–6).

What a backend *declares* (capabilities):

    supports_diff               checkpoint kinds (CHK_DIFF) available?
    supports_dedicated_thread   CP-dedicated thread (§4.2.2) available?
    supports_incremental        §8 incremental stores available?
    max_level                   deepest ladder rung

What a backend *composes* (``compose_tiers``): the level → tier-stack map
the pipeline places with.  No backend re-implements placement, redundancy
or commit — those are pipeline stages (core/pipeline.py); file-mode
protocols (SCR) enter the pipeline at Place via ``finish_external``.

Asynchrony is uniform: Plan always runs on the calling thread (device
snapshot / on-device diff kernels, digest ordering); when the backend has a
CP-dedicated thread, the Pack → Place → Commit tail is submitted to it —
for FULL, DIFF *and* incremental stores alike.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.async_engine import CPDedicatedThread
from repro.core.comm import Communicator
from repro.core.storage import (
    CHK_FULL,
    LoadRequest,
    StorageConfig,
    StorageEngine,
    StoreReport,
    StoreRequest,
)


class Backend(abc.ABC):
    """Capabilities + the uniform entry points TCL drives."""

    name: str = "?"
    supports_diff: bool = False
    supports_dedicated_thread: bool = False
    supports_incremental: bool = True
    max_level: int = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 dedicated_thread: Optional[bool] = None):
        self.cfg = cfg
        self.comm = comm
        self.engine = StorageEngine(cfg, comm, compose=self.compose_tiers(),
                                    pack_compose=self.compose_pack_tiers())
        self.pipeline = self.engine.pipeline
        use_cp = (self.supports_dedicated_thread if dedicated_thread is None
                  else dedicated_thread and self.supports_dedicated_thread)
        self._cp: Optional[CPDedicatedThread] = (
            CPDedicatedThread(name=f"openchk-cp-{self.name}")
            if use_cp else None)
        self.stats: Dict[str, Any] = {"stores": 0, "loads": 0,
                                      "diff_fallbacks": 0, "bytes": 0}

    # --- declaration hooks -------------------------------------------- #

    def compose_tiers(self) -> Optional[Callable]:
        """Return a ``TierContext → {level: [Tier, ...]}`` composer, or None
        for the default FTI ladder (core/tiers.default_tier_stacks).
        Override to plug in custom tiers without touching the pipeline."""
        return None

    def compose_pack_tiers(self) -> Optional[Callable]:
        """Return a ``() → [PackTier, ...]`` composer for the Pack-stage
        encoder chain (clause-consuming: compression codecs first, the CHK5
        format tier as fallback — core/tiers.default_pack_tiers), or None
        for the default.  Override to add codecs without touching Pack."""
        return None

    def capabilities(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "diff": self.supports_diff,
            "dedicated_thread": self.supports_dedicated_thread,
            "incremental": self.supports_incremental,
            "max_level": self.max_level,
            # the object-store L4 rung rides the shared pipeline stacks,
            # so every backend gains it from config, not from backend code
            "objstore": self.engine.objstore_tier() is not None,
        }

    # --- uniform surface driven by TCL -------------------------------- #

    @staticmethod
    def as_request(named_or_req, ckpt_id=None, level=None,
                   kind=None) -> StoreRequest:
        """Normalize the TCL call protocol: the single ``StoreRequest``
        object carries everything; the old positional form
        ``(named, ckpt_id, level, kind)`` converts to a clause-less one."""
        if isinstance(named_or_req, StoreRequest):
            return named_or_req
        return StoreRequest(named=named_or_req, ckpt_id=int(ckpt_id),
                            level=int(level), kind=kind or CHK_FULL)

    def tcl_store(self, req: Any, ckpt_id: Optional[int] = None,
                  level: Optional[int] = None,
                  kind: Optional[str] = None) -> Optional[StoreReport]:
        """Plan on the calling thread; finish sync or on the CP thread.
        Returns None when the store was handed to the CP thread (errors
        surface at the next directive, FTI-style).

        ``req`` is a :class:`StoreRequest` (clause specs included); the
        legacy positional protocol is accepted via :meth:`as_request`."""
        req = self.as_request(req, ckpt_id, level, kind)
        if self._cp is not None:
            # surface deferred failures BEFORE plan() touches the digest
            # chain — otherwise a dropped store leaves digests pointing at
            # data no committed checkpoint holds
            self._cp.check_errors()
        if req.wants_diff and not self.supports_diff:
            self.stats["diff_fallbacks"] += 1
        req.level = min(req.level, self.max_level)
        req.diff_supported = self.supports_diff
        plan = self.pipeline.plan(req)
        if self._cp is not None:
            try:
                self._cp.submit(req.ckpt_id, lambda: self._finish(plan))
            except BaseException:
                # the tail will never run — release the plan's digest
                # fence or the next DIFF plan blocks forever
                self.pipeline.abort_plan(plan)
                raise
            return None
        return self._finish(plan)

    def _finish(self, plan) -> StoreReport:
        rep = self.pipeline.finish(plan)
        self.stats["stores"] += 1
        self.stats["bytes"] += rep.bytes_payload
        return rep

    def tcl_load(self, req: Optional[LoadRequest] = None
                 ) -> Optional[Dict[str, np.ndarray]]:
        """Restore the newest restorable checkpoint's named leaves (codec
        datasets are decoded and roundtrip-verified by the Pack tiers'
        read side).  ``req`` carries the load-side clause specs; backends
        that restore whole containers don't need it, but it rides the
        uniform protocol so subclasses can consume it.

        Sharded leaves come back as lazy ``ShardedLeafRef`` handles — TCL
        assembles exactly the regions the restart template's shardings
        need (native-API callers use ``engine.load_latest()``, which
        materializes)."""
        self.tcl_wait()
        got = self.engine.load_latest(lazy_sharded=True)
        if got is None:
            return None
        self.stats["loads"] += 1
        return got[0]

    def tcl_store_begin(self, ckpt_id: int, level: int,
                        extra_meta: Optional[Dict[str, Any]] = None):
        """Open an incremental store routed through this backend's pipeline
        (and its CP thread, when present)."""
        if not self.supports_incremental:
            raise NotImplementedError(
                f"backend {self.name!r} has no incremental stores")
        from repro.core.incremental import IncrementalStore
        return IncrementalStore(self.engine, ckpt_id, level,
                                extra_meta=extra_meta, cp=self._cp,
                                stats=self.stats)

    def tcl_wait(self) -> None:
        """Fence asynchronous work (no-op for synchronous backends)."""
        if self._cp is not None:
            self._cp.wait()
            self._cp.check_errors()

    def tcl_finalize(self) -> None:
        if self._cp is not None:
            self._cp.wait()
            try:
                # a failure in the very last async store must not vanish:
                # shutdown is the final directive that can surface it
                self._cp.check_errors()
            finally:
                self._cp.shutdown()
        else:
            self.tcl_wait()
