"""Serving engine: batched prefill + incremental decode with KV caches.

``make_serve_step`` builds the single-token decode step that the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells. The engine's state
(caches + positions + generated tokens) is a pytree, so OpenCHK can
checkpoint a *serving* process too — a failed server resumes decoding
without re-running prefill (examples/serve_resilient.py).

Weights are an explicit :class:`WeightsHandle` — an epoch-tagged,
provenance-carrying immutable record — not a bare pytree attribute.
:meth:`ServingEngine.set_weights` is the **only** mutation path, and the
flip is atomic (one attribute assignment of an immutable handle):
``generate()`` captures the handle once per batch, so an in-flight batch
finishes entirely on the weights it started with and the next batch picks
up the new epoch — the zero-downtime hot-swap contract the deploy
subscriber (``repro.serve.deploy``) builds on.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace


class ServeState(NamedTuple):
    caches: Any
    pos: jnp.ndarray             # scalar int32 — next write position
    last_token: jnp.ndarray      # (B, 1) int32


@dataclass(frozen=True)
class WeightsHandle:
    """The weights a serving engine holds, with their provenance: the
    param pytree plus the deploy epoch that installed it, the catalog
    entry it came from, and the sharding it was assembled onto.  Frozen —
    a swap replaces the whole handle, never a leaf inside one, so a
    reader holding a handle can never observe a torn tree."""
    params: Any
    epoch: int = 0                       # monotonic per-engine swap count
    entry_id: Optional[int] = None       # catalog entry id (None = local)
    sharding: Any = None                 # serving-mesh sharding (or None)


def make_serve_step(model: Model) -> Callable[..., Tuple[jnp.ndarray, Any]]:
    """serve_step(params, token (B,1), caches, pos) → (next_token, caches).

    Greedy argmax sampling (deterministic — serving benchmarks measure the
    system, not the sampler).
    """

    def serve_step(params, token, caches, pos):
        logits, caches = model.decode_step(params, token, caches, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, caches

    return serve_step


class ServingEngine:
    """Minimal batched serving loop over a fixed request batch."""

    def __init__(self, model: Model, params: Any, batch: int, max_len: int,
                 name: str = "serve"):
        self.model = model
        #: telemetry label for this engine's swap events and gauges; a
        #: FleetDeployer re-stamps it with the replica name it manages
        self.name = name
        if not isinstance(params, WeightsHandle):
            params = WeightsHandle(params=params)
        self._weights = params
        self._swap_lock = threading.Lock()
        self.batch = batch
        self.max_len = max_len
        self._step = jax.jit(make_serve_step(model))
        self._decode_warm = jax.jit(model.decode_step)
        self.state: Optional[ServeState] = None
        #: called as ``swap_hook(old_handle, new_handle)`` after every
        #: successful set_weights — deploy readiness reporting
        self.swap_hook: Optional[Callable[[WeightsHandle, WeightsHandle],
                                          None]] = None

    # --- the weights surface --------------------------------------------- #

    @property
    def weights(self) -> WeightsHandle:
        return self._weights

    @property
    def params(self) -> Any:
        """The current param pytree (read-only view of the handle —
        mutation goes through :meth:`set_weights`)."""
        return self._weights.params

    def set_weights(self, handle: WeightsHandle) -> WeightsHandle:
        """The only weights mutation path: atomically flip the engine to
        ``handle``.  A zero/unset epoch is stamped monotonically so every
        swap is observable.  In-flight ``generate()`` batches captured the
        old handle and finish on it; the next batch serves the new one."""
        if not isinstance(handle, WeightsHandle):
            raise TypeError(
                f"set_weights takes a WeightsHandle, not "
                f"{type(handle).__name__} — wrap the pytree: "
                f"WeightsHandle(params=...)")
        with self._swap_lock:
            old = self._weights
            if handle.epoch <= old.epoch:
                handle = WeightsHandle(
                    params=handle.params, epoch=old.epoch + 1,
                    entry_id=handle.entry_id, sharding=handle.sharding)
            self._weights = handle       # the atomic flip
        # every swap is a telemetry event + gauge update: the fleet-wide
        # epoch/entry view (fleet_epochs, /readyz payloads, dashboards)
        # reads these instead of bespoke dicts
        ttrace.instant("serve.swap", replica=self.name, epoch=handle.epoch,
                       entry=handle.entry_id)
        tmetrics.gauge("openchk_serve_epoch",
                       replica=self.name).set(handle.epoch)
        tmetrics.gauge("openchk_fleet_entry_id", replica=self.name).set(
            -1 if handle.entry_id is None else handle.entry_id)
        if self.swap_hook is not None:
            self.swap_hook(old, handle)
        return handle

    # --- serving --------------------------------------------------------- #

    def prefill(self, prompts: jnp.ndarray) -> None:
        """Sequential prefill through the decode path (cache-exact; fine for
        the small CPU examples — large-scale prefill uses model.forward)."""
        b, s = prompts.shape
        if s == 0:
            raise ValueError(
                "prefill needs at least one prompt token per sequence "
                f"(got prompt_len=0 for batch {b}) — there are no logits "
                "to seed decoding from an empty prompt")
        handle = self._weights          # one capture — swap-consistent
        caches = self.model.init_caches(b, self.max_len)
        for i in range(s):
            logits, caches = self._decode_warm(
                handle.params, prompts[:, i: i + 1], caches, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.state = ServeState(caches, jnp.int32(s), nxt)

    def generate(self, n_tokens: int) -> jnp.ndarray:
        assert self.state is not None, "prefill first (or restore a checkpoint)"
        toks = []
        st = self.state
        # capture the handle once: this batch runs to completion on the
        # weights it started with, even if set_weights flips mid-loop —
        # a swap is only ever observable at a batch boundary
        handle = self._weights
        for _ in range(n_tokens):
            nxt, caches = self._step(handle.params, st.last_token,
                                     st.caches, st.pos)
            st = ServeState(caches, st.pos + 1, nxt)
            toks.append(nxt)
        self.state = st
        return jnp.concatenate(toks, axis=1)

    # --- checkpointable serving state (OpenCHK integration) -------------- #
    def get_state(self) -> ServeState:
        return self.state

    def set_state(self, st: ServeState) -> None:
        self.state = st
