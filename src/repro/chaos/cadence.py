"""Daly-optimal adaptive checkpoint cadence.

Implements the higher-order optimum-interval estimate from Daly, "A higher
order estimate of the optimum checkpoint interval for restart dumps"
(FGCS 2006), and the progress-rate / checkpoint-efficiency model from
Daly & Jones, "Quantifying checkpoint efficiency" — the equations that
SNIPPETS.md snippet 3 (comd-ft ``progress_rate_test.c``) encodes. The
snippet's reference constants (10 TB/s peak store/recovery bandwidth,
1-year per-node MTBF scaling linearly with node count, 2432 GB/node with a
20% checkpoint fraction) are mirrored in :data:`REFERENCE` and pinned by
unit tests.

Model (delta = checkpoint write cost, R = restart/recovery cost, M = MTBF):

* optimum interval, for delta < 2M::

      tau_opt = sqrt(2 delta M) * [1 + (1/3) sqrt(delta / 2M)
                                     + (1/9) (delta / 2M)] - delta

  and ``tau_opt = M`` once delta >= 2M (checkpointing costs more than the
  expected uptime — take the full interval).

* expected total wall time for Ts seconds of useful work, with Poisson
  failures at rate 1/M (Daly eq. 13)::

      T(tau) = M e^{R/M} (e^{(tau+delta)/M} - 1) Ts / tau

  giving  ``progress_rate(tau) = Ts / T = tau e^{-R/M} / (M (e^{(tau+delta)/M} - 1))``
  — the fraction of wall time that is forward progress.

* ``checkpoint_efficiency = progress_rate(tau_opt)`` — the best achievable
  fraction given the platform's (delta, R, M); what the bench gates.

:class:`CadenceController` feeds these from live signals: per-level store
cost EWMA from pipeline :class:`~repro.core.pipeline.StoreReport` s,
recovery cost from observed restores, and MTBF estimated online from
``ft/detector`` heartbeat gaps plus the chaos registry's injected-fault
history. L1's tiny delta keeps it frequent; L4's full-bandwidth delta
tracks the Daly optimum.
"""
from __future__ import annotations

import json
import math
import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# -- reference constants (SNIPPETS.md snippet 3, comd-ft) -------------------
SECONDS_PER_YEAR = 365.25 * 86400.0

# checkpoint kinds, mirrored from core/protect.py (string-stable contract;
# re-declared here so the chaos package stays a stdlib-only leaf)
CHK_FULL_KIND = "FULL"
CHK_DIFF_KIND = "DIFF"


@dataclass(frozen=True)
class ReferenceConstants:
    """comd-ft progress_rate_test.c platform model."""

    peak_bw_gb_s: float = 10000.0  # checkpoint store bandwidth
    peak_rec_bw_gb_s: float = 10000.0  # recovery read bandwidth
    mtbf_per_node_s: float = SECONDS_PER_YEAR  # 1 year per node
    mem_per_node_gb: float = 2432.0
    mem_chkpt_frac: float = 0.20

    @property
    def chkpt_gb_per_node(self) -> float:
        return self.mem_per_node_gb * self.mem_chkpt_frac  # 486.4 GB

    def platform(self, num_nodes: int) -> "Platform":
        """System-level (delta, R, M) for a machine of *num_nodes* nodes.

        Snippet assumptions: MTBF scales down linearly with node count;
        recovery reads the same bytes the checkpoint wrote.
        """
        size_gb = self.chkpt_gb_per_node * num_nodes
        return Platform(
            delta_s=size_gb / self.peak_bw_gb_s,
            recovery_s=size_gb / self.peak_rec_bw_gb_s,
            mtbf_s=self.mtbf_per_node_s / num_nodes,
        )


REFERENCE = ReferenceConstants()


@dataclass(frozen=True)
class Platform:
    delta_s: float
    recovery_s: float
    mtbf_s: float


# -- closed-form Daly equations ---------------------------------------------
def daly_interval(delta_s: float, mtbf_s: float) -> float:
    """Higher-order optimum compute interval between checkpoints (seconds)."""
    if delta_s <= 0.0:
        raise ValueError("checkpoint cost delta must be positive")
    if mtbf_s <= 0.0:
        raise ValueError("MTBF must be positive")
    if delta_s >= 2.0 * mtbf_s:
        return mtbf_s
    x = delta_s / (2.0 * mtbf_s)
    return math.sqrt(2.0 * delta_s * mtbf_s) * (
        1.0 + math.sqrt(x) / 3.0 + x / 9.0
    ) - delta_s


def progress_rate(tau_s: float, delta_s: float, recovery_s: float, mtbf_s: float) -> float:
    """Fraction of wall time spent on forward progress at interval tau."""
    if tau_s <= 0.0 or mtbf_s <= 0.0:
        raise ValueError("interval and MTBF must be positive")
    expo = (tau_s + delta_s) / mtbf_s
    if expo > 500.0:  # e^{expo} overflows; rate is numerically zero
        return 0.0
    return tau_s * math.exp(-recovery_s / mtbf_s) / (mtbf_s * (math.expm1(expo)))


def checkpoint_efficiency(delta_s: float, recovery_s: float, mtbf_s: float) -> float:
    """Best achievable progress rate: progress_rate at the Daly optimum."""
    return progress_rate(daly_interval(delta_s, mtbf_s), delta_s, recovery_s, mtbf_s)


# -- online MTBF estimation --------------------------------------------------
class MTBFEstimator:
    """MTBF = observed uptime span / failures, smoothed by a prior.

    Signals: explicit failures (injected-fault history, supervisor
    restarts) and progress marks (heartbeats / steps) that extend the
    observed span. A heartbeat gap longer than ``gap_failure_s`` counts as
    a failure signal — a silent worker is indistinguishable from a dead
    one at the cadence layer.
    """

    def __init__(
        self,
        prior_mtbf_s: float = 3600.0,
        prior_weight: float = 1.0,
        gap_failure_s: Optional[float] = None,
    ) -> None:
        self.prior_mtbf_s = float(prior_mtbf_s)
        self.prior_weight = float(prior_weight)
        self.gap_failure_s = gap_failure_s
        self._span_s = 0.0
        self._failures = 0
        self._last_t: Optional[float] = None

    @property
    def failures(self) -> int:
        return self._failures

    @property
    def span_s(self) -> float:
        return self._span_s

    def merge(self, failures: int, span_s: float) -> None:
        """Fold in observations made by *another* estimator — the
        supervisor's real worker-death/heartbeat-gap record, handed to a
        restarted worker through :class:`MTBFFeed` — without disturbing
        this estimator's own progress cursor."""
        if span_s > 0.0:
            self._span_s += float(span_s)
        if failures > 0:
            self._failures += int(failures)

    def note_progress(self, t: Optional[float] = None) -> None:
        """A liveness mark (heartbeat / step) at monotonic time *t*."""
        t = time.monotonic() if t is None else t
        if self._last_t is not None:
            gap = t - self._last_t
            if gap > 0.0:
                self._span_s += gap
                if self.gap_failure_s is not None and gap > self.gap_failure_s:
                    self._failures += 1
        self._last_t = t

    def note_failure(self, t: Optional[float] = None) -> None:
        t = time.monotonic() if t is None else t
        if self._last_t is not None and t > self._last_t:
            self._span_s += t - self._last_t
        self._last_t = t
        self._failures += 1

    def ingest_fault_times(self, times: List[float]) -> None:
        """Feed the chaos registry's fired-fault monotonic timestamps."""
        for t in sorted(times):
            self.note_failure(t)

    def estimate(self) -> float:
        """Posterior-mean MTBF (prior acts as one pseudo-observation)."""
        num = self.prior_mtbf_s * self.prior_weight + self._span_s
        den = self.prior_weight + self._failures
        return num / den if den > 0 else self.prior_mtbf_s


class MTBFFeed:
    """Durable failure-observation file: supervisor writes, worker seeds.

    The supervisor watches worker deaths and heartbeat gaps from outside
    the process; a restarted worker's fresh :class:`MTBFEstimator` would
    otherwise start blind at the prior.  The feed closes that loop: the
    supervisor :meth:`write` s its estimator's (failures, span) plus
    death/MTTR bookkeeping after every death, and the worker
    :meth:`seed` s them into its cadence estimator at startup.  Atomic
    tmp+replace writes; malformed content warns and seeds nothing — a
    corrupt feed must never stop a restart."""

    def __init__(self, path: str) -> None:
        self.path = path

    def read(self) -> Optional[Dict]:
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                blob = json.load(f)
            if not isinstance(blob, dict):
                raise ValueError("feed root must be a JSON object")
            return blob
        except FileNotFoundError:
            return None
        except (OSError, ValueError) as e:
            warnings.warn(f"ignoring malformed MTBF feed at {self.path}: {e}",
                          RuntimeWarning, stacklevel=2)
            return None

    def write(self, estimator: MTBFEstimator, *, deaths: int = 0,
              mttr_s: Optional[List[float]] = None) -> None:
        blob = {
            "failures": estimator.failures,
            "span_s": round(estimator.span_s, 6),
            "estimate_s": round(estimator.estimate(), 6),
            "deaths": deaths,
            "mttr_s": [round(m, 6) for m in (mttr_s or [])],
        }
        tmp = self.path + ".tmp"
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(blob, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except OSError as e:
            warnings.warn(f"could not write MTBF feed to {self.path}: {e}",
                          RuntimeWarning, stacklevel=2)

    def seed(self, estimator: MTBFEstimator) -> bool:
        """Merge the feed's observations into *estimator*; True if any."""
        blob = self.read()
        if not blob:
            return False
        try:
            failures = int(blob.get("failures", 0))
            span_s = float(blob.get("span_s", 0.0))
        except (TypeError, ValueError) as e:
            warnings.warn(f"ignoring malformed MTBF feed at {self.path}: {e}",
                          RuntimeWarning, stacklevel=2)
            return False
        if failures <= 0 and span_s <= 0.0:
            return False
        estimator.merge(failures, span_s)
        return True


# -- per-tier cadence controller ---------------------------------------------
@dataclass
class _LevelCost:
    store_s: Optional[float] = None  # EWMA (FULL / promoted stores)
    diff_store_s: Optional[float] = None  # EWMA (non-promoted DIFF stores)
    dirty_ratio: Optional[float] = None  # EWMA of observed DIFF dirty ratio
    recovery_s: Optional[float] = None  # EWMA
    n_stores: int = 0


@dataclass
class CadenceConfig:
    levels: tuple = (1, 2, 3, 4)
    ewma: float = 0.3  # weight of the newest observation
    min_interval_s: float = 1e-3
    max_interval_s: float = 7 * 86400.0
    prior_mtbf_s: float = 3600.0
    prior_store_s: float = 1.0  # assumed delta before any measurement
    gap_failure_s: Optional[float] = None
    #: dirty-ratio break-even above which the diff engine promotes to
    #: FULL (mirrors StorageConfig.promote_threshold) — at or past it the
    #: DIFF interval collapses onto the FULL interval, because the store
    #: the schedule would trigger is going to be a FULL anyway
    promote_threshold: float = 0.95


class CadenceController:
    """Per-tier Daly-optimal checkpoint intervals from live measurements.

    Wire-up: ``pipeline.on_report = controller.note_report`` feeds store
    costs; the training loop calls :meth:`note_step` each step (extends the
    MTBF uptime span) and asks :meth:`due_levels` which checkpoint levels
    are due now. Restores feed :meth:`note_recovery`; failures (supervisor
    restarts, chaos history) feed :meth:`note_failure` /
    :meth:`ingest_chaos_history`.
    """

    def __init__(self, config: Optional[CadenceConfig] = None) -> None:
        self.cfg = config or CadenceConfig()
        self.mtbf = MTBFEstimator(
            prior_mtbf_s=self.cfg.prior_mtbf_s,
            gap_failure_s=self.cfg.gap_failure_s,
        )
        self._costs: Dict[int, _LevelCost] = {lv: _LevelCost() for lv in self.cfg.levels}
        self._last_store_t: Dict[int, float] = {}
        self._ingested_faults = 0

    # -- observations -----------------------------------------------------
    def _ewma(self, old: Optional[float], new: float) -> float:
        if old is None:
            return new
        a = self.cfg.ewma
        return a * new + (1.0 - a) * old

    def note_store(self, level: int, seconds: float) -> None:
        c = self._costs.setdefault(level, _LevelCost())
        c.store_s = self._ewma(c.store_s, float(seconds))
        c.n_stores += 1

    def note_diff_store(self, level: int, seconds: Optional[float] = None,
                        dirty_ratio: Optional[float] = None) -> None:
        """A non-promoted DIFF store: its own cost EWMA + dirty ratio."""
        c = self._costs.setdefault(level, _LevelCost())
        if seconds is not None:
            c.diff_store_s = self._ewma(c.diff_store_s, float(seconds))
            c.n_stores += 1
        if dirty_ratio is not None:
            c.dirty_ratio = self._ewma(c.dirty_ratio, float(dirty_ratio))

    def note_report(self, report) -> None:
        """Observer hook for ``CheckpointPipeline.on_report``.

        Routes by what the store actually was: a DIFF that the engine
        promoted to FULL (dirty ratio past break-even) is a FULL cost
        observation — charging its wall time to the DIFF EWMA would make
        the DIFF schedule pay FULL prices forever after one hot step."""
        level = int(report.level)
        kind = getattr(report, "kind", CHK_FULL_KIND)
        promoted = bool(getattr(report, "promoted_full", False))
        dirty = getattr(report, "dirty_ratio", None)
        if kind == CHK_DIFF_KIND and not promoted:
            self.note_diff_store(level, float(report.seconds), dirty)
        else:
            self.note_store(level, float(report.seconds))
            if promoted and dirty is not None:
                # the promotion still carries dirty-ratio evidence
                self.note_diff_store(level, None, float(dirty))

    def note_recovery(self, level: int, seconds: float) -> None:
        c = self._costs.setdefault(level, _LevelCost())
        c.recovery_s = self._ewma(c.recovery_s, float(seconds))

    def note_step(self, t: Optional[float] = None) -> None:
        self.mtbf.note_progress(t)

    def note_failure(self, t: Optional[float] = None) -> None:
        self.mtbf.note_failure(t)

    def ingest_chaos_history(self, registry=None) -> int:
        """Fold newly-fired injected faults into the MTBF estimate."""
        if registry is None:
            from repro.chaos.inject import registry as _reg

            registry = _reg()
        times = registry.fault_times()
        fresh = times[self._ingested_faults:]
        self.mtbf.ingest_fault_times(fresh)
        self._ingested_faults = len(times)
        return len(fresh)

    # -- model outputs ----------------------------------------------------
    def store_cost(self, level: int) -> float:
        c = self._costs.get(level)
        if c is None or c.store_s is None:
            return self.cfg.prior_store_s
        return c.store_s

    def recovery_cost(self, level: int) -> float:
        c = self._costs.get(level)
        if c is not None and c.recovery_s is not None:
            return c.recovery_s
        # snippet assumption (2): recovery reads what the store wrote
        return self.store_cost(level)

    def diff_store_cost(self, level: int) -> float:
        """Expected delta for a DIFF store at *level* — the dirty-ratio
        economics folded into the Daly math.

        Past the promote threshold the engine turns the DIFF into a FULL,
        so the cost *is* the FULL cost.  Below it, a measured DIFF EWMA
        wins; with only a dirty ratio observed, the FULL cost scales by
        it (a DIFF writes ~dirty_ratio of the payload); with nothing
        observed, assume FULL (never schedule cheaper than evidence)."""
        c = self._costs.get(level)
        full = self.store_cost(level)
        if c is None:
            return full
        if (c.dirty_ratio is not None
                and c.dirty_ratio >= self.cfg.promote_threshold):
            return full
        if c.diff_store_s is not None:
            return c.diff_store_s
        if c.dirty_ratio is not None:
            return max(c.dirty_ratio, 1e-3) * full
        return full

    def interval_for(self, level: int, kind: str = CHK_FULL_KIND) -> float:
        """Daly-optimal compute interval for *level*, clamped to config.

        ``kind=CHK_DIFF_KIND`` paces DIFF stores by their own (cheaper)
        delta instead of FULL pricing — the ROADMAP's cadence-aware DIFF
        scheduling rung."""
        delta = (self.diff_store_cost(level) if kind == CHK_DIFF_KIND
                 else self.store_cost(level))
        tau = daly_interval(delta, self.mtbf.estimate())
        return min(max(tau, self.cfg.min_interval_s), self.cfg.max_interval_s)

    def schedule(self, kind: str = CHK_FULL_KIND) -> Dict[int, float]:
        return {lv: self.interval_for(lv, kind) for lv in self.cfg.levels}

    def due_levels(self, now: Optional[float] = None,
                   kind: str = CHK_FULL_KIND) -> List[int]:
        """Levels whose interval has elapsed since their last store.

        Highest level first, so a step that crosses several thresholds
        stores once at the strongest tier (tier stacks nest: L4's stack
        includes the local tier).
        """
        now = time.monotonic() if now is None else now
        due = []
        for lv in sorted(self.cfg.levels, reverse=True):
            last = self._last_store_t.get(lv)
            if last is None or (now - last) >= self.interval_for(lv, kind):
                due.append(lv)
        return due

    def mark_stored(self, level: int, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        # a store at level L refreshes every nested weaker level too
        for lv in self.cfg.levels:
            if lv <= level:
                self._last_store_t[lv] = now

    def progress_rate(self, level: int = 4) -> float:
        return progress_rate(
            self.interval_for(level),
            self.store_cost(level),
            self.recovery_cost(level),
            self.mtbf.estimate(),
        )

    def checkpoint_efficiency(self, level: int = 4) -> float:
        return checkpoint_efficiency(
            self.store_cost(level),
            self.recovery_cost(level),
            self.mtbf.estimate(),
        )

    def datapoints(self, level: int = 4) -> Dict[str, float]:
        """First-class bench datapoints (bench_overhead.py surfaces these)."""
        return {
            "cadence_interval_s": self.interval_for(level),
            "cadence_store_cost_s": self.store_cost(level),
            "cadence_mtbf_s": self.mtbf.estimate(),
            "progress_rate": self.progress_rate(level),
            "checkpoint_efficiency": self.checkpoint_efficiency(level),
        }
