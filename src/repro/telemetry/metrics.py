"""Counter/gauge/histogram registry with JSON snapshot + Prometheus text.

One process-wide :class:`MetricsRegistry` (module singleton, mirroring the
tracer) fed by the same instrumentation points as the spans: the pipeline's
commit, the chunk uploader's stats sites, deploy swaps, supervisor
deaths/restarts, chaos fault fires and the cadence controller.  Unlike the
tracer there is no enable switch — a metric update is a dict lookup plus an
integer add under a lock, cheap enough to be always on, and the registry is
what ``/metrics`` (health.py) and the chaos runner's embedded snapshots
read.

Metric naming follows Prometheus convention: ``openchk_`` prefix,
``_total`` suffix on counters, ``_seconds``/``_bytes`` units in the name,
labels for low-cardinality dimensions (level, kind, site, replica).

The canonical instrument set (all created lazily on first touch):

========================================  =========  =======================
name                                      kind       labels
========================================  =========  =======================
openchk_store_total                       counter    level, kind
openchk_store_bytes_total                 counter    level, kind
openchk_store_seconds                     histogram  level
openchk_chunks_uploaded_total             counter    —
openchk_chunks_deduped_total              counter    —
openchk_chunk_bytes_uploaded_total        counter    —
openchk_chunk_bytes_deduped_total         counter    —
openchk_deploy_swaps_total                counter    replica
openchk_deploy_pulls_failed_total         counter    replica
openchk_deploy_bytes_fetched_total        counter    —
openchk_fleet_entry_id                    gauge      replica
openchk_serve_ready                      gauge      replica
openchk_serve_epoch                       gauge      replica
openchk_faults_fired_total                counter    site, mode
openchk_worker_deaths_total               counter    —
openchk_worker_restarts_total             counter    —
openchk_mttr_seconds                      histogram  —
openchk_mtbf_estimate_seconds             gauge      —
openchk_cadence_interval_seconds          gauge      level
========================================  =========  =======================
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

# Default histogram buckets: seconds-flavored, wide enough for both a
# sub-ms L1 store and a multi-minute MTTR.
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                   30.0, 60.0, 300.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey, extra: Optional[Dict[str, str]] = None) -> str:
    items = list(key)
    if extra:
        items = items + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


class Counter:
    """Monotonic float counter."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics)."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> None:
        self._lock = threading.Lock()
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.sum += v
            self.count += 1
            for i, le in enumerate(self.buckets):
                if v <= le:
                    self.counts[i] += 1
                    return
            self.counts[-1] += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        """→ [(le, cumulative_count), ...] ending with (+Inf, count)."""
        with self._lock:
            out, acc = [], 0
            for le, c in zip(self.buckets, self.counts):
                acc += c
                out.append((le, acc))
            out.append((float("inf"), self.count))
            return out


class MetricsRegistry:
    """Get-or-create instrument store keyed by (name, sorted labels)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> ("counter"|"gauge"|"histogram", {label_key: instrument})
        self._families: Dict[str, Tuple[str, Dict[LabelKey, Any]]] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, Any],
             factory) -> Any:
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = (kind, {})
                self._families[name] = fam
            if fam[0] != kind:
                raise TypeError(
                    f"metric {name!r} already registered as {fam[0]}")
            inst = fam[1].get(key)
            if inst is None:
                inst = factory()
                fam[1][key] = inst
            return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels: Any) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    def reset(self) -> None:
        with self._lock:
            self._families = {}

    # -- exposition ------------------------------------------------------- #

    def snapshot(self) -> Dict[str, Any]:
        """JSON-friendly dump: name → {kind, series: [{labels, ...}]}."""
        with self._lock:
            families = {n: (k, dict(s)) for n, (k, s) in
                        self._families.items()}
        out: Dict[str, Any] = {}
        for name, (kind, series) in sorted(families.items()):
            rows = []
            for key, inst in sorted(series.items()):
                row: Dict[str, Any] = {"labels": dict(key)}
                if kind == "histogram":
                    row.update(sum=inst.sum, count=inst.count,
                               buckets=[[le if le != float("inf") else
                                         "+Inf", c]
                                        for le, c in inst.cumulative()])
                else:
                    row["value"] = inst.value
                rows.append(row)
            out[name] = {"kind": kind, "series": rows}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        with self._lock:
            families = {n: (k, dict(s)) for n, (k, s) in
                        self._families.items()}
        lines: List[str] = []
        for name, (kind, series) in sorted(families.items()):
            lines.append(f"# TYPE {name} {kind}")
            for key, inst in sorted(series.items()):
                if kind == "histogram":
                    for le, c in inst.cumulative():
                        le_s = "+Inf" if le == float("inf") else repr(le)
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(key, {'le': le_s})} {c}")
                    lines.append(f"{name}_sum{_fmt_labels(key)} {inst.sum}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {inst.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)} {inst.value}")
        return "\n".join(lines) + "\n"


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def counter(name: str, **labels: Any) -> Counter:
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: Any) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
              **labels: Any) -> Histogram:
    return _REGISTRY.histogram(name, buckets=buckets, **labels)


def snapshot() -> Dict[str, Any]:
    return _REGISTRY.snapshot()


def to_prometheus() -> str:
    return _REGISTRY.to_prometheus()


def reset() -> None:
    _REGISTRY.reset()


def note_store_report(report: Any) -> None:
    """Feed a pipeline ``StoreReport`` into the canonical store metrics.

    Called directly from ``CheckpointPipeline.commit`` (the single-slot
    ``on_report`` hook stays free for user observers like the cadence
    controller)."""
    level = str(getattr(report, "level", "?"))
    kind = str(getattr(report, "kind", "?"))
    counter("openchk_store_total", level=level, kind=kind).inc()
    counter("openchk_store_bytes_total", level=level, kind=kind).inc(
        float(getattr(report, "bytes_payload", 0) or 0))
    histogram("openchk_store_seconds", level=level).observe(
        float(getattr(report, "seconds", 0.0) or 0.0))
