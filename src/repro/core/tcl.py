"""TCL — the Transparent Checkpoint Library layer (paper §5.3).

TCL sits between the directives (context.py) and the backends: it owns
serialization (pytree ⇄ named arrays — the work Mercurium + TCL share in
the paper), resolves the directive's ``Protect`` clause specs over the
flattened tree, and forwards one :class:`StoreRequest` / :class:`LoadRequest`
object to the selected backend — the clauses survive the whole stack
instead of being flattened into positional arguments.

TCL hands the backend the *device-side* protected leaves; the pipeline's
Plan stage (core/pipeline.py) then runs the on-device hash/pack kernels and
takes the device→host snapshot on this thread, in submission order — the
synchronous cost the paper budgets for §4.2.2 — before the Pack → Place →
Commit tail goes to a CP-dedicated thread when the backend has one.

The pre-clause positional protocol (``store(tree, ckpt_id, level, kind,
selectors)``) remains accepted and converts to a clause-less request.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.backends.base import Backend
from repro.backends.registry import make_backend
from repro.core.comm import Communicator
from repro.core.pipeline import LoadRequest, StoreRequest
from repro.core.protect import (
    flatten_named,
    normalize_protects,
    resolve_specs,
    unflatten_named,
)
from repro.core.resharding import ShardedLeafRef, assemble_onto
from repro.core.storage import CHK_FULL, StorageConfig, StoreReport


class TCL:
    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 backend: Optional[str] = None, **backend_kw):
        self.backend: Backend = make_backend(cfg, comm, backend, **backend_kw)
        self.comm = comm

    # ------------------------------------------------------------------ #

    def store(self, req: Any, ckpt_id: Optional[int] = None,
              level: Optional[int] = None, kind: str = CHK_FULL,
              selectors: Optional[List[str]] = None
              ) -> Optional[StoreReport]:
        """Resolve the request's clause specs over the flattened tree and
        forward the one request object to the backend.

        Leaves stay on device here: the pipeline's Plan stage performs the
        snapshot (and, for CHK_DIFF subtrees, the on-device hash/pack)
        synchronously; everything after may be asynchronous."""
        if not isinstance(req, StoreRequest):    # legacy positional protocol
            req = StoreRequest(tree=req, ckpt_id=int(ckpt_id),
                               level=int(level), kind=kind,
                               protects=normalize_protects(selectors))
        if req.named is None:
            named_all, _ = flatten_named(req.tree)
            req.specs = resolve_specs(named_all, req.protects)
            req.named = {p: named_all[p] for p in req.specs}
        return self.backend.tcl_store(req)

    def store_begin(self, ckpt_id: int, level: int):
        """Open an incremental store (§8) on the backend's pipeline — parts
        are added as they become ready; commit may be asynchronous."""
        return self.backend.tcl_store_begin(ckpt_id, level)

    def load(self, req: Any,
             selectors: Optional[List[str]] = None) -> Optional[Any]:
        """Transparent restart: returns a tree shaped like the request's
        template with restored leaves, or None when no checkpoint exists."""
        if not isinstance(req, LoadRequest):     # legacy positional protocol
            req = LoadRequest(template=req,
                              protects=normalize_protects(selectors))
        named_t, treedef = flatten_named(req.template)
        req.specs = resolve_specs(named_t, req.protects)
        restored = self.backend.tcl_load(req)
        if restored is None:
            return None
        merged: Dict[str, Any] = {}
        for path, leaf in named_t.items():
            if path in req.specs:
                if path not in restored:
                    raise KeyError(f"checkpoint missing protected leaf {path!r}")
                arr = restored[path]
                if list(arr.shape) != list(leaf.shape):
                    raise ValueError(
                        f"{path}: checkpoint shape {arr.shape} != "
                        f"template {leaf.shape} (use elastic restore)")
                if arr.dtype != np.dtype(leaf.dtype):
                    raise TypeError(
                        f"{path}: checkpoint dtype {arr.dtype} != "
                        f"template {leaf.dtype}")
                # mesh-change restart: the template leaf's sharding is the
                # *target* layout (core/resharding.reshard_tree builds such
                # templates).  A shard-file checkpoint restores through
                # ElasticLoader assembly: each target device reads exactly
                # its slice from the chunk files — store on 4×4, restore
                # on 2×8 or 16×1 without materializing the global array on
                # host.  Gathered checkpoints land via device_put as
                # before; plain arrays restore unchanged.
                sharding = getattr(leaf, "sharding", None)
                if isinstance(arr, ShardedLeafRef):
                    if sharding is not None:
                        merged[path] = assemble_onto(arr, sharding)
                    else:
                        merged[path] = jax.device_put(arr.materialize(), None)
                else:
                    merged[path] = jax.device_put(arr, sharding)
            else:
                merged[path] = leaf
        return unflatten_named(treedef, merged, req.template)

    def wait(self) -> None:
        self.backend.tcl_wait()

    def finalize(self) -> None:
        self.backend.tcl_finalize()
