"""Training state pytree — the unit the OpenCHK directives protect."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWState, adamw_init


class TrainState(NamedTuple):
    step: jnp.ndarray            # scalar int32 — doubles as the checkpoint id
    params: Any
    opt: AdamWState
    rng: jnp.ndarray             # PRNG key
    data_state: Any              # checkpointable data-pipeline cursor


def init_train_state(params: Any, rng, data_state: Any) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt=adamw_init(params),
        rng=rng,
        data_state=data_state,
    )


def train_state_struct(param_struct: Any, data_state_struct: Any) -> TrainState:
    """Abstract TrainState for dry-run lowering."""
    opt = jax.eval_shape(adamw_init, param_struct)
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=param_struct,
        opt=opt,
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
        data_state=data_state_struct,
    )
